"""Persistent per-mesh autotune cache.

Tuning results are keyed on a **mesh fingerprint** — everything that can
change which design point wins without the workload changing:

  mesh shape + axis names + device kind + jax version + backend target

One JSON file per fingerprint lives under the cache directory
(``~/.cache/repro-tune`` by default, ``REPRO_TUNE_CACHE`` overrides,
``XDG_CACHE_HOME`` respected).  The file name is a short hash of the
fingerprint, but the full fingerprint payload is stored *inside* the file and
re-verified on every load: a payload mismatch (hand-copied cache file, hash
collision, edited entry) invalidates the whole file and forces a re-tune —
never a silent reuse of another mesh's winners.

Entries are keyed on ``(kind, shape signature, candidate-space)`` — the
ranker that produced a winner is recorded but is NOT part of the key, so a
measured result is never clobbered by a later model-ranked lookup.  Hits
never re-measure, with one deliberate exception owned by ``autotune``: an
*explicit* ``ranker="measure"`` request upgrades a model-ranked record (the
pre-warm flow), overwriting it with the measured winner.

Writes are atomic (temp file + ``os.replace``); corrupt or unreadable files
degrade to an empty cache.  A process-local memo avoids re-reading the JSON
on every trace-time resolution.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import time
from typing import Any, Dict, Optional, Tuple

import jax

__all__ = [
    "cache_dir",
    "mesh_fingerprint",
    "fingerprint_digest",
    "load_entry",
    "store_entry",
    "clear_memo",
]

_ENV_DIR = "REPRO_TUNE_CACHE"

# (directory, digest, entry_key) -> record; invalidated via clear_memo()
# (tests) or whenever store_entry writes through it.
_MEMO: Dict[Tuple[str, str, str], Dict[str, Any]] = {}
# (directory, digest) -> parsed file payload, so one trace touching many
# shapes reads once; keyed by directory so distinct cache_dir arguments in
# one process never serve each other's entries
_FILES: Dict[Tuple[str, str], Dict[str, Any]] = {}


def cache_dir() -> str:
    """Resolved cache directory (not created until first store)."""
    env = os.environ.get(_ENV_DIR)
    if env:
        return os.path.expanduser(env)
    xdg = os.environ.get("XDG_CACHE_HOME", "~/.cache")
    return os.path.join(os.path.expanduser(xdg), "repro-tune")


def mesh_fingerprint(
    mesh=None, *, axis: Optional[str] = None, world: Optional[int] = None
) -> Dict[str, Any]:
    """The stable identity a tuning result is valid for.

    With a ``mesh``, the full shape/axis-name tuple is used.  Without one
    (e.g. resolving inside a manual region where only the collective axis is
    visible), the caller supplies ``(axis, world)`` and the fingerprint
    covers just that axis — still unique per (topology, software) pair.
    """
    if mesh is not None:
        shape = tuple(int(mesh.shape[a]) for a in mesh.axis_names)
        names = tuple(str(a) for a in mesh.axis_names)
        dev = mesh.devices.flat[0]
    else:
        if axis is None or world is None:
            raise ValueError("mesh_fingerprint needs a mesh or (axis, world)")
        shape = (int(world),)
        names = (str(axis),)
        dev = jax.devices()[0]
    from repro import backend  # late: backend reads env at call time

    return {
        "mesh_shape": list(shape),
        "axis_names": list(names),
        "device_kind": str(getattr(dev, "device_kind", dev.platform)),
        "jax_version": jax.__version__,
        "backend_target": backend.target(),
    }


def fingerprint_digest(fp: Dict[str, Any]) -> str:
    """Short stable digest of a fingerprint payload (the cache file name)."""
    blob = json.dumps(fp, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _resolve_dir(directory: Optional[str]) -> str:
    return os.path.abspath(directory or cache_dir())


def _path(digest: str, directory: str) -> str:
    return os.path.join(directory, f"{digest}.json")


def _read_file(
    digest: str, directory: str, fp: Dict[str, Any], *, fresh: bool = False
) -> Dict[str, Any]:
    """Load + verify one cache file; any mismatch or damage -> empty cache.

    ``fresh=True`` bypasses the process memo and re-parses the disk file —
    writers use it so concurrent processes sharing a cache directory merge
    instead of clobbering each other with stale memo snapshots.
    """
    if not fresh and (directory, digest) in _FILES:
        return _FILES[(directory, digest)]
    payload: Dict[str, Any] = {"fingerprint": fp, "entries": {}}
    try:
        with open(_path(digest, directory)) as fh:
            data = json.load(fh)
        # the stored fingerprint must match the live one exactly; the digest
        # alone is not trusted (mesh-fingerprint mismatch => re-tune)
        if data.get("fingerprint") == fp and isinstance(data.get("entries"), dict):
            payload = data
    except (OSError, ValueError):
        pass
    _FILES[(directory, digest)] = payload
    return payload


def load_entry(
    fp: Dict[str, Any], entry_key: str, *, directory: Optional[str] = None
) -> Optional[Dict[str, Any]]:
    """Cached record for ``entry_key`` under fingerprint ``fp``, else None."""
    directory = _resolve_dir(directory)
    digest = fingerprint_digest(fp)
    memo_key = (directory, digest, entry_key)
    if memo_key in _MEMO:
        return _MEMO[memo_key]
    rec = _read_file(digest, directory, fp)["entries"].get(entry_key)
    if rec is not None:
        _MEMO[memo_key] = rec
    return rec


def store_entry(
    fp: Dict[str, Any],
    entry_key: str,
    record: Dict[str, Any],
    *,
    directory: Optional[str] = None,
) -> str:
    """Persist ``record``; returns the cache file path.  Atomic per write.

    The payload is re-read from disk (not the memo) right before writing, so
    entries stored by OTHER processes since our last read are merged rather
    than lost — last-writer-wins applies per entry, not per file.
    """
    directory = _resolve_dir(directory)
    digest = fingerprint_digest(fp)
    path = _path(digest, directory)
    payload = _read_file(digest, directory, fp, fresh=True)
    payload["fingerprint"] = fp
    payload["entries"][entry_key] = dict(record, saved_at=time.time())
    os.makedirs(directory, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    _MEMO[(directory, digest, entry_key)] = payload["entries"][entry_key]
    _FILES[(directory, digest)] = payload
    return path


def clear_memo() -> None:
    """Drop the in-process memo (tests use this to force disk round-trips)."""
    _MEMO.clear()
    _FILES.clear()
