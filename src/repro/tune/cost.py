"""Analytic cost model — the ranking fallback when wall time is no signal.

CPU wall time on the emulated target does not predict TPU behavior (ROADMAP),
and resolution can also happen *inside* a trace, where timing is impossible.
This model ranks candidates from first principles in the spirit of
``launch/roofline.py``: per schedule step, bytes-on-wire over link bandwidth
vs. per-tile FLOPs over peak, composed into a pipelined makespan:

    t_step  = max(t_comm, t_comp)            (overlap: the slower engine gates)
    total   = (steps - 1) * t_step           (steady state)
            + (t_comm + t_comp) / C          (pipeline fill/drain: finer
                                              channels expose less head/tail)
            + alpha * C * steps              (per-transfer launch latency —
                                              what keeps C from growing forever)

Order effects: a bidirectional ring with >= 2 channels splits traffic across
both ICI link directions (halving per-link bytes); all2all pays the mean ring
distance (R/4 hops) per payload on a physical ring/torus.  The flow dtype
scales wire bytes only for flows whose *partials* travel (rs / ag_rs); for
pure AG flows the input tiles travel in their own dtype, so the model is
flow-dtype-neutral there and the enumeration order (float32 first) breaks the
tie deterministically.

Hardware constants come from ``launch.roofline.HW`` (TPU v5e) — the model
ranks relative candidates, so absolute calibration is not critical.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

from repro.launch.roofline import HW
from repro.tune.candidates import Candidate, chunk_extent

__all__ = ["ALPHA_S", "step_terms", "predict_cost"]

# per-transfer launch/synchronization latency (seconds); the alpha of a
# classic alpha-beta model.  ~1us per DMA descriptor + semaphore round.
ALPHA_S = 1e-6

# bytes per element flowing tiles travel in (activations; bf16 on TPU)
_TILE_BYTES = 2


def _flow_bytes(accum_dtype: str) -> int:
    return jnp.dtype(accum_dtype).itemsize


def step_terms(
    kind: str, sig: Tuple[int, ...], world: int, accum_dtype: str
) -> Tuple[float, float]:
    """(wire_bytes, flops) per schedule step per rank for one candidate.

    Bytes counts every flow the executor permutes each step (tiles and/or
    the travelling reduction); flops counts the tile compute consumed while
    those transfers are in flight (see core/overlap.run_plan).
    """
    fb = _flow_bytes(accum_dtype)
    if kind == "ag_matmul":
        lead, m_loc, k, n_loc = sig
        wire = lead * m_loc * k * _TILE_BYTES
        flops = 2.0 * lead * m_loc * k * n_loc
    elif kind == "matmul_rs":
        lead, m_glob, k_loc, n = sig
        m_loc = max(1, m_glob // world)
        wire = lead * m_loc * n * fb  # the accumulator is the flow
        flops = 2.0 * lead * m_loc * k_loc * n
    elif kind == "ag_attention":
        b, h, hkv, s_loc, d = sig
        wire = 2.0 * b * hkv * s_loc * d * _TILE_BYTES  # K and V tiles
        flops = 4.0 * b * h * s_loc * s_loc * d  # QK^T + PV
    elif kind == "ag_moe":
        m_loc, d_model, top_k, e_loc, d_exp = sig
        # double ring: token tiles flow forward AND the combined reduction
        # rides the same permutes (in the flow dtype)
        wire = m_loc * d_model * (_TILE_BYTES + fb)
        flops = 6.0 * m_loc * d_model * d_exp * max(1, top_k)
    else:
        raise ValueError(f"no cost model for kind {kind!r}")
    return float(wire), float(flops)


def predict_cost(kind: str, sig: Tuple[int, ...], world: int, cand: Candidate) -> float:
    """Predicted makespan (seconds) of one candidate; lower is better."""
    wire, flops = step_terms(kind, sig, world, cand.accum_dtype)
    steps = world

    # per-link effective bytes for this tile order
    dirs = 2.0 if (cand.order == "bidir_ring" and cand.num_channels >= 2) else 1.0
    hops = max(1.0, world / 4.0) if cand.order == "all2all" else 1.0

    t_comm = wire * hops / (HW["link_bw"] * dirs)
    t_comp = flops / HW["peak_flops"]

    steady = (steps - 1) * max(t_comm, t_comp)
    fill = (t_comm + t_comp) / cand.num_channels
    launch = ALPHA_S * cand.num_channels * steps
    return steady + fill + launch


def explain(kind: str, sig: Tuple[int, ...], world: int, cand: Candidate) -> Dict[str, float]:
    """Itemized terms for reports/benchmarks (same math as predict_cost)."""
    wire, flops = step_terms(kind, sig, world, cand.accum_dtype)
    ext = chunk_extent(kind, sig)
    return {
        "wire_bytes_per_step": wire,
        "flops_per_step": flops,
        "chunk_extent": float(ext),
        "predicted_s": predict_cost(kind, sig, world, cand),
    }
