"""Analytic cost model — the ranking fallback when wall time is no signal.

CPU wall time on the emulated target does not predict TPU behavior (ROADMAP),
and resolution can also happen *inside* a trace, where timing is impossible.
This model ranks candidates from first principles in the spirit of
``launch/roofline.py``: per schedule step, bytes-on-wire over link bandwidth
vs. per-tile FLOPs over peak, composed into a pipelined makespan:

    t_step  = max(t_comm, t_comp)            (overlap: the slower engine gates)
    total   = (steps - 1) * t_step           (steady state)
            + (t_comm + t_comp) / C          (pipeline fill/drain: finer
                                              channels expose less head/tail)
            + alpha * C * steps              (per-transfer launch latency —
                                              what keeps C from growing forever)

Order effects: a bidirectional ring with >= 2 channels splits traffic across
both ICI link directions (halving per-link bytes); all2all pays the mean ring
distance per payload on a physical ring/torus — computed from the actual
``schedules.all2all_peer`` tables (``_order_hops``), never a closed-form
guess, so cost and schedule agree for non-power-of-2 worlds too.  Dtype on
the wire: with no tuned wire (``Candidate.flow is None``) the accum dtype
scales wire bytes only for flows whose *partials* travel (rs / ag_rs) — for
pure AG flows the input tiles travel in their own dtype, so the model is
dtype-neutral there and the enumeration order (float32 first) breaks the tie
deterministically.  A tuned wire dtype (``Candidate.flow``, the QuantSpec
axis) reprices EVERY travelling payload at its itemsize — AG tiles included
— plus a small per-payload scale-table overhead for the quantized wires;
that is the term that lets an int8 flow win comm-bound shapes.

Compute-tile terms (the CompSpec half): for the GEMM kinds ``t_comp`` is
itself a per-tile roofline over the realized (tm, tn, tk) blocking —

    t_comp = max(FLOPs / (peak * mxu_eff), bytes_touched / hbm_bw)
           + beta * n_tiles

where ``mxu_eff`` penalizes tiles narrower than the 128-wide systolic array,
``bytes_touched`` counts the A/B operand tiles streamed from VMEM/HBM per
block plus one accumulator write per (tm, tn) block (bigger tiles amortize
operand re-reads), and ``beta`` is the fixed per-tile issue cost (grid
iteration + copy descriptors) that keeps tiles from shrinking forever.  The
VMEM budget bounds them from above (pruned in ``tune/candidates``).

The attention consumer prices (tm, tk) as (block_q, block_kv): a per-tile
softmax+MXU roofline — the QK^T score tile's MXU utilization, a VPU term
for the fp32 online-softmax work, and a score-spill term (a whole-chunk
score matrix that cannot stay VMEM-resident pays an fp32 HBM round-trip —
exactly what a flash-style tile removes).  The MoE consumer prices the
per-expert grouped GEMMs with a tile-occupancy term: expert groups are
capacity-sized, so the last row tile of each expert pads to tm and wastes
MXU cycles.  All compute terms are accum-dtype-free — the wire dtype only
prices the wire — so AG flows keep the deterministic f32 tie-break.

``alpha`` and ``beta`` are the calibratable constants of the classic
alpha-beta model: defaults below, env overrides ``REPRO_TUNE_ALPHA`` /
``REPRO_TUNE_BETA`` (seconds) for calibration against a real TPU.  Hardware
constants come from ``launch.roofline.HW`` (TPU v5e) and the
``repro.backend`` MXU probe — the model ranks relative candidates, so
absolute calibration is not critical.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, Tuple

import jax.numpy as jnp

from repro.core import schedules
from repro.core.comp_tiles import DEFAULT_TILE, largest_divisor, resolve_tile, tile_footprint_bytes
from repro.launch.roofline import HW
from repro.tune.candidates import (
    Candidate,
    GEMM_TILE_KINDS,
    _tile_dims,
    a2a_sigs,
    chunk_extent,
    seq_sigs,
)

__all__ = [
    "ALPHA_S",
    "BETA_TILE_S",
    "step_terms",
    "realized_tile",
    "comp_step_time",
    "predict_cost",
    "seam_saving",
    "predict_seq_cost",
    "a2a_saving",
    "predict_a2a_cost",
]

# per-transfer launch/synchronization latency (seconds); the alpha of a
# classic alpha-beta model.  ~1us per DMA descriptor + semaphore round.
ALPHA_S = float(os.environ.get("REPRO_TUNE_ALPHA", 1e-6))

# fixed per-compute-tile issue cost (seconds): one grid iteration's control
# flow + operand copy descriptors.  The beta of the compute half.
BETA_TILE_S = float(os.environ.get("REPRO_TUNE_BETA", 2e-7))

# bytes per element flowing tiles travel in (activations; bf16 on TPU)
_TILE_BYTES = 2

# online-softmax statistics and score tiles stay fp32 regardless of the flow
# dtype (core/overlap.ring_attention) — NOT the candidate's accum dtype, so
# the compute term stays accum-dtype-free and AG flows keep the f32 tie-break
_SCORE_BYTES = 4

# VPU elementwise ops per attention score (max, sub, exp, the running l/o
# rescale) and the VPU's throughput relative to the MXU peak — the softmax
# half of the attention roofline
_SOFTMAX_OPS = 8.0
_VPU_FRACTION = 1.0 / 16.0


# bytes per (token, slot) routing entry riding a dispatch tile: one int32
# expert id plus one float32 gate weight (the paper's f_R/f_S travel with data)
_ROUTE_BYTES = 8


# per-payload overhead of a quantized wire: one f32 scale per tile plus the
# descriptor bookkeeping of the side-channel table ride-along
_SCALE_OVERHEAD_BYTES = 64


def _flow_bytes(accum_dtype: str) -> int:
    return jnp.dtype(accum_dtype).itemsize


@functools.lru_cache(maxsize=None)
def _order_hops(order: str, world: int) -> float:
    """Mean ring-distance per payload of one schedule step for ``order``.

    Derived from the actual peer tables (``schedules.all2all_peer``) rather
    than a closed form, so the cost model and the baked schedule cannot
    disagree — in particular for non-power-of-2 worlds, where the all2all
    order falls back to rotation peers instead of XOR pairing.  Ring orders
    always step to a physical neighbor (one hop).
    """
    if order != "all2all" or world <= 1:
        return 1.0
    total = 0
    for s in range(1, world):
        for r in range(world):
            p = schedules.all2all_peer(r, s, world)
            total += min((p - r) % world, (r - p) % world)
    return max(1.0, total / float((world - 1) * world))


def _moe_rows(sig: Tuple[int, ...], world: int) -> float:
    """Effective grouped-GEMM token rows per step for a MoE signature.

    The base count is ``m_loc * top_k`` assignment rows.  The optional MoE
    signature axes refine it: ``sig[5]`` is the hottest-expert imbalance in
    quarter-units (4 == balanced; a hot expert gates the grouped GEMM), and
    ``sig[6]`` is the per-expert capacity row count (dropping bounds the
    work from above, so an aggressively low capacity factor models faster).
    """
    m_loc, _d_model, top_k, e_loc, _d_exp = sig[:5]
    rows = float(m_loc * max(1, top_k))
    if len(sig) > 5:
        rows *= max(1.0, sig[5] / 4.0)
    if len(sig) > 6:
        rows = min(rows, float(max(1, e_loc * world) * sig[6]))
    return rows


def step_terms(
    kind: str, sig: Tuple[int, ...], world: int, accum_dtype: str,
    wire_dtype: str = None,
) -> Tuple[float, float]:
    """(wire_bytes, flops) per schedule step per rank for one candidate.

    Bytes counts every flow the executor permutes each step (tiles and/or
    the travelling reduction); flops counts the tile compute consumed while
    those transfers are in flight (see core/overlap.run_plan).
    ``wire_dtype=None`` keeps the legacy pricing (tiles at the activation
    itemsize, travelling reductions at the accum itemsize); a tuned wire
    dtype reprices everything on the wire at its own itemsize plus the
    quantized-wire scale overhead.
    """
    if wire_dtype is None:
        fb = _flow_bytes(accum_dtype)
        tb, extra = _TILE_BYTES, 0.0
    else:
        from repro.core.quant import wire_itemsize

        fb = tb = wire_itemsize(wire_dtype)
        extra = float(_SCALE_OVERHEAD_BYTES) if wire_dtype not in (
            "float32", "bfloat16", "float16") else 0.0
    if kind == "ag_matmul":
        lead, m_loc, k, n_loc = sig
        lead = abs(lead)  # decode signatures carry a negated lead marker
        wire = lead * m_loc * k * tb + extra
        flops = 2.0 * lead * m_loc * k * n_loc
    elif kind == "matmul_rs":
        lead, m_glob, k_loc, n = sig
        lead = abs(lead)
        m_loc = max(1, m_glob // world)
        wire = lead * m_loc * n * fb + extra  # the accumulator is the flow
        flops = 2.0 * lead * m_loc * k_loc * n
    elif kind == "ag_attention":
        b, h, hkv, s_loc, d = sig
        wire = 2.0 * b * hkv * s_loc * d * tb + extra  # K and V tiles
        flops = 4.0 * b * h * s_loc * s_loc * d  # QK^T + PV
    elif kind == "ag_moe":
        m_loc, d_model, _top_k, _e_loc, d_exp = sig[:5]
        # double ring: token tiles flow forward AND the combined reduction
        # rides the same permutes (in the wire dtype)
        wire = m_loc * d_model * (tb + fb) + extra
        flops = 6.0 * _moe_rows(sig, world) * d_model * d_exp
    elif kind == "a2a_dispatch":
        m_loc, d_model, top_k, _e_loc, d_exp = sig[:5]
        # pairwise exchange of original token tiles plus the routing tables
        # (expert ids + gate weights) that travel with them
        wire = m_loc * d_model * tb + m_loc * max(1, top_k) * _ROUTE_BYTES
        # the expert FFN on landed tiles runs while the next exchange flies
        flops = 6.0 * _moe_rows(sig, world) * d_model * d_exp
    elif kind == "combine_rs":
        m_loc, d_model = sig[0], sig[1]
        # weighted partials return straight home in the wire dtype; the only
        # compute on this half is the per-token accumulate
        wire = m_loc * d_model * fb
        flops = 2.0 * m_loc * d_model
    else:
        raise ValueError(f"no cost model for kind {kind!r}")
    return float(wire), float(flops)


def realized_tile(
    kind: str, sig: Tuple[int, ...], world: int, cand: Candidate
) -> Tuple[int, int, int]:
    """The blocking a candidate's compute tile actually executes as.

    The DEFAULT_TILE sentinel realizes as what the consumers run when
    untuned — for the GEMM kinds whole-chunk rows and contraction with
    128-wide output columns; for attention the whole-chunk online-softmax
    update; for MoE the whole per-expert grouped GEMM — NOT as a literal
    128^3 decomposition, so the default is never charged per-tile costs its
    execution does not incur (a tuned tile must beat the real thing).
    Non-default tiles clamp like everywhere else.
    """
    m, n, k = _tile_dims(kind, tuple(sig), world, max(1, cand.num_channels))
    if tuple(cand.comp_tile) == DEFAULT_TILE:
        if kind in GEMM_TILE_KINDS:
            return m, largest_divisor(n, 128), k
        return m, n, k  # native: one whole-chunk consumer block
    return resolve_tile(tuple(cand.comp_tile), m, n, k)


def _spill_bytes(tm: int, tn: int, tk: int, acc_bytes: int) -> float:
    """Extra HBM round-trip a blocking pays when it cannot stay VMEM-resident.

    A blocking whose working set fits the probed budget keeps its
    accumulator (GEMM) or score tile (attention) on-chip; one that does not
    spills it to HBM — write + read-back.  This is the term a tuned
    flash-style tile exists to remove, and it is what lets a non-default
    attention/MoE tile beat the whole-chunk native blocking on shapes whose
    chunk no longer fits.
    """
    from repro import backend

    if tile_footprint_bytes((tm, tn, tk), _TILE_BYTES, acc_bytes) <= backend.vmem_budget_bytes():
        return 0.0
    return 2.0 * tm * tn * acc_bytes


def comp_step_time(kind: str, sig: Tuple[int, ...], world: int, cand: Candidate) -> float:
    """Per-step compute time for one candidate, tile blocking included.

    Every tunable kind prices its realized (tm, tn, tk) blocking (see
    :func:`realized_tile`) with a per-tile roofline: the GEMM kinds as in
    the module docstring; attention as a per-tile softmax+MXU roofline
    (score-tile MXU utilization, a VPU softmax term, score-spill bytes);
    MoE as per-expert tile occupancy (last-row-tile padding waste over the
    capacity-sized expert groups).  All terms are accum-dtype-free so AG
    flows keep the deterministic f32 tie-break.
    """
    _, flops = step_terms(kind, sig, world, cand.accum_dtype)
    sig = tuple(sig)
    nch = max(1, cand.num_channels)
    dims = _tile_dims(kind, sig, world, nch)
    if dims is None:
        return flops / HW["peak_flops"]

    from repro import backend

    m, n, k = dims
    tm, tn, tk = realized_tile(kind, sig, world, cand)
    mxu = backend.mxu_dim()

    if kind in GEMM_TILE_KINDS:
        eff = (min(tm, mxu) / mxu) * (min(tn, mxu) / mxu)
        lead = max(1, abs(int(sig[0])))  # decode sigs negate the lead
        # all C channels run their blocks each step
        blocks_mn = (m // tm) * (n // tn) * nch * lead
        n_tiles = blocks_mn * (k // tk)
        # output tiles are written in the activation dtype — the MXU
        # accumulates f32 natively, so the wire dtype must not bias the
        # compute term (it already prices the wire for travelling partials)
        bytes_touched = (n_tiles * (tm * tk + tk * tn) + blocks_mn * tm * tn) * _TILE_BYTES
        bytes_touched += blocks_mn * _spill_bytes(tm, tn, tk, 4)
        t_flops = flops / (HW["peak_flops"] * eff)
        t_mem = bytes_touched / HW["hbm_bw"]
        return max(t_flops, t_mem) + BETA_TILE_S * n_tiles

    if kind == "ag_attention":
        b, h, _hkv, s_loc, d = sig
        # (tm, tk) block the (block_q, block_kv) score tile; tn clamps to the
        # head dim.  Per step each channel consumes one s_sub KV chunk for
        # every (batch, head).
        blocks = b * h * (m // tm) * (k // tk) * nch
        n_tiles = blocks * max(1, n // tn)
        eff = (min(tm, mxu) / mxu) * (min(tk, mxu) / mxu)  # QK^T -> (tm, tk)
        t_flops = flops / (HW["peak_flops"] * eff)
        # softmax is VPU work over every score element, fp32 regardless of
        # the wire dtype (the compute term must stay accum-dtype-free)
        scores = float(b) * h * m * k * nch
        t_soft = _SOFTMAX_OPS * scores / (HW["peak_flops"] * _VPU_FRACTION)
        # per block: Q tile + K and V tiles in, one accumulator update out;
        # a whole-chunk score tile that cannot stay resident spills fp32
        bytes_touched = blocks * (2.0 * tm * n + 2.0 * tk * n) * _TILE_BYTES
        bytes_touched += blocks * _spill_bytes(tm, tk, n, _SCORE_BYTES)
        t_mem = bytes_touched / HW["hbm_bw"]
        return max(t_flops + t_soft, t_mem) + BETA_TILE_S * n_tiles

    # ag_moe / a2a_dispatch: per-expert grouped GEMMs over capacity-sized
    # token groups
    m_loc, d_model, top_k, e_loc, _d_exp = sig[:5]
    e_total = max(1, e_loc * world)
    m_sub = max(1, m_loc // nch)
    # per-expert row count: the capacity proxy (moe_overlap._capacity with
    # factor 1 — rounded up to the 8-row sublane)
    rows = max(8, ((m_sub * max(1, top_k) + e_total - 1) // e_total + 7) // 8 * 8)
    if len(sig) > 6:  # the signature's capacity axis caps the expert groups
        rows = min(rows, int(sig[6]))
    tm_e = min(tm, rows)
    row_tiles = -(-rows // tm_e)
    occupancy = rows / float(row_tiles * tm_e)  # last-row-tile padding waste
    blocks = e_loc * nch * row_tiles * max(1, n // tn)
    n_tiles = blocks * max(1, k // tk) * 2  # gate+up AND down projections
    eff = (min(tm_e, mxu) / mxu) * (min(tn, mxu) / mxu) * occupancy
    t_flops = flops / (HW["peak_flops"] * eff)
    bytes_touched = (n_tiles * (tm_e * tk + tk * tn) + blocks * tm_e * tn) * _TILE_BYTES
    bytes_touched += blocks * _spill_bytes(tm_e, tn, tk, 4)
    t_mem = bytes_touched / HW["hbm_bw"]
    return max(t_flops, t_mem) + BETA_TILE_S * n_tiles


def predict_cost(kind: str, sig: Tuple[int, ...], world: int, cand: Candidate) -> float:
    """Predicted makespan (seconds) of one candidate; lower is better."""
    wire, _ = step_terms(kind, sig, world, cand.accum_dtype, cand.flow)
    steps = world

    # per-link effective bytes for this tile order
    dirs = 2.0 if (cand.order == "bidir_ring" and cand.num_channels >= 2) else 1.0
    hops = _order_hops(cand.order, world)

    t_comm = wire * hops / (HW["link_bw"] * dirs)
    t_comp = comp_step_time(kind, sig, world, cand)

    steady = (steps - 1) * max(t_comm, t_comp)
    fill = (t_comm + t_comp) / cand.num_channels
    launch = ALPHA_S * cand.num_channels * steps
    return steady + fill + launch


def _fill_drain_time(kind: str, sig: Tuple[int, ...], world: int, cand: Candidate) -> float:
    """The pipeline fill/drain term of one op's makespan (same math as
    ``predict_cost``'s ``fill``)."""
    wire, _ = step_terms(kind, sig, world, cand.accum_dtype, cand.flow)
    dirs = 2.0 if (cand.order == "bidir_ring" and cand.num_channels >= 2) else 1.0
    hops = _order_hops(cand.order, world)
    t_comm = wire * hops / (HW["link_bw"] * dirs)
    t_comp = comp_step_time(kind, sig, world, cand)
    return (t_comm + t_comp) / cand.num_channels


def seam_saving(sig: Tuple[int, ...], world: int, cand: Candidate) -> float:
    """Modeled time the fused seam removes vs. the unfused pair (seconds).

    Unfused, the RS pipeline's drain and the AG pipeline's fill serialize at
    the operator-collective boundary — the exposed-collective seam.  Fused,
    the home segments hand off rank-locally and the two pipelines schedule
    against each other, so the shorter of the two fill/drain tails hides
    inside the longer one:

        saving = min(fill_drain(rs), fill_drain(ag))

    Strictly positive for every candidate, so a schedule-compatible fused
    seam is never modeled slower than the same candidate unfused.
    """
    sig_rs, sig_ag = seq_sigs(tuple(sig), world)
    return min(
        _fill_drain_time("matmul_rs", sig_rs, world, cand),
        _fill_drain_time("ag_matmul", sig_ag, world, cand),
    )


def predict_seq_cost(
    sig: Tuple[int, ...], world: int, cand: Candidate, *, fused: bool = True
) -> float:
    """Predicted makespan (seconds) of the RS -> AG seam under one shared
    candidate: the two per-op makespans, minus the seam overlap when fused."""
    sig_rs, sig_ag = seq_sigs(tuple(sig), world)
    total = predict_cost("matmul_rs", sig_rs, world, cand) + predict_cost(
        "ag_matmul", sig_ag, world, cand
    )
    if fused:
        total -= seam_saving(sig, world, cand)
    return total


def a2a_saving(sig: Tuple[int, ...], world: int, cand: Candidate) -> float:
    """Modeled time the overlapped dispatch/combine pipeline removes vs.
    running the two exchanges back to back (seconds).

    In the overlapped executor the combine of step ``s`` flies while the
    dispatch of step ``s + 1`` is in flight (``core/overlap.run_a2a_seq``),
    so — exactly like :func:`seam_saving` — the shorter half's fill/drain
    tail hides inside the longer one.  Strictly positive, so a legal
    overlapped plan is never modeled slower than the same candidate split.
    """
    d_sig, c_sig = a2a_sigs(tuple(sig), world)
    return min(
        _fill_drain_time("a2a_dispatch", d_sig, world, cand),
        _fill_drain_time("combine_rs", c_sig, world, cand),
    )


def predict_a2a_cost(
    sig: Tuple[int, ...], world: int, cand: Candidate, *, fused: bool = True
) -> float:
    """Predicted makespan (seconds) of the MoE dispatch -> combine exchange
    under one shared candidate: the two per-kind makespans, minus the
    overlap credit when fused.  ``fused=False`` models the unfused
    ``a2a_moe_baseline`` style split (dispatch fully lands, then combine)."""
    d_sig, c_sig = a2a_sigs(tuple(sig), world)
    total = predict_cost("a2a_dispatch", d_sig, world, cand) + predict_cost(
        "combine_rs", c_sig, world, cand
    )
    if fused:
        total -= a2a_saving(sig, world, cand)
    return total


def explain(kind: str, sig: Tuple[int, ...], world: int, cand: Candidate) -> Dict[str, float]:
    """Itemized terms for reports/benchmarks (same math as predict_cost)."""
    wire, flops = step_terms(kind, sig, world, cand.accum_dtype, cand.flow)
    ext = chunk_extent(kind, sig)
    out = {
        "wire_bytes_per_step": wire,
        "flops_per_step": flops,
        "chunk_extent": float(ext),
        "comp_step_s": comp_step_time(kind, sig, world, cand),
        "predicted_s": predict_cost(kind, sig, world, cand),
    }
    if _tile_dims(kind, tuple(sig), world, max(1, cand.num_channels)) is not None:
        out["realized_tile"] = realized_tile(kind, sig, world, cand)
    return out
