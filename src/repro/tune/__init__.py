"""Design-space autotuner over the compiled frontend (ROADMAP "Next").

The paper's core argument (§3.1) is that communication and computation tune
*independently*: the best ``(tile order, channel count f_C, accum dtype —
and, under a quant-widened space, the wire dtype)``
on the comm half and the best ``(tm, tn, tk)`` consumer tile on the compute
half both change per shape and per mesh.  PR 2 made that space uniformly
sweepable through ``compile_overlap``; this package searches it:

    result = autotune("ag_matmul", signature=(1, 64, 32, 32), mesh=mesh)
    fn = compile_overlap("ag_matmul", result.channel)

or transparently:

    compile_overlap("ag_matmul", channel="auto")      # comm half, per call shape
    compile_overlap("ag_matmul", channel="auto", comp="auto")   # joint search
    ParallelContext(mesh=mesh, tune=True)             # every op resolves joint
    nn.ffn.apply_seq(params, x, pc, cfg, tune=True)   # per-layer opt-in

``DEFAULT_SPACE`` sweeps the comm half only; ``JOINT_SPACE`` adds the
pruned compute-tile lattice (``tune/candidates.py``) — shape-, VMEM- and
MXU-alignment-constrained via the ``repro.backend`` hardware probes;
``QUANT_SPACE`` additionally opens the wire-dtype (flow) axis for the
``QUANT_WIRE_KINDS`` (``compile_overlap(..., quant="auto")``).

Rankers
-------
``ranker="measure"``  times candidates through ``compile_overlap`` under
                      shard_map on the target mesh (``tune/measure.py``:
                      AOT-split compilation, (median, iqr) scores) — pruned
                      by the successive-halving early-exit sweep in
                      ``tune/sweep.py`` (``REPRO_TUNE_SWEEP*`` knobs; the
                      v3 cache record keeps the pruning ledger);
``ranker="model"``    ranks with the analytic bytes-on-wire vs. per-tile-FLOPs
                      cost model (``tune/cost.py``);
``ranker="auto"``     (default) measures on a real TPU target, models
                      otherwise — emulated-CPU wall time is not a perf signal
                      (ROADMAP), and model ranking is pure host arithmetic so
                      it is also safe *inside* a trace, where timing is
                      impossible.  ``REPRO_TUNE_RANKER`` overrides globally.

Both rankers walk ONE candidate enumerator (``tune/candidates.py``) and
share ONE cache schema (``tune/cache.py``): results persist per mesh
fingerprint (mesh shape + axis names + device kind + jax version + backend
target) under ``~/.cache/repro-tune`` (``REPRO_TUNE_CACHE`` overrides), and
a fingerprint hit never re-measures — except that an *explicit*
``ranker="measure"`` request upgrades a model-ranked record in place, so
pre-warming the cache with measured winners actually takes effect.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Any, Dict, Optional, Sequence, Tuple

import jax

from repro.core.channels import BlockChannel
from repro.tune import cache as _cache
from repro.tune import cost as _cost
from repro.tune import measure as _measure
from repro.tune import sweep as _sweep
from repro.tune.candidates import (
    A2A_SEQ_KIND,
    COMP_TILE_LATTICE,
    DEFAULT_SPACE,
    GEMM_TILE_KINDS,
    JOINT_SPACE,
    MOE_SIG_KINDS,
    QUANT_SPACE,
    QUANT_WIRE_KINDS,
    SEQ_KIND,
    Candidate,
    Space,
    TUNABLE_KINDS,
    a2a_sigs,
    chunk_extent,
    comp_tile_candidates,
    enumerate_a2a_candidates,
    enumerate_candidates,
    enumerate_seq_candidates,
    seq_sigs,
    signature,
)

__all__ = [
    "autotune",
    "resolve_channel",
    "resolve_seq",
    "resolve_a2a",
    "TuneResult",
    "Space",
    "Candidate",
    "DEFAULT_SPACE",
    "JOINT_SPACE",
    "QUANT_SPACE",
    "COMP_TILE_LATTICE",
    "GEMM_TILE_KINDS",
    "QUANT_WIRE_KINDS",
    "TUNABLE_KINDS",
    "SEQ_KIND",
    "A2A_SEQ_KIND",
    "MOE_SIG_KINDS",
    "RANKERS",
    "CACHE_SCHEMA",
    "signature",
    "enumerate_candidates",
    "enumerate_seq_candidates",
    "enumerate_a2a_candidates",
    "seq_sigs",
    "a2a_sigs",
    "comp_tile_candidates",
    "chunk_extent",
]

RANKERS = ("auto", "measure", "model")
_ENV_RANKER = "REPRO_TUNE_RANKER"

# record-format version.  v1 (PR 3) records are comm-only (no ``comp_tile``);
# v2 (PR 4) records predate the measured-sweep stats and the attention/MoE
# compute-tile axes; v3 records predate the wire-dtype (flow) axis, so their
# winners were chosen from a space that could never trade wire bytes for
# quantization error.  Loading any older (or malformed) record re-tunes — a
# cheap model ranking — instead of guessing; it never crashes and never
# half-applies.
CACHE_SCHEMA = 4


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Winner of one search (or one cache hit)."""

    kind: str
    signature: Tuple[int, ...]
    candidate: Candidate
    channel: BlockChannel
    ranker: str  # ranker that PRODUCED the record
    score: float  # predicted seconds or measured median us
    cache_hit: bool
    fingerprint: Dict[str, Any]
    considered: int  # candidates enumerated (0 on a hit)
    score_iqr: float = 0.0  # measured noise estimate (us); 0.0 for the model
    sweep: Optional[Dict[str, Any]] = None  # pruning ledger (measured sweeps)


def _entry_key(kind: str, axis: str, world: int, sig: Sequence[int], space: Space) -> str:
    # axis + world are part of the key: one multi-axis mesh fingerprint can
    # host tunings along different axes with different ring sizes
    shape = ",".join(str(int(s)) for s in sig)
    return f"{kind}|axis={axis}|world={int(world)}|sig={shape}|space={space.digest()}"


def _tracing() -> bool:
    """Best-effort: are we inside a jax trace (timing would be meaningless)?"""
    try:
        probe = getattr(jax.core, "trace_state_clean", None)
        if probe is None:
            from jax._src import core as _src_core  # probed, version-moved

            probe = getattr(_src_core, "trace_state_clean", None)
        return not probe() if probe is not None else False
    except Exception:
        return False


def _wants_measure_upgrade(rec: Dict[str, Any], ranker: Optional[str], mesh) -> bool:
    """Should this hit re-rank?  An *explicit* ``ranker="measure"`` request
    (argument or ``REPRO_TUNE_RANKER``) landing on a model-ranked record —
    exactly the pre-warm flow the fallback warning recommends — must measure
    and overwrite, provided measurement is actually possible here.  Measured
    records satisfy every request; ``"auto"`` never forces a re-rank.
    """
    requested = ranker or os.environ.get(_ENV_RANKER)
    return (
        requested == "measure"
        and rec.get("ranker") == "model"
        and mesh is not None
        and not _tracing()
    )


def _parse_record(rec: Any) -> Optional[Dict[str, Any]]:
    """Validated view of a cache record, or None when it must re-tune.

    Every way a record can be unusable degrades identically — to a re-tune:
    a v1/v2 record from an older schema (whose winner was picked from a
    smaller joint space, pre sweep-stats), or a malformed record (junk file,
    hand-edited entry, torn write).  Nothing here may raise.
    """
    try:
        if int(rec.get("schema", 1)) != CACHE_SCHEMA:
            return None
        flow = rec.get("flow")
        cand = Candidate(
            order=rec["order"],
            num_channels=int(rec["num_channels"]),
            accum_dtype=rec["accum_dtype"],
            comp_tile=tuple(int(t) for t in rec["comp_tile"]),
            flow=None if flow is None else str(flow),
        )
        cand.channel("_probe")  # spec construction validates order/dtype/tile
        sweep = rec.get("sweep")
        return {
            "candidate": cand,
            "ranker": str(rec["ranker"]),
            "score": float(rec["score"]),
            "score_iqr": float(rec.get("score_iqr_us", 0.0)),
            "sweep": dict(sweep) if isinstance(sweep, dict) else None,
        }
    except (AttributeError, KeyError, TypeError, ValueError):
        return None


def _resolve_ranker(ranker: Optional[str], mesh) -> str:
    from repro import backend

    choice = ranker or os.environ.get(_ENV_RANKER) or "auto"
    if choice not in RANKERS:
        raise ValueError(f"unknown ranker {choice!r}; one of {RANKERS}")
    if choice == "auto":
        choice = "measure" if backend.target() == "tpu" else "model"
    if choice == "measure" and (mesh is None or _tracing()):
        warnings.warn(
            "repro.tune: measured ranking needs a mesh outside a trace; "
            "falling back to the analytic cost model (pre-tune with "
            "repro.tune.autotune(..., ranker='measure') to warm the cache)",
            stacklevel=3,
        )
        choice = "model"
    return choice


def autotune(
    kind: str,
    *,
    signature: Sequence[int],
    mesh=None,
    axis: str = "model",
    world: Optional[int] = None,
    base: Optional[BlockChannel] = None,
    ranker: Optional[str] = None,
    space: Space = DEFAULT_SPACE,
    cache_dir: Optional[str] = None,
    force: bool = False,
    repeats: int = 3,
    warmup: int = 1,
) -> TuneResult:
    """Find (or recall) the best design point for ``(kind, signature)``.

    ``signature`` is the canonical per-shard shape tuple (see
    :func:`repro.tune.signature`).  With ``mesh`` the fingerprint covers the
    whole topology; without one, ``world`` (the axis size) must be given.
    ``force=True`` re-ranks even on a cache hit (and overwrites the entry).
    """
    sig = tuple(int(s) for s in signature)
    if mesh is not None:
        world = int(mesh.shape[axis])
    if world is None:
        raise ValueError("autotune needs a mesh or an explicit world size")
    fp = _cache.mesh_fingerprint(mesh, axis=axis, world=world)
    key = _entry_key(kind, axis, world, sig, space)

    if not force:
        rec = _cache.load_entry(fp, key, directory=cache_dir)
        if rec is not None:
            rec = _parse_record(rec)  # old schema / malformed -> None (re-tune)
        if rec is not None and _wants_measure_upgrade(rec, ranker, mesh):
            rec = None  # explicit measure request upgrades a model-ranked entry
        if rec is not None:
            cand = rec["candidate"]
            return TuneResult(
                kind=kind,
                signature=sig,
                candidate=cand,
                channel=cand.channel(axis, base),
                ranker=rec["ranker"],
                score=rec["score"],
                cache_hit=True,
                fingerprint=fp,
                considered=0,
                score_iqr=rec["score_iqr"],
                sweep=rec["sweep"],
            )

    use = _resolve_ranker(ranker, mesh)
    cands = enumerate_candidates(
        kind, extent=chunk_extent(kind, sig), space=space, sig=sig, world=world
    )
    best_iqr = 0.0
    sweep_stats: Optional[Dict[str, Any]] = None
    if use == "measure":
        # one CaseTimer per search: operands are synthesized once and shared
        # by every candidate; compile time is AOT-split out of every score
        case = _measure.CaseTimer(kind, mesh, axis, sig)

        def timer(cand, *, repeats=repeats, warmup=warmup):
            return case.time(cand.channel(axis, base), repeats=repeats, warmup=warmup)

        sw = _sweep.measured_sweep(kind, sig, world, cands, timer, repeats=repeats, warmup=warmup)
        best, best_score, best_iqr = sw.winner, sw.median_us, sw.iqr_us
        sweep_stats = sw.stats
    else:
        best, best_score = None, float("inf")
        for cand in cands:
            score = _cost.predict_cost(kind, sig, world, cand)
            if score < best_score:  # strict: ties keep enumeration order
                best, best_score = cand, score
    assert best is not None

    record = {
        "schema": CACHE_SCHEMA,
        "kind": kind,
        "signature": list(sig),
        "world": world,
        "order": best.order,
        "num_channels": best.num_channels,
        "accum_dtype": best.accum_dtype,
        "comp_tile": list(best.comp_tile),
        "flow": best.flow,
        "ranker": use,
        "score": best_score,
        "score_unit": "us_measured" if use == "measure" else "s_predicted",
        "considered": len(cands),
    }
    if use == "measure":
        record["score_iqr_us"] = best_iqr
        record["sweep"] = sweep_stats
    _cache.store_entry(fp, key, record, directory=cache_dir)
    return TuneResult(
        kind=kind,
        signature=sig,
        candidate=best,
        channel=best.channel(axis, base),
        ranker=use,
        score=best_score,
        cache_hit=False,
        fingerprint=fp,
        considered=len(cands),
        score_iqr=best_iqr,
        sweep=sweep_stats,
    )


def resolve_seq(
    *,
    shapes: Optional[Sequence[Tuple[int, ...]]] = None,
    sig: Optional[Sequence[int]] = None,
    mesh=None,
    axis: str = "model",
    world: Optional[int] = None,
    base: Optional[BlockChannel] = None,
    ranker: Optional[str] = None,
    space: Space = DEFAULT_SPACE,
) -> Tuple[bool, BlockChannel, BlockChannel]:
    """Seam-aware resolution for ``compile_overlap([...], channel="auto")``.

    Returns ``(fused, ch_rs, ch_ag)``: whether to run the fused seam, and the
    channel for each half.  The fused plan is priced over the shared-channel
    candidates (``enumerate_seq_candidates``) with the eliminated
    exposed-collective time credited (``cost.seam_saving``); the unfused plan
    takes each half's own autotuned winner and prices the pair on the SAME
    modeled scale (``cost.predict_cost`` — never mixing measured us with
    modeled seconds).  Whenever a shared-channel candidate exists, the fused
    seam with *those* channels costs no more than the same channels unfused —
    unfused only wins here when the halves' independent winners diverge by
    more than the seam saving (e.g. extents that clamp a good shared C away).
    Pure host-side arithmetic plus cache-backed per-op lookups: trace-safe.
    """
    if sig is None:
        if shapes is None:
            raise ValueError("resolve_seq needs shapes or a signature")
        sig = signature(SEQ_KIND, [tuple(s) for s in shapes])
    sig = tuple(int(s) for s in sig)
    if world is None and mesh is not None:
        world = int(mesh.shape[axis])
    if world is None:
        raise ValueError("resolve_seq needs a mesh or an explicit world size")

    best_f, best_f_score = None, float("inf")
    for cand in enumerate_seq_candidates(sig=sig, world=world, space=space):
        score = _cost.predict_seq_cost(sig, world, cand, fused=True)
        if score < best_f_score:  # strict: ties keep enumeration order
            best_f, best_f_score = cand, score

    sig_rs, sig_ag = seq_sigs(sig, world)
    res_rs = autotune(
        "matmul_rs", signature=sig_rs, mesh=mesh, axis=axis, world=world,
        base=base, ranker=ranker, space=space,
    )
    res_ag = autotune(
        "ag_matmul", signature=sig_ag, mesh=mesh, axis=axis, world=world,
        base=base, ranker=ranker, space=space,
    )
    unfused_score = _cost.predict_cost(
        "matmul_rs", sig_rs, world, res_rs.candidate
    ) + _cost.predict_cost("ag_matmul", sig_ag, world, res_ag.candidate)

    if best_f is not None and best_f_score <= unfused_score:
        ch = best_f.channel(axis, base)
        return True, ch, ch
    return False, res_rs.channel, res_ag.channel


def resolve_a2a(
    *,
    shapes: Optional[Sequence[Tuple[int, ...]]] = None,
    sig: Optional[Sequence[int]] = None,
    mesh=None,
    axis: str = "model",
    world: Optional[int] = None,
    base: Optional[BlockChannel] = None,
    ranker: Optional[str] = None,
    space: Space = DEFAULT_SPACE,
    capacity_factor: Optional[float] = None,
    imbalance: Optional[float] = None,
) -> Tuple[bool, BlockChannel, BlockChannel]:
    """Joint resolution for ``compile_overlap(["a2a_dispatch", "combine_rs"],
    channel="auto")``.

    Returns ``(fused, ch_dispatch, ch_combine)``: whether to run the
    overlapped expert-parallel pipeline, and the channel for each half.
    The overlapped program is priced over the shared-channel candidates
    (``enumerate_a2a_candidates`` — every point already model-checked as a
    full dispatch -> GEMM -> combine protocol) with the pipeline overlap
    credited (``cost.a2a_saving``); the split program prices the same
    exchange without the credit, which is what ``a2a_moe_baseline`` (bulk
    all_gather + psum_scatter) degrades to.  Because the credit is strictly
    positive, unfused only wins when NO legal shared-channel candidate
    exists (e.g. a world the order cannot schedule) — the baseline then
    keeps numerical parity while the verifier keeps its guarantees.

    ``capacity_factor``/``imbalance`` fold into the signature's quantized
    MoE workload axes (``signature(..., imbalance=, capacity=)``) so tight
    capacities and hot experts rank their own winners.  Pure host-side
    model arithmetic (the a2a halves have no single-op measured path), so
    this is trace-safe like :func:`resolve_seq`; ``ranker`` is accepted for
    signature symmetry and reserved for a future measured path.
    """
    del ranker  # model-ranked (see docstring)
    if world is None and mesh is not None:
        world = int(mesh.shape[axis])
    if world is None:
        raise ValueError("resolve_a2a needs a mesh or an explicit world size")
    if sig is None:
        if shapes is None:
            raise ValueError("resolve_a2a needs shapes or a signature")
        shapes = [tuple(s) for s in shapes]
        cap_rows = None
        if capacity_factor is not None:
            from repro.core.moe_overlap import _capacity

            m_loc, top_k, e_loc = shapes[0][-2], shapes[1][-1], shapes[3][0]
            cap_rows = _capacity(
                int(m_loc), int(top_k), max(1, int(e_loc) * world), float(capacity_factor)
            )
        sig = signature(A2A_SEQ_KIND, shapes, imbalance=imbalance, capacity=cap_rows)
    sig = tuple(int(s) for s in sig)

    best, best_score = None, float("inf")
    for cand in enumerate_a2a_candidates(sig=sig, world=world, space=space):
        score = _cost.predict_a2a_cost(sig, world, cand, fused=True)
        if score < best_score:  # strict: ties keep enumeration order
            best, best_score = cand, score

    if best is None:
        ch = base or BlockChannel(axis=axis)
        ch = ch.with_(axis=axis)
        return False, ch, ch
    ch = best.channel(axis, base)
    return True, ch, ch


def resolve_channel(
    kind: str,
    *,
    shapes: Optional[Sequence[Tuple[int, ...]]] = None,
    sig: Optional[Sequence[int]] = None,
    mesh=None,
    axis: str = "model",
    world: Optional[int] = None,
    base: Optional[BlockChannel] = None,
    ranker: Optional[str] = None,
    space: Space = DEFAULT_SPACE,
) -> BlockChannel:
    """Tuned ``BlockChannel`` for an op call — the transparent entry point.

    Cache hits and model ranking are pure host-side work, so this is safe at
    trace time (which is where ``compile_overlap(kind, channel="auto")`` and
    ``ParallelContext(tune=True)`` land).  Non-tuned fields (comm resource,
    mode, tiles) are inherited from ``base``.
    """
    if sig is None:
        if shapes is None:
            raise ValueError("resolve_channel needs shapes or a signature")
        sig = signature(kind, [tuple(s) for s in shapes])
    res = autotune(
        kind,
        signature=sig,
        mesh=mesh,
        axis=axis,
        world=world,
        base=base,
        ranker=ranker,
        space=space,
    )
    return res.channel
