"""Candidate enumeration — ONE design-space walk for both rankers.

The tunable space is the full decoupled ``CommSpec x CompSpec`` surface the
plan layer sweeps (paper §3.1): tile order x channel count (f_C) x flow
dtype on the comm half, and the (tm, tn, tk) consumer-kernel tile on the
compute half.  Both the measured ranker and the analytic cost model iterate
the tuple returned by :func:`enumerate_candidates`, and the cache entry key
hashes the same :class:`Space` — so "which points were considered" is part
of a result's identity and a narrowed sweep can never shadow a full one.

Enumeration is deterministic (nested loops over the Space's ordered fields)
and feasibility-aware:

  * each requested channel count is pushed through
    ``mapping.effective_channels`` against the kind's chunked extent;
  * each requested compute tile is pruned against the operand shapes
    (largest-divisor clamp, like the comm half), the dtype-dependent MXU
    packing multiples, and the per-tile VMEM footprint — all probed through
    ``repro.backend`` (``sublane_multiple``, ``lane_multiple``,
    ``vmem_budget_bytes``), so tiles enumerated on an emulated host stay
    valid on real TPUs;
  * candidates that clamp onto an already-seen effective point are dropped —
    the rankers never time the same realized schedule twice.

``DEFAULT_SPACE`` sweeps the comm half only (the compute tile stays the
backend-chosen default) — the PR-3 contract.  ``JOINT_SPACE`` adds the
pruned (tm, tn, tk) lattice; ``compile_overlap(..., comp="auto")`` and
``ParallelContext(tune=True)`` search it.  ``QUANT_SPACE`` additionally
opens the wire-dtype (flow) axis — ``QuantSpec`` per candidate, enumerated
only for the ``QUANT_WIRE_KINDS`` — which ``compile_overlap(...,
quant="auto")`` searches.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Optional, Sequence, Tuple

from repro.core.channels import BlockChannel, ORDERS
from repro.core.comp_tiles import DEFAULT_TILE, resolve_tile, tile_footprint_bytes
from repro.core.mapping import effective_channels
from repro.core.quant import WIRE_DTYPES

__all__ = [
    "Space",
    "Candidate",
    "DEFAULT_SPACE",
    "JOINT_SPACE",
    "QUANT_SPACE",
    "COMP_TILE_LATTICE",
    "QUANT_WIRE_KINDS",
    "GEMM_TILE_KINDS",
    "SEQ_KIND",
    "A2A_SEQ_KIND",
    "MOE_SIG_KINDS",
    "enumerate_candidates",
    "enumerate_seq_candidates",
    "enumerate_a2a_candidates",
    "comp_tile_candidates",
    "signature",
    "seq_sigs",
    "a2a_sigs",
    "chunk_extent",
]

TUNABLE_KINDS = ("ag_matmul", "matmul_rs", "ag_attention", "ag_moe")

# the fused RS -> AG layer seam (compile_overlap seq form); tuned through its
# shared-channel enumerator + seam-aware cost, not the single-op paths above
SEQ_KIND = "seq_rs_ag"

# the expert-parallel MoE dispatch -> combine exchange (compile_overlap
# ["a2a_dispatch", "combine_rs"]); tuned through enumerate_a2a_candidates +
# cost.predict_a2a_cost, resolved jointly by tune.resolve_a2a
A2A_SEQ_KIND = "seq_a2a_moe"

# kinds whose signature may carry the optional trailing MoE workload axes
# (expert imbalance, capacity) — see signature()
MOE_SIG_KINDS = ("ag_moe", A2A_SEQ_KIND)

# kinds whose consumer compute is a plain GEMM the (tm, tn, tk) tile blocks
# directly; the attention and MoE consumers interpret the same tile through
# their own dims (see _tile_dims) — attention maps (tm, tk) onto
# (block_q, block_kv), MoE onto the per-expert grouped GEMMs
GEMM_TILE_KINDS = ("ag_matmul", "matmul_rs")

# kinds whose wire dtype is tunable (Space.flows).  The MoE kinds are
# excluded: their state carries int32 routing tables alongside the float
# tiles, so a quantized wire buys proportionally less and the executor's
# error story (re-encode per hop on the combine) is worse — the flow axis
# collapses to the inherited wire there.
QUANT_WIRE_KINDS = ("ag_matmul", "matmul_rs", "ag_attention")

# requested (tm, tn, tk) lattice of the joint space, default tile FIRST so a
# cost-model tie breaks toward the backend-chosen blocking.  Points are
# pruned per shape signature before ranking (see comp_tile_candidates).
COMP_TILE_LATTICE = (DEFAULT_TILE,) + tuple(
    (tm, tn, tk)
    for tm in (64, 128, 256)
    for tn in (128, 256, 512)
    for tk in (128, 256, 512)
    if (tm, tn, tk) != DEFAULT_TILE
)

# fraction of the probed VMEM budget one compute tile's working set may
# occupy (the rest holds the comm staging buffers and double-buffering)
VMEM_TILE_FRACTION = 0.25

# wire/operand bytes per element for footprint pruning (activations travel
# bf16 on TPU — same convention as tune/cost.py)
_IN_BYTES = 2


@dataclasses.dataclass(frozen=True)
class Space:
    """The swept portion of the design space (ordered -> deterministic)."""

    orders: Tuple[str, ...] = ORDERS
    channel_counts: Tuple[int, ...] = (1, 2, 4)
    accum_dtypes: Tuple[str, ...] = ("float32", "bfloat16")
    comp_tiles: Tuple[Tuple[int, int, int], ...] = (DEFAULT_TILE,)
    # wire-dtype (flow) axis: None = inherit the channel's QuantSpec (for a
    # bare channel, the accum dtype — legacy pricing).  Kept (None,) by
    # default so an existing sweep's identity does not change; widened by
    # QUANT_SPACE / compile_overlap(..., quant="auto").  Only the
    # QUANT_WIRE_KINDS enumerate it.
    flows: Tuple[Optional[str], ...] = (None,)

    def __post_init__(self):
        for o in self.orders:
            if o not in ORDERS:
                raise ValueError(f"unknown order {o!r}; one of {ORDERS}")
        if any(c < 1 for c in self.channel_counts):
            raise ValueError(f"channel counts must be >= 1: {self.channel_counts}")
        for t in self.comp_tiles:
            if len(t) != 3 or any(int(d) < 1 for d in t):
                raise ValueError(f"comp tiles must be 3 positive ints, got {t}")
        for f in self.flows:
            if f is not None and f not in WIRE_DTYPES:
                raise ValueError(f"unknown flow dtype {f!r}; one of {WIRE_DTYPES}")

    def digest(self) -> str:
        blob = repr(
            (self.orders, self.channel_counts, self.accum_dtypes, self.comp_tiles, self.flows)
        )
        return hashlib.sha256(blob.encode()).hexdigest()[:8]


DEFAULT_SPACE = Space()
JOINT_SPACE = Space(comp_tiles=COMP_TILE_LATTICE)
# the joint space with the wire-dtype axis opened: None first so a cost-model
# tie breaks toward the un-quantized wire (exactness wins ties)
QUANT_SPACE = Space(comp_tiles=COMP_TILE_LATTICE, flows=(None, "int8"))


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One design point; ``num_channels`` and ``comp_tile`` are already the
    effective (feasibility-clamped) values."""

    order: str
    num_channels: int
    accum_dtype: str
    comp_tile: Tuple[int, int, int] = DEFAULT_TILE
    # tuned wire dtype; None = keep the base channel's QuantSpec untouched
    flow: Optional[str] = None

    def channel(self, axis: str, base: Optional[BlockChannel] = None) -> BlockChannel:
        """Realize as a BlockChannel, inheriting non-tuned fields of ``base``."""
        base = base or BlockChannel(axis=axis)
        kw = {}
        if self.flow is not None:
            kw["quant"] = dataclasses.replace(base.quant, wire_dtype=self.flow)
        return base.with_(
            axis=axis,
            num_channels=self.num_channels,
            comm=dataclasses.replace(base.comm, order=self.order),
            comp=dataclasses.replace(
                base.comp, accum_dtype=self.accum_dtype, tile=tuple(self.comp_tile)
            ),
            **kw,
        )

    def label(self) -> str:
        tag = f"{self.order}/C={self.num_channels}/{self.accum_dtype}"
        if tuple(self.comp_tile) != DEFAULT_TILE:
            tm, tn, tk = self.comp_tile
            tag += f"/tile={tm}x{tn}x{tk}"
        if self.flow is not None:
            tag += f"/wire={self.flow}"
        return tag


def _tile_dims(
    kind: str, sig: Sequence[int], world: Optional[int], nch: int
) -> Optional[Tuple[int, int, int]]:
    """Per-step per-channel consumer extents (m, n, k) the tile must divide.

    GEMM kinds: the per-step GEMM itself.  ``ag_attention``: queries x head
    dim x per-channel KV rows — tm is block_q, tk is block_kv, tn clamps to
    the head dim (the flash-attention blocking).  ``ag_moe``: per-expert
    token rows x fused gate+up width x d_model (the first expert GEMM; the
    down projection reuses the same blocking, clamped to its own extents).
    Unknown kinds/signatures return None (the lattice collapses to the
    sentinel).
    """
    nch = max(1, nch)
    if kind == "ag_matmul":
        _, m_loc, k, n_loc = sig
        return max(1, m_loc // nch), n_loc, k
    if kind == "matmul_rs":
        _, m_glob, k_loc, n = sig
        m = max(1, m_glob // world) if world else m_glob
        return m, max(1, n // nch), k_loc
    if kind == "ag_attention":
        _b, _h, _hkv, s_loc, d = sig
        return s_loc, d, max(1, s_loc // nch)
    if kind in ("ag_moe", "a2a_dispatch"):
        # sig[:5] — MoE signatures may carry trailing (imbalance, capacity)
        # workload axes the tile lattice never reads
        m_loc, d_model, _top_k, _e_loc, d_exp = sig[:5]
        return max(1, m_loc // nch), 2 * d_exp, d_model
    return None


def comp_tile_candidates(
    kind: str,
    sig: Optional[Sequence[int]],
    *,
    world: Optional[int] = None,
    nch: int = 1,
    accum_dtype: str = "float32",
    space: Space = DEFAULT_SPACE,
) -> Tuple[Tuple[int, int, int], ...]:
    """Feasible (tm, tn, tk) points for one comm-half design point.

    Each requested tile is clamped to divisors of the per-step GEMM extents
    (largest-divisor rule, mirroring ``effective_channels``), then dropped if
    a clamped dim is neither the full extent nor a multiple of the MXU
    packing multiple for its position (sublane for tm/tk, lane for tn), or
    if the tile's VMEM working set exceeds ``VMEM_TILE_FRACTION`` of the
    probed budget.  ``DEFAULT_TILE`` is a sentinel ("backend-chosen
    blocking", what every op runs with when untuned) and passes through
    unclamped and unpruned.  A single-tile space is an *explicit* request
    (``compile_overlap(..., comp=<CompSpec>)``): its point is clamped but
    never pruned — the kernels themselves clamp identically, so honoring it
    matches what an explicit channel would run.  Every tunable kind has a
    tile axis (the per-kind dims live in :func:`_tile_dims`); unknown kinds
    and signatures collapse to the sentinel.
    """
    import jax.numpy as jnp

    from repro import backend

    if sig is None:
        return (DEFAULT_TILE,)
    dims = _tile_dims(kind, tuple(int(s) for s in sig), world, nch)
    if dims is None:
        return (DEFAULT_TILE,)
    m, n, k = dims
    sub = backend.sublane_multiple(accum_dtype)
    lane = backend.lane_multiple()
    budget = int(backend.vmem_budget_bytes() * VMEM_TILE_FRACTION)
    acc_bytes = jnp.dtype(accum_dtype).itemsize

    def aligned(t: int, extent: int, mult: int) -> bool:
        return t == extent or t % mult == 0

    explicit = len(space.comp_tiles) == 1
    out, seen = [], set()
    for req in space.comp_tiles:
        req = tuple(int(d) for d in req)
        if req == DEFAULT_TILE:
            tile = DEFAULT_TILE  # sentinel: never clamped, never pruned
        else:
            tile = resolve_tile(req, m, n, k)
            tm, tn, tk = tile
            if not explicit:
                if not (aligned(tm, m, sub) and aligned(tn, n, lane) and aligned(tk, k, sub)):
                    continue
                if tile_footprint_bytes(tile, _IN_BYTES, acc_bytes) > budget:
                    continue
        if tile in seen:
            continue
        seen.add(tile)
        out.append(tile)
    if not out:
        # every lattice point was pruned (tiny budget / hostile extents):
        # fall back to the sentinel so the comm half stays tunable
        out.append(DEFAULT_TILE)
    return tuple(out)


def enumerate_candidates(
    kind: str,
    *,
    extent: Optional[int] = None,
    space: Space = DEFAULT_SPACE,
    sig: Optional[Sequence[int]] = None,
    world: Optional[int] = None,
) -> Tuple[Candidate, ...]:
    """Deterministic feasible design points for ``kind``.

    ``extent`` is the chunked extent ``num_channels`` must divide (see
    :func:`chunk_extent`); when known, infeasible counts are clamped through
    ``mapping.effective_channels`` and deduplicated.  ``sig``/``world``
    enable the compute-tile pruning (without them the comp axis passes
    through unclamped — extent-only callers keep the comm-only behavior).
    When ``world`` is known each (order, channels) point is also statically
    verified (``analysis.check_candidate``) so no measurement budget is ever
    spent on a schedule the executor would reject.
    """
    from repro.analysis import check_candidate

    if kind not in TUNABLE_KINDS:
        raise ValueError(f"kind {kind!r} is not tunable; one of {TUNABLE_KINDS}")
    flows = space.flows if kind in QUANT_WIRE_KINDS else (None,)
    out, seen = [], set()
    for order in space.orders:
        for req in space.channel_counts:
            if extent is not None:
                # warn=False: an enumerator probing feasibility is not a
                # surprise; the one-shot clamp warning stays armed for
                # genuine runtime fallbacks
                nch = effective_channels(extent, req, kind=kind, warn=False)
            else:
                nch = req
            if world is not None and check_candidate(kind, order, world, nch) is not None:
                continue  # provably illegal schedule: spend no budget on it
            for accum in space.accum_dtypes:
                if sig is not None:
                    tiles = comp_tile_candidates(
                        kind, sig, world=world, nch=nch, accum_dtype=accum, space=space
                    )
                else:
                    tiles = tuple(dict.fromkeys(tuple(int(d) for d in t) for t in space.comp_tiles))
                for tile in tiles:
                    for flow in flows:
                        cand = Candidate(
                            order=order, num_channels=nch, accum_dtype=accum,
                            comp_tile=tile, flow=flow,
                        )
                        if cand not in seen:
                            seen.add(cand)
                            out.append(cand)
    return tuple(out)


def _moe_axes(imbalance, capacity) -> Tuple[int, ...]:
    """Quantized optional MoE workload axes appended to a MoE signature.

    ``imbalance`` (hottest-expert load over the balanced mean, >= 1.0)
    quantizes to quarter-units so near-identical routing skews share one
    cache entry; ``capacity`` (per-expert row budget) quantizes up to the
    8-row sublane, matching ``moe_overlap._capacity``.  Capacity implies the
    imbalance slot (default balanced) so positions stay unambiguous:
    ``sig[5]`` is always imbalance, ``sig[6]`` always capacity.
    """
    if imbalance is None and capacity is None:
        return ()
    axes = (max(4, int(round(4.0 * float(1.0 if imbalance is None else imbalance)))),)
    if capacity is not None:
        axes += (max(8, -(-int(capacity) // 8) * 8),)
    return axes


def signature(kind: str, shapes: Sequence[Tuple[int, ...]],
              decode: bool = False, *, imbalance=None,
              capacity=None) -> Tuple[int, ...]:
    """Canonical shape signature from *per-shard* operand shapes.

    Takes the positional operand shapes exactly as the ``compile_overlap``
    ops receive them inside the manual region, and keeps only what changes
    the tuning landscape (leading batch dims collapse into one).

    ``decode=True`` marks a GEMM-kind signature as a *decode shape*: the
    lead element is negated, so tiny-M decode GEMMs key their own cache
    entries (and resolve their own joint winners) instead of aliasing the
    prefill entry for the same dims.  Cost-model consumers read
    ``abs(sig[0])``; the tile lattice never reads the lead at all.

    MoE kinds (``ag_moe`` and the ``seq_a2a_moe`` pair) may append the
    optional quantized workload axes ``imbalance``/``capacity`` (see
    :func:`_moe_axes`): routing skew and capacity both move the tuning
    landscape (a hot expert gates the grouped GEMM; a tight capacity bounds
    it), so they are part of a result's identity.  Every signature consumer
    slices the shape half with ``sig[:5]``.
    """
    if decode and kind not in GEMM_TILE_KINDS:
        raise ValueError(
            f"decode signatures are defined for the GEMM kinds "
            f"{GEMM_TILE_KINDS}, not {kind!r}")
    if (imbalance is not None or capacity is not None) and kind not in MOE_SIG_KINDS:
        raise ValueError(
            f"imbalance/capacity signature axes are defined for the MoE "
            f"kinds {MOE_SIG_KINDS}, not {kind!r}")

    def _lead(x):
        lead = math.prod(x[:-2]) if len(x) > 2 else 1
        return -lead if decode else lead

    if kind == SEQ_KIND:
        x, w1, w2 = shapes[0], shapes[1], shapes[2]
        # (lead, m_glob, k_loc, n_mid, n2_loc)
        return (_lead(x), x[-2], x[-1], w1[-1], w2[-1])
    if kind == "ag_matmul":
        x, w = shapes[0], shapes[1]
        return (_lead(x), x[-2], x[-1], w[-1])  # (lead, m_loc, k, n_loc)
    if kind == "matmul_rs":
        x, w = shapes[0], shapes[1]
        return (_lead(x), x[-2], x[-1], w[-1])  # (lead, m_glob, k_loc, n)
    if kind == "ag_attention":
        q, k = shapes[0], shapes[1]
        # s_loc comes from K: the KV shard is the ring extent — queries may
        # arrive gathered (the AG-Q + ring-KV layer form)
        return (q[0], q[1], k[1], k[2], q[3])  # (b, h, hkv, s_loc, d)
    if kind in MOE_SIG_KINDS:
        # ag_moe and the a2a pair take the same operand order
        # (x, topk_ids, topk_w, w_gu, w_down)
        x, ids, w_gu = shapes[0], shapes[1], shapes[3]
        # (m_loc, d_model, top_k, e_loc, d_expert) + optional workload axes
        base = (x[-2], x[-1], ids[-1], w_gu[0], w_gu[-1] // 2)
        return base + _moe_axes(imbalance, capacity)
    raise ValueError(f"kind {kind!r} is not tunable; one of {TUNABLE_KINDS}")


def seq_sigs(sig: Tuple[int, ...], world: int) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Split a seam signature into its constituent per-op signatures.

    The RS half sees the seam's inputs directly; the AG half consumes the
    reduce-scattered [m_glob / world, n_mid] home segment.
    """
    lead, m_glob, k_loc, n_mid, n2_loc = sig
    return (lead, m_glob, k_loc, n_mid), (lead, m_glob // world, n_mid, n2_loc)


def enumerate_seq_candidates(
    *,
    sig: Sequence[int],
    world: int,
    space: Space = DEFAULT_SPACE,
) -> Tuple[Candidate, ...]:
    """Shared-channel feasible design points for a fused RS -> AG seam.

    The seam handoff is per-channel, so only requests whose two chunked
    extents (RS: the n_mid columns, AG: the m_glob / world rows) clamp to the
    SAME effective count survive — anything else is what
    the ``compile_overlap`` seq form degrades to the unfused pair for.  Each
    surviving
    (order, C) point is statically verified as a seam
    (``analysis.check_seq_candidate``); compute tiles are pruned against the
    RS half's per-step GEMM (the dominant contraction at the seam).
    """
    from repro.analysis import check_seq_candidate

    sig = tuple(int(s) for s in sig)
    _lead, m_glob, _k_loc, n_mid, _n2_loc = sig
    if world < 1 or m_glob % world:
        return ()
    m_loc = m_glob // world
    sig_rs, _sig_ag = seq_sigs(sig, world)
    out, seen = [], set()
    for order in space.orders:
        for req in space.channel_counts:
            nch = effective_channels(n_mid, req, kind="matmul_rs", warn=False)
            if nch != effective_channels(m_loc, req, kind="ag_matmul", warn=False):
                continue
            if check_seq_candidate(order, world, nch) is not None:
                continue
            for accum in space.accum_dtypes:
                tiles = comp_tile_candidates(
                    "matmul_rs", sig_rs, world=world, nch=nch, accum_dtype=accum, space=space
                )
                for tile in tiles:
                    # both halves of the seam are QUANT_WIRE_KINDS, so the
                    # shared candidate enumerates the flow axis too
                    for flow in space.flows:
                        cand = Candidate(
                            order=order, num_channels=nch, accum_dtype=accum,
                            comp_tile=tile, flow=flow,
                        )
                        if cand not in seen:
                            seen.add(cand)
                            out.append(cand)
    return tuple(out)


def a2a_sigs(sig: Tuple[int, ...], world: int) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Split a ``seq_a2a_moe`` signature into its per-kind signatures.

    Unlike the RS -> AG seam, both halves of the MoE exchange see the SAME
    token extent — dispatch carries the tiles out, combine returns the
    weighted partials over the reverse of the same pairing — so both get the
    full signature (the combine's cost terms only read ``sig[:2]``).
    """
    sig = tuple(sig)
    return sig, sig


def enumerate_a2a_candidates(
    *,
    sig: Sequence[int],
    world: int,
    space: Space = DEFAULT_SPACE,
) -> Tuple[Candidate, ...]:
    """Shared-channel feasible design points for the MoE dispatch/combine
    exchange.

    Both halves chunk the same ``m_loc`` token extent, so (unlike the RS ->
    AG seam) every requested count clamps identically for the pair — there
    is no divergence case to degrade on.  Each surviving (order, C) point is
    statically verified as a full dispatch -> combine program
    (``analysis.check_a2a_candidate``: exchange legality, seam composition,
    protocol model check); compute tiles are pruned against the dispatch
    half's per-expert grouped GEMM.
    """
    from repro.analysis import check_a2a_candidate

    sig = tuple(int(s) for s in sig)
    m_loc = sig[0]
    if world < 1:
        return ()
    out, seen = [], set()
    for order in space.orders:
        for req in space.channel_counts:
            nch = effective_channels(m_loc, req, kind="a2a_dispatch", warn=False)
            if check_a2a_candidate(order, world, nch) is not None:
                continue
            for accum in space.accum_dtypes:
                tiles = comp_tile_candidates(
                    "a2a_dispatch", sig, world=world, nch=nch, accum_dtype=accum, space=space
                )
                for tile in tiles:
                    cand = Candidate(
                        order=order, num_channels=nch, accum_dtype=accum, comp_tile=tile
                    )
                    if cand not in seen:
                        seen.add(cand)
                        out.append(cand)
    return tuple(out)


def chunk_extent(kind: str, sig: Tuple[int, ...]) -> int:
    """The extent ``num_channels`` chunks for ``kind`` (what C must divide)."""
    if kind == "ag_matmul":
        return sig[1]  # m_loc rows of the local shard
    if kind == "matmul_rs":
        return sig[3]  # n columns of the partial
    if kind == "ag_attention":
        return sig[3]  # s_loc KV rows of the local shard
    if kind in ("ag_moe", "a2a_dispatch", "combine_rs"):
        return sig[0]  # m_loc token rows of the local chunk
    raise ValueError(f"kind {kind!r} is not tunable; one of {TUNABLE_KINDS}")
