"""Candidate enumeration — ONE design-space walk for both rankers.

The tunable space is exactly the decoupled ``CommSpec x CompSpec`` surface
the plan layer sweeps (paper §3.1): tile order x channel count (f_C) x flow
dtype.  Both the measured ranker and the analytic cost model iterate the
tuple returned by :func:`enumerate_candidates`, and the cache entry key
hashes the same :class:`Space` — so "which points were considered" is part
of a result's identity and a narrowed sweep can never shadow a full one.

Enumeration is deterministic (nested loops over the Space's ordered fields)
and feasibility-aware: each requested channel count is pushed through
``mapping.effective_channels`` against the kind's chunked extent, and
candidates that clamp onto an already-seen effective point are dropped —
the rankers never time the same realized schedule twice.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import warnings
from typing import Optional, Sequence, Tuple

from repro.core.channels import BlockChannel, ORDERS
from repro.core.mapping import effective_channels

__all__ = [
    "Space",
    "Candidate",
    "DEFAULT_SPACE",
    "enumerate_candidates",
    "signature",
    "chunk_extent",
]

TUNABLE_KINDS = ("ag_matmul", "matmul_rs", "ag_attention", "ag_moe")


@dataclasses.dataclass(frozen=True)
class Space:
    """The swept portion of the design space (ordered -> deterministic)."""

    orders: Tuple[str, ...] = ORDERS
    channel_counts: Tuple[int, ...] = (1, 2, 4)
    accum_dtypes: Tuple[str, ...] = ("float32", "bfloat16")

    def __post_init__(self):
        for o in self.orders:
            if o not in ORDERS:
                raise ValueError(f"unknown order {o!r}; one of {ORDERS}")
        if any(c < 1 for c in self.channel_counts):
            raise ValueError(f"channel counts must be >= 1: {self.channel_counts}")

    def digest(self) -> str:
        blob = repr((self.orders, self.channel_counts, self.accum_dtypes))
        return hashlib.sha256(blob.encode()).hexdigest()[:8]


DEFAULT_SPACE = Space()


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One design point; ``num_channels`` is already the effective divisor."""

    order: str
    num_channels: int
    accum_dtype: str

    def channel(self, axis: str, base: Optional[BlockChannel] = None) -> BlockChannel:
        """Realize as a BlockChannel, inheriting non-tuned fields of ``base``."""
        base = base or BlockChannel(axis=axis)
        return base.with_(
            axis=axis,
            num_channels=self.num_channels,
            comm=dataclasses.replace(base.comm, order=self.order),
            comp=dataclasses.replace(base.comp, accum_dtype=self.accum_dtype),
        )

    def label(self) -> str:
        return f"{self.order}/C={self.num_channels}/{self.accum_dtype}"


def enumerate_candidates(
    kind: str, *, extent: Optional[int] = None, space: Space = DEFAULT_SPACE
) -> Tuple[Candidate, ...]:
    """Deterministic feasible design points for ``kind``.

    ``extent`` is the chunked extent ``num_channels`` must divide (see
    :func:`chunk_extent`); when known, infeasible counts are clamped through
    ``mapping.effective_channels`` and deduplicated.
    """
    if kind not in TUNABLE_KINDS:
        raise ValueError(f"kind {kind!r} is not tunable; one of {TUNABLE_KINDS}")
    out, seen = [], set()
    for order in space.orders:
        for req in space.channel_counts:
            if extent is not None:
                with warnings.catch_warnings():
                    # the clamp warning is for silent runtime fallbacks; an
                    # enumerator probing feasibility is not a surprise
                    warnings.simplefilter("ignore")
                    nch = effective_channels(extent, req, kind=kind)
            else:
                nch = req
            for accum in space.accum_dtypes:
                cand = Candidate(order=order, num_channels=nch, accum_dtype=accum)
                if cand not in seen:
                    seen.add(cand)
                    out.append(cand)
    return tuple(out)


def signature(kind: str, shapes: Sequence[Tuple[int, ...]]) -> Tuple[int, ...]:
    """Canonical shape signature from *per-shard* operand shapes.

    Takes the positional operand shapes exactly as the ``compile_overlap``
    ops receive them inside the manual region, and keeps only what changes
    the tuning landscape (leading batch dims collapse into one).
    """
    if kind == "ag_matmul":
        x, w = shapes[0], shapes[1]
        lead = math.prod(x[:-2]) if len(x) > 2 else 1
        return (lead, x[-2], x[-1], w[-1])  # (lead, m_loc, k, n_loc)
    if kind == "matmul_rs":
        x, w = shapes[0], shapes[1]
        lead = math.prod(x[:-2]) if len(x) > 2 else 1
        return (lead, x[-2], x[-1], w[-1])  # (lead, m_glob, k_loc, n)
    if kind == "ag_attention":
        q, k = shapes[0], shapes[1]
        return (q[0], q[1], k[1], q[2], q[3])  # (b, h, hkv, s_loc, d)
    if kind == "ag_moe":
        x, ids, w_gu = shapes[0], shapes[1], shapes[3]
        # (m_loc, d_model, top_k, e_loc, d_expert)
        return (x[-2], x[-1], ids[-1], w_gu[0], w_gu[-1] // 2)
    raise ValueError(f"kind {kind!r} is not tunable; one of {TUNABLE_KINDS}")


def chunk_extent(kind: str, sig: Tuple[int, ...]) -> int:
    """The extent ``num_channels`` chunks for ``kind`` (what C must divide)."""
    if kind == "ag_matmul":
        return sig[1]  # m_loc rows of the local shard
    if kind == "matmul_rs":
        return sig[3]  # n columns of the partial
    if kind == "ag_attention":
        return sig[3]  # s_loc KV rows of the local shard
    if kind == "ag_moe":
        return sig[0]  # m_loc token rows of the local chunk
    raise ValueError(f"kind {kind!r} is not tunable; one of {TUNABLE_KINDS}")
