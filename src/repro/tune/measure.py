"""Measured ranker — time candidates through the real compiled frontend.

Each candidate is realized as a ``BlockChannel``, lowered with
``compile_overlap`` (the SAME entry point production code uses — no
tuning-only code path), wrapped in shard_map over the target mesh, and timed
on synthetic operands reconstructed from the shape signature.  The signature
is per-shard (what the ops see inside the manual region), so global operands
scale the sharded dim by the mesh's axis size.

Timing discipline (the Triton-distributed-style pitfalls, PAPERS.md):

  * compilation is split from measurement via the AOT path —
    ``jit(...).lower(*args).compile()`` — so jit compile time can never land
    inside a timed window and ONE compiled executable is reused across every
    warmup and repeat of a candidate;
  * ``warmup >= 1`` is enforced: even a pre-compiled executable's first call
    pays one-time costs (buffer donation bookkeeping, allocator warm-up)
    that are not steady state, so a cold call must never be scored;
  * :func:`time_fn` reports ``(median_us, iqr_us)`` — the interquartile
    range is the noise estimate the early-exit sweep (``tune/sweep.py``)
    reasons with when deciding whether an incumbent can still be beaten;
  * :class:`CaseTimer` synthesizes the operands ONCE per ``(kind, mesh,
    signature)`` and shares them across every candidate of a sweep, so
    candidate scores differ only by the design point, never by the data.

Wall time is only a meaningful perf signal on a real accelerator target —
on the emulated CPU target the analytic model (``tune/cost.py``) should rank
instead (``ranker="auto"`` does this; see ``repro.tune.autotune``).  The
measured path still *runs* everywhere, which is how tests exercise it.
"""
from __future__ import annotations

import time
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.channels import BlockChannel
from repro.tune.candidates import TUNABLE_KINDS

__all__ = ["build_case", "measure_channel", "time_fn", "CaseTimer"]


def _aot_compile(fn, *args):
    """Ahead-of-time compile ``fn`` for ``args`` when it has an AOT surface.

    Jitted callables go through ``lower(*args).compile()`` so the executable
    exists before the first timed window; plain callables (already-compiled
    executables, host functions in tests) are returned as-is.
    """
    lower = getattr(fn, "lower", None)
    if lower is None:
        return fn
    try:
        return lower(*args).compile()
    except Exception:  # version-moved AOT surface: fall back to the jit cache
        return fn


def time_fn(fn, *args, repeats: int = 3, warmup: int = 1) -> Tuple[float, float]:
    """``(median_us, iqr_us)`` wall time per call, compile time excluded.

    ``fn`` is AOT-compiled first (see :func:`_aot_compile`) and the ONE
    compiled executable serves every warmup and timed call.  ``warmup`` must
    be >= 1 so a cold first call can never be scored; ``iqr_us`` is the
    spread between the upper and lower quartile of the timed repeats (0.0
    for a single repeat) — the pruner's noise estimate.
    """
    if warmup < 1:
        raise ValueError(
            f"time_fn needs warmup >= 1 (a cold call must never be scored), got {warmup}"
        )
    if repeats < 1:
        raise ValueError(f"time_fn needs repeats >= 1, got {repeats}")
    compiled = _aot_compile(fn, *args)
    for _ in range(warmup):
        jax.block_until_ready(compiled(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    n = len(ts)
    median = ts[n // 2]
    iqr = ts[min(n - 1, (3 * n) // 4)] - ts[n // 4]
    return median * 1e6, iqr * 1e6


def build_case(kind: str, mesh, axis: str, sig: Tuple[int, ...]):
    """(builder, args): builder(channel) -> jitted global-operand callable.

    Shapes come from the per-shard signature (see ``candidates.signature``);
    operands are synthesized deterministically so repeated measurements of
    the same signature are comparable.
    """
    from repro.core.compiler import compile_overlap  # late: avoid import cycle

    world = int(mesh.shape[axis])
    key = jax.random.PRNGKey(0)

    def sm(fn, in_specs, out_specs):
        wrapped = compat.shard_map(fn, mesh, in_specs=in_specs, out_specs=out_specs)
        return jax.jit(wrapped)

    def lead_shape(lead, *rest):
        return ((lead,) if lead > 1 else ()) + rest

    if kind == "ag_matmul":
        lead, m_loc, k, n_loc = sig
        x = jax.random.normal(key, lead_shape(lead, world * m_loc, k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n_loc), jnp.float32)
        nlead = len(x.shape) - 2
        xspec = P(*((None,) * nlead), axis, None)
        out_spec = P(*((None,) * (nlead + 2)))

        def build(ch: BlockChannel):
            return sm(compile_overlap(kind, ch), (xspec, P(None, None)), out_spec)

        return build, (x, w)

    if kind == "matmul_rs":
        lead, m_glob, k_loc, n = sig
        x = jax.random.normal(key, lead_shape(lead, m_glob, world * k_loc), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(2), (world * k_loc, n), jnp.float32)
        nlead = len(x.shape) - 2
        xspec = P(*((None,) * nlead), None, axis)
        out_spec = P(*((None,) * nlead), axis, None)

        def build(ch: BlockChannel):
            return sm(compile_overlap(kind, ch), (xspec, P(axis, None)), out_spec)

        return build, (x, w)

    if kind == "ag_attention":
        b, h, hkv, s_loc, d = sig
        q = jax.random.normal(key, (b, h, world * s_loc, d), jnp.float32)
        kv = jax.random.normal(jax.random.PRNGKey(3), (b, hkv, world * s_loc, d), jnp.float32)
        spec = P(None, None, axis, None)

        def build(ch: BlockChannel):
            return sm(compile_overlap(kind, ch, causal=True), (spec, spec, spec), spec)

        return build, (q, kv, kv)

    if kind == "ag_moe":
        from repro.core.moe_overlap import moe_router

        m_loc, d_model, top_k, e_loc, d_exp = sig
        e = e_loc * world
        x = jax.random.normal(key, (world * m_loc, d_model), jnp.float32) * 0.5
        wr = jax.random.normal(jax.random.PRNGKey(4), (d_model, e), jnp.float32)
        wgu = jax.random.normal(jax.random.PRNGKey(5), (e, d_model, 2 * d_exp), jnp.float32) * 0.1
        wdn = jax.random.normal(jax.random.PRNGKey(6), (e, d_exp, d_model), jnp.float32) * 0.1

        def build(ch: BlockChannel):
            g = compile_overlap(kind, ch, capacity_factor=8.0)

            def f_(xs, wgu_, wdn_):
                ids, wts, _ = moe_router(xs, wr, num_experts=e, top_k=max(1, top_k))
                return g(xs, ids, wts, wgu_, wdn_)

            in_specs = (P(axis, None), P(axis, None, None), P(axis, None, None))
            return sm(f_, in_specs, P(axis, None))

        return build, (x, wgu, wdn)

    raise ValueError(f"kind {kind!r} is not measurable; one of {TUNABLE_KINDS}")


class CaseTimer:
    """One ``(kind, mesh, signature)`` measurement context for a whole sweep.

    ``build_case`` runs ONCE — the synthetic operands are shared by every
    candidate, so scores differ only by the design point.  Each candidate
    still compiles its own executable (a different design point is a
    different program) through the AOT split in :func:`time_fn`.
    """

    def __init__(self, kind: str, mesh, axis: str, sig: Tuple[int, ...]):
        self.kind = kind
        self._build, self._args = build_case(kind, mesh, axis, sig)

    def time(self, channel: BlockChannel, *, repeats: int = 3, warmup: int = 1):
        """``(median_us, iqr_us)`` for one realized candidate."""
        return time_fn(self._build(channel), *self._args, repeats=repeats, warmup=warmup)


def measure_channel(
    kind: str,
    channel: BlockChannel,
    mesh,
    sig: Tuple[int, ...],
    *,
    repeats: int = 3,
    warmup: int = 1,
) -> Tuple[float, float]:
    """``(median_us, iqr_us)`` of one realized candidate on ``mesh``."""
    return CaseTimer(kind, mesh, channel.axis, sig).time(channel, repeats=repeats, warmup=warmup)
