"""Successive-halving measured sweep with analytic screening + early exit.

The joint ``CommSpec x CompSpec`` space is ~hundreds of points per shape
(ROADMAP) — far too many to time naively at full repeats.  This module is
the measured ranker's search strategy, structured as three shrinking rounds
in the successive-halving spirit (cf. the Flux / Triton-distributed
autotuners in PAPERS.md):

  1. **rank** — the whole space is ordered by the analytic cost model
     (``tune/cost.py``): free, trace-safe host arithmetic;
  2. **screen** — only a cost-ordered *prefix* (``screen_fraction`` of the
     space, at least ``min_screen`` points) is timed at all, with a cheap
     1-repeat screen through the shared :class:`~repro.tune.measure.CaseTimer`
     (operands built once, compile time AOT-split out); everything past the
     prefix is pruned unmeasured;
  3. **promote** — the best ``keep_fraction`` of the screen, re-ordered by
     screen time, gets full-repeat ``(median, iqr)`` timing.  The loop stops
     early once the incumbent beats the next candidate's screen time by more
     than its own noise band — ``screen > median + iqr`` — so measurement
     noise WIDENS the search instead of shrinking it: a screen below the
     incumbent's plausible range still gets timed, and screens are sorted
     ascending, so past the cut no remaining candidate can plausibly win.

``measured_sweep`` takes the timer as a callable so tests and the CI smoke
can substitute a deterministic oracle — on the emulated CPU target wall time
is not a perf signal (ROADMAP), but the pruning *algorithm* (prefix size,
early exit, winner agreement with the exhaustive sweep) is deterministic and
is asserted in ``benchmarks/autotune_bench.py --smoke``.

Environment knobs (also surfaced in README.md):

  ``REPRO_TUNE_SWEEP``         "0" disables pruning — every candidate is
                               timed at full repeats (the exhaustive sweep);
  ``REPRO_TUNE_SWEEP_SCREEN``  fraction of the cost-ordered space screened
                               (default 0.4);
  ``REPRO_TUNE_SWEEP_KEEP``    fraction of the screen promoted to
                               full-repeat timing (default 0.25).
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

from repro.tune import cost as _cost
from repro.tune.candidates import Candidate

__all__ = ["SweepConfig", "SweepResult", "sweep_config_from_env", "measured_sweep"]

_ENV_ENABLE = "REPRO_TUNE_SWEEP"
_ENV_SCREEN = "REPRO_TUNE_SWEEP_SCREEN"
_ENV_KEEP = "REPRO_TUNE_SWEEP_KEEP"

# a Timer maps (candidate, repeats=, warmup=) -> (median_us, iqr_us)
Timer = Callable[..., Tuple[float, float]]


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    """Knobs of the pruned sweep (env-derived via :func:`sweep_config_from_env`)."""

    enabled: bool = True
    screen_fraction: float = 0.4  # cost-ordered prefix that is timed at all
    keep_fraction: float = 0.25  # screened fraction promoted to full repeats
    min_screen: int = 4  # small spaces: never screen fewer than this
    min_keep: int = 2

    def __post_init__(self):
        if not (0.0 < self.screen_fraction <= 1.0 and 0.0 < self.keep_fraction <= 1.0):
            raise ValueError(
                f"sweep fractions must be in (0, 1]: screen={self.screen_fraction}, "
                f"keep={self.keep_fraction}"
            )


def sweep_config_from_env() -> SweepConfig:
    """Config with the ``REPRO_TUNE_SWEEP*`` environment overrides applied."""
    kw: Dict[str, Any] = {}
    flag = os.environ.get(_ENV_ENABLE)
    if flag is not None:
        kw["enabled"] = flag.strip().lower() not in ("0", "false", "off", "no")
    screen = os.environ.get(_ENV_SCREEN)
    if screen:
        kw["screen_fraction"] = float(screen)
    keep = os.environ.get(_ENV_KEEP)
    if keep:
        kw["keep_fraction"] = float(keep)
    return SweepConfig(**kw)


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Winner of one measured sweep plus the pruning ledger."""

    winner: Candidate
    median_us: float
    iqr_us: float
    stats: Dict[str, Any]  # total/screened/timed/pruned/early_exit (cache v3)


def _exhaustive(cands, timer, repeats, warmup) -> SweepResult:
    best, best_med, best_iqr = None, float("inf"), 0.0
    for cand in cands:
        med, iqr = timer(cand, repeats=repeats, warmup=warmup)
        if med < best_med:  # strict: ties keep enumeration order
            best, best_med, best_iqr = cand, med, iqr
    stats = {
        "total": len(cands),
        "screened": len(cands),
        "timed": len(cands),
        "pruned": 0,
        "early_exit": False,
    }
    return SweepResult(winner=best, median_us=best_med, iqr_us=best_iqr, stats=stats)


def measured_sweep(
    kind: str,
    sig: Sequence[int],
    world: int,
    cands: Sequence[Candidate],
    timer: Timer,
    *,
    repeats: int = 3,
    warmup: int = 1,
    config: Optional[SweepConfig] = None,
) -> SweepResult:
    """Pruned measured search over ``cands`` (module docstring for the shape).

    ``timer(cand, repeats=, warmup=)`` must return ``(median_us, iqr_us)``;
    ``repeats``/``warmup`` here apply to the full-timing round (the screen
    always uses one repeat).  Disabled or degenerate configs fall back to
    the exhaustive full-repeat sweep so the winner contract never weakens.
    """
    if not cands:
        raise ValueError("measured_sweep needs at least one candidate")
    cfg = config or sweep_config_from_env()
    n = len(cands)
    n_screen = min(n, max(cfg.min_screen, math.ceil(cfg.screen_fraction * n)))
    if not cfg.enabled or n_screen >= n:
        return _exhaustive(cands, timer, repeats, warmup)

    sig = tuple(int(s) for s in sig)
    order = sorted(range(n), key=lambda i: _cost.predict_cost(kind, sig, world, cands[i]))
    screened = []
    for i in order[:n_screen]:
        med, _ = timer(cands[i], repeats=1, warmup=warmup)
        screened.append((i, med))
    # stable sort: model-order ties resolve toward the cheaper predicted point
    screened.sort(key=lambda t: t[1])
    n_keep = min(len(screened), max(cfg.min_keep, math.ceil(cfg.keep_fraction * len(screened))))

    best, best_med, best_iqr, timed, early = None, float("inf"), 0.0, 0, False
    for i, screen_us in screened[:n_keep]:
        if best is not None and screen_us > best_med + best_iqr:
            # the incumbent beats every remaining screen (ascending) by more
            # than its own noise band: nothing left can plausibly win
            early = True
            break
        med, iqr = timer(cands[i], repeats=repeats, warmup=warmup)
        timed += 1
        if med < best_med:
            best, best_med, best_iqr = cands[i], med, iqr
    assert best is not None  # n_keep >= 1 and the first iteration always times
    stats = {
        "total": n,
        "screened": n_screen,
        "timed": timed,
        "pruned": n - n_screen,
        "early_exit": early,
    }
    return SweepResult(winner=best, median_us=best_med, iqr_us=best_iqr, stats=stats)
