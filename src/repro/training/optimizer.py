"""AdamW with warmup+cosine schedule, global-norm clipping, grad masks.

Optimizer state is fp32 and sharded exactly like the parameters (ZeRO-style:
the same PartitionSpec tree applies, so per-device optimizer bytes scale 1/N).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "apply_update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1, cfg.total_steps - cfg.warmup_steps), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params):
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree_util.tree_map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                        for leaf in leaves))


def apply_update(params, grads, state, cfg: AdamWConfig,
                 grad_masks: Optional[Any] = None):
    """One AdamW step. Returns (params, state, metrics)."""
    if grad_masks is not None:
        grads = jax.tree_util.tree_map(
            lambda g, m: g if m is None else g * m.astype(g.dtype),
            grads, grad_masks,
            is_leaf=lambda v: v is None or isinstance(v, jnp.ndarray),
        )
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32) * clip
        mu = cfg.b1 * mu + (1 - cfg.b1) * g32
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g32)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(state["mu"])
    flat_nu = jax.tree_util.tree_leaves(state["nu"])
    new = [upd(p, g, mu, nu) for p, g, mu, nu in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([n[0] for n in new])
    new_mu = tdef.unflatten([n[1] for n in new])
    new_nu = tdef.unflatten([n[2] for n in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
