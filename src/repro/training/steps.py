"""Train / eval step builders (jit-compiled, mesh-aware)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.training.optimizer import AdamWConfig, apply_update

__all__ = ["softmax_xent", "make_train_step", "make_eval_step"]


def softmax_xent(logits, labels, mask=None):
    """Mean cross-entropy. logits [B,S,V] (any dtype), labels [B,S] int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        m = mask.astype(jnp.float32)
        return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    return nll.mean()


def make_train_step(model, cfg, pc, opt_cfg: AdamWConfig, *,
                    remat_policy: str = "dots",
                    grad_masks=None,
                    aux_weight: float = 0.01,
                    donate: bool = True,
                    sync_kv: bool = True) -> Callable:
    """Returns jit'd train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch: {"inputs": [B,S] i32, "labels": [B,S] i32, optional "embeds",
    optional "mask"}.
    """

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            logits, aux = model.forward(
                p, cfg, pc, batch["inputs"], embeds=batch.get("embeds"),
                remat_policy=remat_policy)
            ce = softmax_xent(logits, batch["labels"], batch.get("mask"))
            return ce + aux_weight * aux, (ce, aux)

        (loss, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        if sync_kv and hasattr(model, "sync_grads"):
            grads = model.sync_grads(grads, cfg, pc)
        new_params, new_opt, om = apply_update(
            params, grads, opt_state, opt_cfg, grad_masks=grad_masks)
        metrics = {"loss": loss, "ce": ce, "aux": aux, **om}
        return new_params, new_opt, metrics

    donate_argnums = (0, 1) if donate else ()
    return jax.jit(train_step, donate_argnums=donate_argnums)


def make_eval_step(model, cfg, pc) -> Callable:
    def eval_step(params, batch):
        logits, _ = model.forward(params, cfg, pc, batch["inputs"],
                                  embeds=batch.get("embeds"))
        return softmax_xent(logits, batch["labels"], batch.get("mask"))

    return jax.jit(eval_step)
