from repro.training.optimizer import AdamWConfig, init_opt_state, apply_update
from repro.training.steps import make_train_step, softmax_xent
from repro.training import compression

__all__ = ["AdamWConfig", "init_opt_state", "apply_update", "make_train_step",
           "softmax_xent", "compression"]
