"""Gradient compression: int8 quantization with error feedback.

Distributed-optimization trick for the DP all-reduce at scale: gradients are
quantized to int8 (per-tensor scale), the quantization error is carried into
the next step (error feedback keeps SGD/Adam convergence), and the all-reduce
moves 4x fewer bytes.

Under FSDP the gradient reduction is fused into XLA's reduce-scatter and is
already bandwidth-optimal per byte, so compression applies to the *replicated*
(pure-DP) parameter mode — the train driver enables it with
``--grad-compression`` when ``--fsdp=off``; tests validate the error-feedback
contract directly.

The int8 codec itself lives in :mod:`repro.core.quant` (shared with the
wire-dtype QuantSpec layer); ``quantize_int8``/``dequantize_int8`` are
re-exported here for the existing training call sites with their semantics
unchanged (per-tensor symmetric scale, 1e-12 floor, +/-127 clip — pinned by
``tests/test_properties.py``'s error-feedback bound).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core.quant import dequantize_int8, quantize_int8

__all__ = ["quantize_int8", "dequantize_int8", "compress_with_feedback",
           "psum_compressed"]


def compress_with_feedback(g, err):
    """Error-feedback int8 compression of one gradient tensor.

    Returns (quantized, scale, new_err) with g + err = deq(q)*1 + new_err.
    """
    g32 = g.astype(jnp.float32) + err
    q, scale = quantize_int8(g32)
    new_err = g32 - dequantize_int8(q, scale)
    return q, scale, new_err


def psum_compressed(g, err, axis: str):
    """All-reduce a gradient over ``axis`` with int8 error-feedback compression.

    Call per-shard inside shard_map over the DP axis.  The int8 payload is
    summed in int32 (exact), the scale is the per-rank max (conservative).
    Returns (g_reduced_mean, new_err).
    """
    q, scale, new_err = compress_with_feedback(g, err)
    total = lax.psum(q.astype(jnp.int32), axis)
    scale_max = lax.pmax(scale, axis)
    n = lax.psum(1, axis)
    return dequantize_int8(total, scale_max) / n, new_err
