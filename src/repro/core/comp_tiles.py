"""Compute-tile utilities — the CompSpec (tm, tn, tk) half, realized.

``CompSpec.tile`` is the consumer-kernel MXU tile, chosen independently from
the communication tile (the core decoupling of the paper).  This module is
the one place its semantics live, shared by every executor:

  * :func:`largest_divisor` / :func:`resolve_tile` clamp a requested tile
    against the operand extents it must divide — the same largest-divisor
    rule ``mapping.effective_channels`` applies to the comm half, so a tuned
    tile degrades predictably instead of crashing on an awkward shape;
  * :func:`blocked_dot` computes a (possibly batched) GEMM in (tm, tn, tk)
    blocks accumulated in the accum dtype — the XLA-path compute callbacks
    (``core/overlap.py``) and the fused Pallas kernels
    (``kernels/ag_gemm.py``, ``gemm_rs.py``) all honor a non-default tile
    through it, so a tuner winner behaves identically on both backends;
  * :func:`tile_footprint_bytes` is the per-tile VMEM working set the tuner
    prunes its lattice against (``repro.tune.candidates``).

``DEFAULT_TILE`` (128, 128, 128) means "let the backend choose": the XLA
path hands the whole per-step GEMM to XLA's own tiler, the Pallas kernels
use their native blocking.  Only a non-default tile forces explicit blocks.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
from jax import lax

from repro.core.quant import PackedWeight, dequantize_weight

__all__ = [
    "DEFAULT_TILE",
    "largest_divisor",
    "resolve_tile",
    "blocked_dot",
    "tile_footprint_bytes",
]

DEFAULT_TILE = (128, 128, 128)


def largest_divisor(extent: int, cap: int) -> int:
    """Largest divisor of ``extent`` that is <= ``cap`` (>= 1).

    Divisors are enumerated in factor pairs up to ``sqrt(extent)`` —
    O(sqrt(extent)) always — instead of decrementing from ``cap``, which is
    O(extent) when ``extent`` is prime and ``cap`` is large (a vocab-sized
    prime dim would spin for seconds per lattice probe).
    """
    extent = max(1, int(extent))
    cap = min(max(1, int(cap)), extent)
    best = 1
    d = 1
    while d * d <= extent:
        if extent % d == 0:
            if d <= cap and d > best:
                best = d
            pair = extent // d
            if pair <= cap and pair > best:
                best = pair
        d += 1
    return best


def resolve_tile(tile: Tuple[int, int, int], m: int, n: int, k: int) -> Tuple[int, int, int]:
    """Clamp a requested (tm, tn, tk) to divisors of the GEMM dims (m, n, k)."""
    tm, tn, tk = tile
    return (largest_divisor(m, tm), largest_divisor(n, tn), largest_divisor(k, tk))


def blocked_dot(
    a: jnp.ndarray,
    b: jnp.ndarray,
    tile: Tuple[int, int, int],
    accum=jnp.float32,
    out_dtype: Optional[jnp.dtype] = None,
    unroll: bool = False,
) -> jnp.ndarray:
    """``a @ b`` computed in (tm, tn, tk) blocks, accumulated in ``accum``.

    ``a``: [..., m, k] (leading batch dims allowed), ``b``: [k, n] — or a
    :class:`~repro.core.quant.PackedWeight` of the same logical shape
    (weight-only int8/int4): its codes are dequantized with their
    per-output-channel scales/zero-points INSIDE the decomposition, per
    (tk, tn) block on the ``unroll=True`` path — in VMEM right before the
    MXU in the Pallas kernel bodies — and as one fused elementwise producer
    on the XLA paths (XLA fuses it into the dot's operand read).  The tile
    is clamped through :func:`resolve_tile` first; a tile covering the whole
    problem takes the single-dot fast path (bit-identical to the untiled
    contraction).

    Two lowerings of the same block decomposition:

      * ``unroll=False`` (default, the XLA executor path): operands reshape
        to explicit [m/tm, tm, ...] block form and contract in ONE
        ``dot_general`` — O(1) emitted ops regardless of block count, so a
        tuned tile on a large shape cannot blow up trace/compile time;
      * ``unroll=True`` (the Pallas kernel bodies): explicit per-block 2-D
        dots accumulated in registers — the Mosaic-friendly form (4-D
        multi-contraction dots do not lower there), where the block count
        is already bounded by the kernel's per-chunk operand sizes.
    """
    m, k = a.shape[-2], a.shape[-1]
    packed = isinstance(b, PackedWeight)
    n = b.shape[-1]
    accum = jnp.dtype(accum)
    tm, tn, tk = resolve_tile(tile, m, n, k)

    def dot(x, y):
        dims = (((x.ndim - 1,), (0,)), ((), ()))
        return lax.dot_general(x, y, dims, preferred_element_type=accum)

    if (tm, tn, tk) == (m, n, k):
        bv = dequantize_weight(b.q, b.scale, b.zero, accum) if packed else b
        out = dot(a, bv)
        return out.astype(out_dtype) if out_dtype is not None else out

    if not unroll:
        # whole-weight dequant here is the same fused elementwise producer
        # XLA builds for the per-block form — only the Pallas path below
        # needs the dequant spelled per block (VMEM residency)
        bv = dequantize_weight(b.q, b.scale, b.zero, accum) if packed else b
        lead = a.shape[:-2]
        a4 = a.reshape(lead + (m // tm, tm, k // tk, tk))
        b4 = bv.reshape(k // tk, tk, n // tn, tn)
        nd = a4.ndim
        # contract (k-block, tk) jointly: the blocked layout stays explicit,
        # the emitted program stays a single op
        dims = (((nd - 2, nd - 1), (0, 1)), ((), ()))
        out = lax.dot_general(a4, b4, dims, preferred_element_type=accum)
        out = out.reshape(lead + (m, n))  # [..., m/tm, tm, n/tn, tn] -> [..., m, n]
        return out.astype(out_dtype) if out_dtype is not None else out

    def b_block(ni, ki):
        """One (tk, tn) weight block, dequantized at the point of use."""
        ns = slice(ni * tn, (ni + 1) * tn)
        ks = slice(ki * tk, (ki + 1) * tk)
        if not packed:
            return b[ks, ns]
        zero = None if b.zero is None else b.zero[ns]
        return dequantize_weight(b.q[ks, ns], b.scale[ns], zero, accum)

    rows = []
    for mi in range(m // tm):
        a_mi = a[..., mi * tm : (mi + 1) * tm, :]
        cols = []
        for ni in range(n // tn):
            blk = dot(a_mi[..., 0:tk], b_block(ni, 0))
            for ki in range(1, k // tk):
                blk = blk + dot(a_mi[..., ki * tk : (ki + 1) * tk], b_block(ni, ki))
            cols.append(blk)
        rows.append(cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=-1))
    out = rows[0] if len(rows) == 1 else jnp.concatenate(rows, axis=-2)
    return out.astype(out_dtype) if out_dtype is not None else out


def tile_footprint_bytes(tile: Tuple[int, int, int], in_bytes: int, accum_bytes: int) -> int:
    """Per-tile VMEM working set: A and B operand tiles + the accumulator."""
    tm, tn, tk = tile
    return (tm * tk + tk * tn) * in_bytes + tm * tn * accum_bytes
