"""Tile-centric device primitives (paper Table 3) for Pallas TPU kernels.

These are thin, semantically faithful wrappers over Pallas TPU semaphore and
remote-DMA operations, so fused kernels in ``repro.kernels`` read like the
paper's pseudo-code (Figs. 4–6):

  paper primitive            TPU realization
  -------------------------  ----------------------------------------------
  producer_tile_notify       pltpu.semaphore_signal on the consumer's channel
                             semaphore (local or remote rank) — *release*
  consumer_tile_wait         pltpu.semaphore_wait on the channel semaphore —
                             *acquire* (Mosaic DMAs/semaphores order memory)
  peer_tile_notify/wait      same, on a peer-channel semaphore
  tile_push_data             pltpu.make_async_remote_copy (push over ICI)
  tile_pull_data             SPMD-symmetric push (ICI RDMA is push-native; in
                             an SPMD program every rank pushing its shard is
                             dataflow-equivalent to every rank pulling)
  rank_copy_data             host-side: lax.ppermute / XLA async collective
                             (the "copy engine" resource mapping)

Memory consistency (paper §4.2): Mosaic's semaphore_signal has release
semantics w.r.t. prior DMAs/stores issued by the core, and semaphore_wait has
acquire semantics; additionally the kernel builders in ``repro.kernels`` only
emit loads of a tile *after* the wait that guards it, so no pipelining pass can
reorder across the barrier — the strict-dependency rule of the paper.
"""
from __future__ import annotations

from repro import backend

__all__ = [
    "producer_tile_notify",
    "consumer_tile_wait",
    "peer_tile_notify",
    "peer_tile_wait",
    "tile_push_data",
    "make_tile_push",
]


def producer_tile_notify(sem, *, rank=None, inc: int = 1):
    """Mark a producer tile done; notify its consumer tile's channel semaphore.

    ``rank=None`` notifies the local consumer (p2p, same device);
    ``rank=r`` notifies rank ``r`` (push mode); broadcast = loop over ranks.
    """
    backend.semaphore_signal(sem, inc, rank=rank)


def consumer_tile_wait(sem, *, count: int = 1):
    """Block the consumer until ``count`` producer tiles signalled the channel."""
    backend.semaphore_wait(sem, count)


# peers are the same mechanism on a dedicated peer channel (paper Fig. 4 ring)
peer_tile_notify = producer_tile_notify
peer_tile_wait = consumer_tile_wait


def make_tile_push(src_ref, dst_ref, send_sem, recv_sem, rank):
    """Build an async remote copy handle: tile_push_data (start/wait split).

    Returns the handle so callers can overlap: ``h.start()`` issues the DMA on
    the ICI engine; compute proceeds; ``h.wait()`` (or the receiver's
    ``wait_recv``) completes it.
    """
    return backend.make_async_remote_copy(
        src_ref=src_ref,
        dst_ref=dst_ref,
        send_sem=send_sem,
        recv_sem=recv_sem,
        rank=rank,
    )


def tile_push_data(src_ref, dst_ref, send_sem, recv_sem, rank, *, notify_sem=None):
    """Synchronous-ish push: start the DMA and wait for local send completion.

    If ``notify_sem`` is given, also signals the remote consumer's channel
    (producer_tile_notify in push mode) after the send completes.
    """
    h = make_tile_push(src_ref, dst_ref, send_sem, recv_sem, rank)
    h.start()
    h.wait_send()
    if notify_sem is not None:
        producer_tile_notify(notify_sem, rank=rank)
    return h
