"""BlockChannel — the tile-centric mapping context (paper §6).

The paper threads a special ``BlockChannel`` parameter through generated
kernels; it "encapsulates distributed mapping metadata including current
process rank, total world size, synchronization barrier configurations, and
producer/consumer block relationships".  Here it is the *sole input* to the
frontend's plan layer: ``compile_overlap`` lowers ``(kind, BlockChannel)``
through ``core/plan.build_plan`` into a :class:`~repro.core.plan.TilePlan`
that both backends execute — the XLA backend via the generic schedule
executor (``core/overlap.run_plan``), the Pallas backend via schedule tables
baked into the fused kernels.  Every field below is therefore *live* across
all workload kinds:

  ``comm.order``      picks the per-step peer schedule (ring / bidir_ring /
                      all2all) for tiles and flowing partials alike;
  ``num_channels``    chunks each rank's shard into C independently scheduled
                      flows (C outstanding transfers — the paper's f_C);
  ``comp.accum_dtype``is the reduction dtype: what partial reductions
                      accumulate in (fp32 = reduction-exact);
  ``quant``           is the wire half of the dtype axis
                      (:class:`~repro.core.quant.QuantSpec`): what tiles and
                      flowing partials travel the wire in — ``None`` wire
                      inherits ``accum_dtype`` (bitwise-identical default),
                      bf16 halves the ring bytes, int8/fp8 quarter them with
                      scales riding the plan, and ``weight_dtype`` packs
                      weights for dequant-GEMM fused into the ring;
  ``comp.tile``       is the (tm, tn, tk) consumer compute tile — tunable
                      independently of the comm half (``core/comp_tiles``);
  ``comm.resource``   / ``comm.mode`` select the transfer engine and
                      push/pull realization (paper Fig. 2c, §3.2.2).

Specs validate at construction — an unsupported order/resource/mode/dtype or
a non-positive channel count raises immediately, not deep inside a trace.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.quant import QuantSpec

__all__ = ["BlockChannel", "CommSpec", "CompSpec", "QuantSpec", "ORDERS",
           "RESOURCES", "MODES"]

ORDERS = ("ring", "bidir_ring", "all2all")
RESOURCES = ("dma", "core")
MODES = ("push", "pull")


def _check(value, allowed, what: str):
    if value not in allowed:
        raise ValueError(f"unsupported {what} {value!r}; supported: {allowed}")


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """Communication half of the decoupled design space (paper §3.1).

    tile:     communication tile size along the sharded dim (paper's Tm_p).
    order:    tile order — "ring" | "bidir_ring" | "all2all" (paper Fig. 2b).
    resource: "dma" maps transfers to the async DMA/ICI engine (copy-engine
              mapping); "core" issues copies from the compute core (paper Fig 2c).
    mode:     "push" | "pull" (paper §3.2.2); on TPU ICI RDMA is push-native, so
              pull is realized SPMD-symmetrically (each rank pushes its shard).
    """

    tile: int = 128
    order: str = "ring"
    resource: str = "dma"
    mode: str = "push"

    def __post_init__(self):
        _check(self.order, ORDERS, "tile order")
        _check(self.resource, RESOURCES, "comm resource")
        _check(self.mode, MODES, "comm mode")
        if self.tile < 1:
            raise ValueError(f"comm tile must be >= 1, got {self.tile}")


@dataclasses.dataclass(frozen=True)
class CompSpec:
    """Computation half of the decoupled design space.

    tile:        (tm, tn, tk) MXU tile for the consumer compute kernel — chosen
                 independently from CommSpec.tile (the core decoupling of the
                 paper).  The default (128, 128, 128) is a sentinel meaning
                 "backend-chosen blocking"; a non-default tile is honored
                 literally by both backends (clamped to divisors of the
                 operand extents — see core/comp_tiles).
    accum_dtype: dtype partial reductions accumulate in — the reduction
                 dtype only.  What travels the wire is the *quant* half
                 (``BlockChannel.quant``); with the default QuantSpec the
                 wire inherits this dtype, so "float32" is reduction-exact
                 end to end and "bfloat16" halves the flowing bytes.
    """

    tile: Tuple[int, int, int] = (128, 128, 128)
    accum_dtype: str = "float32"

    def __post_init__(self):
        if len(self.tile) != 3 or any(t < 1 for t in self.tile):
            raise ValueError(f"comp tile must be 3 positive ints (tm, tn, tk), got {self.tile}")
        try:
            dt = jnp.dtype(self.accum_dtype)
        except TypeError as e:
            raise ValueError(f"unsupported accum_dtype {self.accum_dtype!r}: {e}") from None
        if not jnp.issubdtype(dt, jnp.floating):
            raise ValueError(
                f"accum_dtype must be floating (flow/reduction dtype), got {self.accum_dtype!r}"
            )


@dataclasses.dataclass(frozen=True)
class BlockChannel:
    """Tile-centric mapping context shared by producer and consumer.

    axis:          mesh axis name the collective runs over (e.g. "model").
    num_channels:  barrier channels per rank (paper's C; controls f_C granularity
                   and == number of outstanding DMA chunks per rank here).  If C
                   does not divide the chunked extent at trace time, the plan
                   layer falls back to the largest divisor <= C (with a warning).
    comm/comp:     the two independent halves of the design space.
    quant:         the wire half of the dtype axis (QuantSpec); the default
                   inherits ``comp.accum_dtype`` as the wire dtype, which is
                   bitwise-identical to the pre-split behavior.
    """

    axis: str
    num_channels: int = 1
    comm: CommSpec = CommSpec()
    comp: CompSpec = CompSpec()
    quant: QuantSpec = QuantSpec()
    name: Optional[str] = None

    def __post_init__(self):
        if not self.axis or not isinstance(self.axis, str):
            raise ValueError(f"axis must be a non-empty mesh axis name, got {self.axis!r}")
        if self.num_channels < 1:
            raise ValueError(f"num_channels must be >= 1, got {self.num_channels}")
        if not isinstance(self.comm, CommSpec):
            raise TypeError(f"comm must be a CommSpec, got {type(self.comm)}")
        if not isinstance(self.comp, CompSpec):
            raise TypeError(f"comp must be a CompSpec, got {type(self.comp)}")
        if not isinstance(self.quant, QuantSpec):
            raise TypeError(f"quant must be a QuantSpec, got {type(self.quant)}")

    def with_(self, **kw) -> "BlockChannel":
        return dataclasses.replace(self, **kw)
