"""BlockChannel — the tile-centric mapping context (paper §6).

The paper threads a special ``BlockChannel`` parameter through generated kernels;
it "encapsulates distributed mapping metadata including current process rank,
total world size, synchronization barrier configurations, and producer/consumer
block relationships".  Here it is an explicit dataclass consumed by both overlap
backends (XLA shard_map schedules and fused Pallas kernels).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["BlockChannel", "CommSpec", "CompSpec"]


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """Communication half of the decoupled design space (paper §3.1).

    tile:     communication tile size along the sharded dim (paper's Tm_p).
    order:    tile order — "ring" | "bidir_ring" | "all2all" (paper Fig. 2b).
    resource: "dma" maps transfers to the async DMA/ICI engine (copy-engine
              mapping); "core" issues copies from the compute core (paper Fig 2c).
    mode:     "push" | "pull" (paper §3.2.2); on TPU ICI RDMA is push-native, so
              pull is realized SPMD-symmetrically (each rank pushes its shard).
    """

    tile: int = 128
    order: str = "ring"
    resource: str = "dma"
    mode: str = "push"


@dataclasses.dataclass(frozen=True)
class CompSpec:
    """Computation half of the decoupled design space.

    tile: (tm, tn, tk) MXU tile for the consumer compute kernel — chosen
    independently from CommSpec.tile (the core decoupling of the paper).
    """

    tile: Tuple[int, int, int] = (128, 128, 128)
    accum_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class BlockChannel:
    """Tile-centric mapping context shared by producer and consumer.

    axis:          mesh axis name the collective runs over (e.g. "model").
    num_channels:  barrier channels per rank (paper's C; controls f_C granularity
                   and == number of outstanding DMA chunks per rank here).
    comm/comp:     the two independent halves of the design space.
    """

    axis: str
    num_channels: int = 1
    comm: CommSpec = CommSpec()
    comp: CompSpec = CompSpec()
    name: Optional[str] = None

    def with_(self, **kw) -> "BlockChannel":
        return dataclasses.replace(self, **kw)
