"""TileLink core: tile-centric primitives, mappings, schedules, overlap compiler."""
from repro.core.channels import BlockChannel, CommSpec, CompSpec
from repro.core.mapping import StaticTileMapping, DynamicTileMapping, build_moe_dynamic_mapping
from repro.core.compiler import compile_overlap
from repro.core import overlap, schedules, moe_overlap

__all__ = [
    "BlockChannel", "CommSpec", "CompSpec",
    "StaticTileMapping", "DynamicTileMapping", "build_moe_dynamic_mapping",
    "compile_overlap", "overlap", "schedules", "moe_overlap",
]
