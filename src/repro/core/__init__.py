"""TileLink core: tile-centric primitives, mappings, schedules, plans, overlap compiler."""
from repro.core.channels import BlockChannel, CommSpec, CompSpec
from repro.core.mapping import (
    StaticTileMapping,
    DynamicTileMapping,
    build_moe_dynamic_mapping,
    effective_channels,
)
from repro.core.plan import (
    TilePlan,
    SeqPlan,
    ChannelSchedule,
    build_plan,
    build_seq_plan,
    plan_cache_info,
)
from repro.core.compiler import (
    compile_overlap,
    SeamFallbackWarning,
    KINDS,
    SEQ_KINDS,
    unsupported_error,
)
from repro.core import comp_tiles, overlap, schedules, moe_overlap, plan

__all__ = [
    "BlockChannel",
    "CommSpec",
    "CompSpec",
    "StaticTileMapping",
    "DynamicTileMapping",
    "build_moe_dynamic_mapping",
    "effective_channels",
    "TilePlan",
    "SeqPlan",
    "ChannelSchedule",
    "build_plan",
    "build_seq_plan",
    "plan_cache_info",
    "compile_overlap",
    "SeamFallbackWarning",
    "KINDS",
    "SEQ_KINDS",
    "unsupported_error",
    "comp_tiles",
    "overlap",
    "schedules",
    "moe_overlap",
    "plan",
]
