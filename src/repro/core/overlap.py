"""XLA overlap backend — one generic schedule executor over tile plans.

This module lowers TileLink tile programs to JAX/XLA:TPU primitives.  The
paper's resource-mapping choice "communication on the copy engine" is realized
by expressing the producer/consumer tile graph as SSA dataflow over
``lax.ppermute`` steps: XLA:TPU's latency-hiding scheduler issues each
``collective-permute-start`` on the ICI DMA engines and overlaps it with the
MXU compute of the previously received tile.  The paper's barriers become SSA
data dependencies — release/acquire consistency is structural (a tile's matmul
consumes exactly the permuted value, so it can never be hoisted above the
"wait"), which satisfies §4.2 of the paper by construction.

There is exactly ONE schedule loop here: :func:`run_plan` executes any
:class:`~repro.core.plan.TilePlan` — every workload kind is a per-tile compute
callback plugged into it (GEMM tile, online-softmax tile, grouped-GEMM tile in
``core/moe_overlap.py``), so ``CommSpec.order``, ``num_channels``,
``CompSpec.accum_dtype`` (the reduction dtype), and the wire half of the
dtype axis (``BlockChannel.quant`` — what travels, encoded at the send edge
and decoded at the consumer, quantized payloads carrying their scales through
the same permutes) behave identically across all kinds.  Every
callback additionally honors a non-default ``CompSpec.tile``: the GEMM
callbacks compute in explicit (tm, tn, tk) blocks
(``core/comp_tiles.blocked_dot``), the attention callback maps (tm, tk)
onto (block_q, block_kv) of its online-softmax update, and the MoE callback
(``core/moe_overlap.py``) blocks its per-expert grouped GEMMs — the same
decompositions the fused Pallas kernels use (``kernels/flash_attention.py``,
``kernels/grouped_matmul.py``), so a tuned tile means the same thing on
both backends.

Every function here is a *per-shard* function: call it inside ``shard_map``
(the model layers do, via ``parallel.ParallelContext``).

Functions come in paper-faithful pairs:

  non-overlapping baseline            overlapped (TileLink)
  ----------------------------------  -------------------------------------
  ag_matmul_baseline                  ag_matmul          (AG + GEMM)
  matmul_rs_baseline                  matmul_rs          (GEMM + ring RS, Fig. 4)
  ag_attention_baseline               ring_attention     (AG-KV + attn, Fig. 6)
  ag_moe_baseline                     ag_moe             (AG + MoE, Fig. 5;
                                                          core/moe_overlap.py)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.backend import axis_size
from repro.core.channels import BlockChannel
from repro.core.comp_tiles import DEFAULT_TILE, blocked_dot, largest_divisor
from repro.core.mapping import effective_channels
from repro.core.plan import SeqPlan, TilePlan, build_plan, build_seq_plan
from repro.core.quant import PackedWeight, decode_tree, encode_tree

__all__ = [
    "run_plan",
    "run_seq_plan",
    "run_a2a_seq",
    "TileContext",
    "ag_matmul",
    "ag_matmul_baseline",
    "matmul_rs",
    "matmul_rs_baseline",
    "matmul_rs_ag",
    "ring_attention",
    "ag_attention_baseline",
    "psum_scatter_ring",
]


# -----------------------------------------------------------------------------
# The generic schedule executor
# -----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TileContext:
    """What the executor tells a compute callback about the current tile.

    step/channel are host ints (the schedule is unrolled at trace time);
    ``src`` is a *traced* rank id: the origin rank of the held tile for AG
    flows, the reduced segment id for RS flows.
    """

    step: int
    channel: int
    src: Any
    plan: TilePlan


def _permute(tree, axis, pairs):
    return jax.tree_util.tree_map(lambda t: lax.ppermute(t, axis, pairs), tree)


def _tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def run_plan(
    plan: TilePlan,
    tile_fn: Callable,
    *,
    state: Optional[Sequence[Any]] = None,
    carry: Any = None,
) -> Any:
    """Execute a tile plan; the only ``lax.ppermute`` loop in the backend.

    plan.flow == "ag":
        ``state[c]`` is channel c's flowing tile (any pytree).  Each step the
        executor issues next-step permutes for every channel (producer side),
        then calls ``tile_fn(ctx, tile, carry) -> carry`` on each held tile
        (consumer side) while the transfers are in flight.  Returns the final
        carry.

    plan.flow == "rs":
        Nothing flows in; ``tile_fn(ctx, None, None) -> partial`` computes the
        partial for segment ``ctx.src``; the executor keeps one flowing
        accumulator per channel (``acc = decode(ppermute(encode(acc))) +
        partial`` — encode/decode are the plan's wire edges, identity for the
        default QuantSpec, a cast for a float wire, scaled int8/fp8 payloads
        otherwise; the add always runs in ``plan.accum_dtype``).  Returns the
        per-channel fully reduced home segments (a list, channel-major).

    Wire encoding (``plan.quant``): flowing tiles ("ag"/"ag_rs"/"a2a" state)
    are encoded ONCE at entry and stay encoded across every permute — each
    consumer step decodes its held tile before the callback, so per-tile
    quantization error is independent of world size.  Flowing reductions
    ("rs", the "ag_rs" ride-along, the "a2a_rs" returns) re-encode at each
    send edge.  With the default spec every edge is the identity function —
    bitwise-identical to the pre-QuantSpec executor.

    plan.flow == "ag_rs" (MoE double ring):
        ``state`` flows exactly as in "ag"; ``tile_fn(ctx, tile, None) ->
        partial`` additionally feeds a reduction that travels the *same*
        permutes as the tiles, plus one final alignment hop sending each
        channel's reduction to its home rank.  Returns the per-channel
        reductions.

    plan.flow == "a2a" (expert-parallel dispatch):
        ``state[c]`` is channel c's *own* token tile.  Each step is a direct
        pairwise exchange of the original tiles (``a2a_perm`` — nothing is
        forwarded): the executor issues step s+1's exchange, then calls
        ``tile_fn(ctx, landed, carry) -> carry`` on the tile that landed this
        step (step 0's landed tile is the own tile).  Returns the final carry.

    plan.flow == "a2a_rs" (expert-parallel combine):
        Nothing flows in; ``tile_fn(ctx, None, None) -> partial`` computes
        the weighted expert output for tokens of origin ``ctx.src``; the
        executor returns each step's partial straight home along the reversed
        exchange edge (``combine_perm``) and accumulates there — the
        accumulator never travels, unlike "ag_rs".  Returns the per-channel
        home accumulators.
    """
    axis, nch = plan.axis, plan.num_channels
    rank = lax.axis_index(axis)
    accs: List[Any] = [None] * nch

    # wire edges: encode at send, decode at the consumer (identity when the
    # wire inherits accum_dtype — the bitwise-identical default)
    spec, adt = plan.quant, plan.accum_dtype
    wire_id = spec.is_identity(adt)

    def enc(t):
        return encode_tree(t, spec, adt)

    def dec(t):
        return decode_tree(t, spec, adt)

    if state is not None and not wire_id:
        # tiles are quantized exactly ONCE here; they stay encoded across
        # every permute and each consumer decodes its held copy
        state = [enc(st) for st in state]
    own = list(state) if plan.flow == "a2a" and state is not None else None

    for s in range(plan.steps):
        nxt = None
        if plan.flow in ("ag", "ag_rs") and s < plan.steps - 1:
            # producer: issue every channel's step s+1 transfer (tile_push_data)
            nxt = [
                _permute(state[c], axis, plan.channels[c].flow_perm(s)) for c in range(nch)
            ]
        elif plan.flow == "a2a" and s < plan.steps - 1:
            # direct exchange: step s+1 permutes the ORIGINAL own tiles
            nxt = [
                _permute(own[c], axis, plan.channels[c].a2a_perm(s + 1)) for c in range(nch)
            ]
        for c in range(nch):
            sched = plan.channels[c]
            if plan.flow == "rs":
                seg = jnp.asarray(sched.rs_segment_table(s))[rank]
                part = tile_fn(TileContext(s, c, seg, plan), None, None)
                if s == 0:
                    accs[c] = part
                else:
                    # peer_tile_wait/notify: previous partial arrives and fuses
                    # (encoded for the wire, decoded back to accum_dtype)
                    accs[c] = _tree_add(
                        dec(_permute(enc(accs[c]), axis, sched.rs_perm(s - 1))), part
                    )
            elif plan.flow == "a2a_rs":
                src = jnp.asarray(sched.source_table(s))[rank]
                part = tile_fn(TileContext(s, c, src, plan), None, None)
                if s == 0:
                    accs[c] = part  # own tokens: the partial is already home
                else:
                    # return along the reversed exchange edge, accumulate home
                    # (each partial is encoded exactly once for its one hop)
                    accs[c] = _tree_add(
                        accs[c], dec(_permute(enc(part), axis, sched.combine_perm(s)))
                    )
            else:
                # consumer_tile_wait is the SSA dependence on state[c]
                src = jnp.asarray(sched.source_table(s))[rank]
                ctx = TileContext(s, c, src, plan)
                held = state[c] if wire_id else dec(state[c])
                if plan.flow in ("ag", "a2a"):
                    carry = tile_fn(ctx, held, carry)
                else:  # ag_rs: reduction rides the tile flow
                    part = tile_fn(ctx, held, None)
                    if s == 0:
                        accs[c] = part
                    else:
                        accs[c] = _tree_add(
                            dec(_permute(enc(accs[c]), axis, sched.flow_perm(s - 1))), part
                        )
        if nxt is not None:
            state = nxt

    if plan.flow in ("ag", "a2a"):
        return carry
    if plan.flow == "ag_rs":
        # final hop: each channel's reduction goes home (rank it belongs to)
        accs = [
            dec(_permute(enc(accs[c]), axis, plan.channels[c].align_perm()))
            for c in range(nch)
        ]
    return accs


def run_seq_plan(
    seq: SeqPlan,
    rs_tile_fn: Callable,
    seam_fn: Callable,
    ag_tile_fn: Callable,
    *,
    carry: Any = None,
) -> Any:
    """Execute a fused RS -> AG seam plan in one traversal of the plan graph.

    The producer half runs exactly like an "rs" plan (``rs_tile_fn`` computes
    each segment partial); its per-channel fully reduced home segments are
    handed — still as in-trace SSA values, never through a resharding
    collective or a shard_map boundary — to ``seam_fn(accs, carry) ->
    (seam_out, state, carry)``, which applies any rank-local glue and
    re-chunks the segments into the consumer's per-channel step-0 tiles.  The
    consumer half then runs like an "ag" plan over that state.  Soundness of
    the in-place handoff is the seam-composition invariant
    (``rs_segment(r, world-1) == r == sigma(r, 0)``), statically proven for
    every ``build_seq_plan`` miss.

    Returns ``(seam_out, carry)``.  Both halves delegate to :func:`run_plan`,
    so this stays a thin composition over the single schedule loop and XLA's
    latency-hiding scheduler sees one straight-line SSA region: the RS drain
    and the AG fill schedule against each other instead of serializing at an
    operator-collective boundary.
    """
    producer, consumer = seq.ops
    accs = run_plan(producer, rs_tile_fn)
    seam_out, state, carry = seam_fn(accs, carry)
    carry = run_plan(consumer, ag_tile_fn, state=state, carry=carry)
    return seam_out, carry


def run_a2a_seq(
    seq: SeqPlan,
    tile_fn: Callable,
    *,
    state: Sequence[Any],
) -> List[Any]:
    """Execute a fused ``a2a_dispatch -> combine_rs`` pair as one pipeline.

    ``state[c]`` is channel c's own (token tile, routing tables) pytree.  Per
    step the executor issues step s+1's direct pairwise exchange of the
    original tiles, calls ``tile_fn(ctx, landed, None) -> partial`` (the
    grouped expert GEMM — the paper's f_R/f_S travel *with* the data, so the
    callback sees the landed routing tables, not a global view) on the tile
    that landed this step while the next exchange is in flight, and returns
    the partial straight home along the reversed edge (``combine_perm``)
    where it accumulates.  Step 0 is rank-local on both sides (a2a_seed).

    Soundness of reversing the edges — the combine's return destination is
    exactly the dispatch edge traversed backwards — is the
    ``a2a_seam_composition`` invariant, statically proven for every
    ``build_seq_plan`` miss.  Returns the per-channel home accumulators
    (channel c holds the combined outputs for the tokens of own chunk c).
    """
    dispatch, combine = seq.ops
    axis, nch = dispatch.axis, dispatch.num_channels
    rank = lax.axis_index(axis)

    # wire edges (see run_plan): token tiles encode once at entry; each
    # returning combine partial encodes once for its single hop home
    spec, adt = dispatch.quant, dispatch.accum_dtype
    wire_id = spec.is_identity(adt)
    if not wire_id:
        state = [encode_tree(st, spec, adt) for st in state]
    own = list(state)
    landed = list(state)
    accs: List[Any] = [None] * nch

    for s in range(dispatch.steps):
        nxt = None
        if s < dispatch.steps - 1:
            nxt = [
                _permute(own[c], axis, dispatch.channels[c].a2a_perm(s + 1))
                for c in range(nch)
            ]
        for c in range(nch):
            sched = combine.channels[c]
            src = jnp.asarray(sched.source_table(s))[rank]
            held = landed[c] if wire_id else decode_tree(landed[c], spec, adt)
            part = tile_fn(TileContext(s, c, src, dispatch), held, None)
            if s == 0:
                accs[c] = part  # own tokens: the partial is already home
            else:
                accs[c] = _tree_add(
                    accs[c],
                    decode_tree(
                        _permute(encode_tree(part, spec, adt), axis, sched.combine_perm(s)),
                        spec, adt,
                    ),
                )
        if nxt is not None:
            landed = nxt
    return accs


def _plan_for(kind: str, channel: BlockChannel, axis: str, extent: int):
    """Resolve (world, effective channels) and fetch the cached plan."""
    world = axis_size(axis)
    nch = effective_channels(extent, channel.num_channels, kind=kind)
    return build_plan(kind, channel, world, nch)


def _dot(a, b, accum=jnp.float32):
    """MXU-friendly contraction of the last dim of a with first dim of b."""
    return lax.dot_general(a, b, (((a.ndim - 1,), (0,)), ((), ())), preferred_element_type=accum)


def _consume_dot(a, w, comp_tile, accum, out_dtype=None):
    """One consumer GEMM tile: ``a @ w`` honoring the CompSpec tile.

    The default tile means "XLA's own blocking" (one dot); a tuned
    (tm, tn, tk) forces the explicit block decomposition.  A
    :class:`~repro.core.quant.PackedWeight` ``w`` (weight-only int8/int4)
    always routes through ``blocked_dot``, which fuses the per-channel
    dequant into the contraction.
    """
    if comp_tile != DEFAULT_TILE or isinstance(w, PackedWeight):
        tile = comp_tile
        if comp_tile == DEFAULT_TILE:
            # packed weight with backend-chosen blocking: cover the whole
            # problem (single dot over the dequantized codes)
            tile = (a.shape[-2], w.shape[-1], a.shape[-1])
        return blocked_dot(a, w, tile, accum=accum, out_dtype=out_dtype)
    out = _dot(a, w, accum=accum)
    return out.astype(out_dtype) if out_dtype is not None else out


def _w_cols(w, lo: int, hi: int):
    """Column-slice a weight operand (PackedWeight slices its scales too)."""
    if isinstance(w, PackedWeight):
        return w.col_slice(lo, hi)
    return w[..., lo:hi]


def _row_update(out, part, row):
    """dynamic_update_slice of ``part`` into dim -2 of ``out`` at ``row``."""
    idx = (0,) * (out.ndim - 2) + (row, 0)
    return lax.dynamic_update_slice(out, part, idx)


def _row_slice(x, row, m):
    """dynamic_slice of ``m`` rows from dim -2 at ``row``."""
    idx = (0,) * (x.ndim - 2) + (row, 0)
    sizes = x.shape[:-2] + (m, x.shape[-1])
    return lax.dynamic_slice(x, idx, sizes)


# -----------------------------------------------------------------------------
# AG + GEMM  (column-parallel producer/consumer pair)
# -----------------------------------------------------------------------------


def ag_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    axis: str,
    channel: Optional[BlockChannel] = None,
    out_dtype=None,
):
    """Overlapped AllGather(x) @ w.

    Per-shard shapes: ``x``: [..., m_loc, K] (sharded along M over ``axis``),
    ``w``: [K, n_loc].  Returns [..., R * m_loc, n_loc].

    Lowered as an "ag" tile plan: the local shard splits into
    ``channel.num_channels`` sub-chunks flowing independently per
    ``channel.comm.order`` (C in-flight transfers — the paper's f_C); each
    arrived tile is consumed by a GEMM accumulated in
    ``channel.comp.accum_dtype``.  With a quantized wire
    (``channel.quant``) each sub-chunk is quantized exactly once at entry
    and travels as int8/fp8 codes + scale; ``w`` may be a
    :class:`~repro.core.quant.PackedWeight` for weight-only dequant-GEMM.
    """
    channel = channel or BlockChannel(axis=axis)
    out_dtype = out_dtype or x.dtype
    m_loc, n_loc = x.shape[-2], w.shape[-1]
    plan = _plan_for("ag_matmul", channel, axis, m_loc)
    m_sub = m_loc // plan.num_channels
    accum = jnp.dtype(channel.comp.accum_dtype)
    comp_tile = tuple(channel.comp.tile)

    chunks = [_row_slice(x, c * m_sub, m_sub) for c in range(plan.num_channels)]
    out0 = jnp.zeros(x.shape[:-2] + (plan.world * m_loc, n_loc), dtype=out_dtype)

    def gemm_tile(ctx, tile, out):
        part = _consume_dot(tile, w, comp_tile, accum, out_dtype=out_dtype)
        # f_S: the tile covers rows [src * m_loc + c * m_sub, ...) globally
        return _row_update(out, part, ctx.src * m_loc + ctx.channel * m_sub)

    return run_plan(plan, gemm_tile, state=chunks, carry=out0)


def ag_matmul_baseline(x, w, *, axis: str, out_dtype=None):
    """Non-overlapping reference: operator-centric AllGather then GEMM."""
    out_dtype = out_dtype or x.dtype
    xg = lax.all_gather(x, axis, axis=x.ndim - 2, tiled=True)
    return _dot(xg, w).astype(out_dtype)


# -----------------------------------------------------------------------------
# GEMM + ring ReduceScatter  (paper Fig. 4)
# -----------------------------------------------------------------------------


def matmul_rs(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    axis: str,
    channel: Optional[BlockChannel] = None,
    out_dtype=None,
):
    """Overlapped (x @ w) reduce-scattered along M over ``axis``.

    Per-shard shapes: ``x``: [..., M, k_loc], ``w``: [k_loc, N];
    returns [..., M / R, N].

    Lowered as an "rs" tile plan (the time reversal of the order's source
    schedule — for "ring" exactly the paper's Fig. 4 ``seg=(r+s+1)%R``): at
    each step the executor fuses the arriving partial into this rank's GEMM
    tile for the scheduled segment, overlapping the in-flight permute with
    the GEMM.  ``num_channels`` chunks the N columns into independent flows;
    partials accumulate in ``channel.comp.accum_dtype`` — the dot PRODUCES
    that dtype natively (preferred_element_type) — and travel the wire per
    ``channel.quant`` (default: the accum dtype itself, so bf16 accum halves
    ring bytes; an int8/fp8 wire re-encodes the flowing accumulator at each
    send edge, quartering them).  ``w`` may be a
    :class:`~repro.core.quant.PackedWeight` for weight-only dequant-GEMM.
    """
    channel = channel or BlockChannel(axis=axis)
    out_dtype = out_dtype or x.dtype

    m_glob, n = x.shape[-2], w.shape[-1]
    plan = _plan_for("matmul_rs", channel, axis, n)
    assert m_glob % plan.world == 0, (m_glob, plan.world)
    m_loc = m_glob // plan.world
    n_sub = n // plan.num_channels
    accum = jnp.dtype(plan.accum_dtype)
    comp_tile = tuple(channel.comp.tile)

    def gemm_tile(ctx, _tile, _carry):
        xs = _row_slice(x, ctx.src * m_loc, m_loc)
        wc = _w_cols(w, ctx.channel * n_sub, (ctx.channel + 1) * n_sub)
        return _consume_dot(xs, wc, comp_tile, accum)

    accs = run_plan(plan, gemm_tile)
    out = accs[0] if plan.num_channels == 1 else jnp.concatenate(accs, axis=-1)
    return out.astype(out_dtype)


def matmul_rs_ag(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    w2: jnp.ndarray,
    *,
    axis: str,
    channel: Optional[BlockChannel] = None,
    channel2: Optional[BlockChannel] = None,
    residual: Optional[jnp.ndarray] = None,
    glue: Optional[Callable] = None,
    out_dtype=None,
):
    """Fused layer seam: ``matmul_rs(x, w1)`` flowing into ``ag_matmul(·, w2)``.

    Per-shard shapes: ``x``: [..., M, k_loc], ``w1``: [k_loc, N] (the RS
    producer — e.g. a down/out projection), ``w2``: [N, n2_loc] (the AG
    consumer — e.g. the next block's fused qkv or gate/up projection).
    Between the two sits the rank-local seam glue applied to the full
    [..., M/R, N] home segment:

        y = residual + matmul_rs(x, w1)      (residual optional)
        h = glue(y)                          (glue optional, row-preserving —
                                              e.g. the next block's rms_norm)

    Returns ``(y, ag_matmul(h, w2))`` — the residual-stream value plus the
    next op's gathered activation — with the intermediate never leaving the
    manual region and no operator collective at the seam (see
    :func:`run_seq_plan`).  Identical float ops to the unfused pair, so the
    results match it to the usual accumulation tolerance.

    Both halves must share the effective channel count (RS chunks the N
    columns, AG chunks the M/R rows); a mismatch raises ``ValueError`` —
    the ``compile_overlap`` seq form pre-checks and degrades loudly to the unfused
    pair instead of calling in.
    """
    channel = channel or BlockChannel(axis=axis)
    channel2 = channel2 or channel
    out_dtype = out_dtype or x.dtype

    m_glob, n_mid = x.shape[-2], w1.shape[-1]
    n2_loc = w2.shape[-1]
    world = axis_size(axis)
    assert m_glob % world == 0, (m_glob, world)
    m_loc = m_glob // world
    nch = effective_channels(n_mid, channel.num_channels, kind="matmul_rs")
    nch_ag = effective_channels(m_loc, channel2.num_channels, kind="ag_matmul")
    if nch != nch_ag:
        raise ValueError(
            f"matmul_rs_ag: seam channel counts diverge — RS extent {n_mid} "
            f"yields C={nch} but AG extent {m_loc} yields C={nch_ag}; use "
            "compile_overlap(['matmul_rs', 'ag_matmul']) for the loud unfused fallback"
        )
    seq = build_seq_plan(("matmul_rs", "ag_matmul"), (channel, channel2), world, nch)
    rs_plan, ag_plan = seq.ops
    n_sub = n_mid // nch
    m_sub = m_loc // nch
    accum = jnp.dtype(rs_plan.accum_dtype)
    accum2 = jnp.dtype(channel2.comp.accum_dtype)
    comp_tile = tuple(channel.comp.tile)
    comp_tile2 = tuple(channel2.comp.tile)

    def rs_tile(ctx, _tile, _carry):
        xs = _row_slice(x, ctx.src * m_loc, m_loc)
        wc = _w_cols(w1, ctx.channel * n_sub, (ctx.channel + 1) * n_sub)
        return _consume_dot(xs, wc, comp_tile, accum)

    def seam(accs, _carry):
        rs_out = accs[0] if nch == 1 else jnp.concatenate(accs, axis=-1)
        rs_out = rs_out.astype(out_dtype)
        y = rs_out if residual is None else residual + rs_out
        # glue needs full rows (e.g. rms_norm normalizes over all N columns),
        # so it runs on the complete home segment before the AG re-chunk —
        # the same float ops, in the same order, as the unfused pair
        h = y if glue is None else glue(y)
        state = [_row_slice(h, c * m_sub, m_sub) for c in range(nch)]
        out0 = jnp.zeros(h.shape[:-2] + (world * m_loc, n2_loc), dtype=h.dtype)
        return y, state, out0

    def ag_tile(ctx, tile, out):
        part = _consume_dot(tile, w2, comp_tile2, accum2, out_dtype=out.dtype)
        return _row_update(out, part, ctx.src * m_loc + ctx.channel * m_sub)

    return run_seq_plan(seq, rs_tile, seam, ag_tile)


def matmul_rs_baseline(x, w, *, axis: str, out_dtype=None):
    """Non-overlapping reference: GEMM then operator-centric ReduceScatter."""
    out_dtype = out_dtype or x.dtype
    part = _dot(x, w)
    out = lax.psum_scatter(part, axis, scatter_dimension=part.ndim - 2, tiled=True)
    return out.astype(out_dtype)


def psum_scatter_ring(x, *, axis: str, channel: Optional[BlockChannel] = None):
    """Ring reduce-scatter of a precomputed partial (no fused GEMM).

    Used for epilogue reductions (e.g. MoE combine) where the partials already
    exist; still overlaps the adds with the permutes (an "rs" plan whose tile
    compute is a row slice).
    """
    channel = channel or BlockChannel(axis=axis)
    m_glob, n = x.shape[-2], x.shape[-1]
    plan = _plan_for("psum_scatter", channel, axis, n)
    m_loc = m_glob // plan.world
    n_sub = n // plan.num_channels

    def slice_tile(ctx, _tile, _carry):
        seg = _row_slice(x, ctx.src * m_loc, m_loc)
        return seg[..., ctx.channel * n_sub : (ctx.channel + 1) * n_sub]

    accs = run_plan(plan, slice_tile)
    return accs[0] if plan.num_channels == 1 else jnp.concatenate(accs, axis=-1)


# -----------------------------------------------------------------------------
# AG-KV + self-attention  (paper Fig. 6) — sequence parallel
# -----------------------------------------------------------------------------


def ring_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    axis: str,
    causal: bool = False,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    channel: Optional[BlockChannel] = None,
    kv_select: bool = False,
):
    """Overlapped sequence-parallel attention with online softmax.

    Per-shard shapes: ``k``/``v``: [B, Hkv, s_loc, D] (sequence sharded over
    ``axis``); ``q``: [B, H, s_loc, D] (queries sharded alongside the KV) OR
    [B, H, R * s_loc, D] (queries already gathered — the AG-Q + ring-KV form
    the TP-sharded nn layer uses, where every rank attends the full query
    range with its local heads while only the KV rotates).  KV tiles rotate
    per the plan's order (``num_channels`` splits each shard's KV along the
    sequence into independent flows) while flash-style online softmax
    consumes each arrived tile — the TileLink AG-KV + flash-attention kernel
    with the AG mapped to the ICI DMA engine.  Online-softmax statistics
    stay fp32; the score and PV contractions accumulate in
    ``channel.comp.accum_dtype``.  A non-default ``channel.comp.tile``
    blocks the consumer: (tm, tk) become (block_q, block_kv), clamped to
    divisors of the query/KV extents — the same blocking
    ``kernels/flash_attention.py`` derives from a tile.

    ``causal`` masks with *global* positions (rank-offset aware).
    ``window`` (sliding-window attention) masks keys outside the window.

    ``kv_select=True`` is the per-KV-group GQA ring: the rotating tiles
    carry ALL ``Hkv`` distinct KV head groups (every rank projects the full
    deduped KV width on its sequence shard), and each rank's online softmax
    consumes only the group its local query heads map to.  With
    ``Hkv >= world`` rank r takes groups ``[r*Hkv/world, (r+1)*Hkv/world)``;
    with ``Hkv < world`` each group is shared by ``world/Hkv`` consecutive
    ranks.
    """
    channel = channel or BlockChannel(axis=axis)
    rank = lax.axis_index(axis)
    b, h, sq, d = q.shape
    hkv, s_loc = k.shape[1], k.shape[2]
    scale = scale if scale is not None else d**-0.5

    plan = _plan_for("ag_attention", channel, axis, s_loc)
    if kv_select:
        kv_need = max(1, hkv // plan.world)
        share = max(1, plan.world // hkv)  # ranks sharing one group
        grp_start = (rank // share) * kv_need
        rep = h // kv_need
    else:
        kv_need, grp_start = hkv, None
        rep = h // hkv
    if sq == s_loc:
        q_off = rank * s_loc  # queries sharded like the KV: rank offset
    elif sq == plan.world * s_loc:
        q_off = 0  # gathered queries: the full global range
    else:
        raise ValueError(
            f"ring_attention: query rows {sq} must equal the KV shard rows "
            f"{s_loc} or the gathered extent {plan.world * s_loc}"
        )
    s_sub = s_loc // plan.num_channels
    accum = jnp.dtype(channel.comp.accum_dtype)
    comp_tile = tuple(channel.comp.tile)
    if comp_tile != DEFAULT_TILE:
        # CompSpec tile: (tm, ·, tk) -> (block_q, block_kv), clamped by the
        # same largest-divisor rule every consumer applies; the default
        # sentinel keeps the whole-chunk update below
        bq = largest_divisor(sq, comp_tile[0])
        bk = largest_divisor(s_sub, comp_tile[2])
    else:
        bq, bk = sq, s_sub

    q32 = (q * scale).astype(jnp.float32)
    m_i = jnp.full((b, h, sq, 1), -jnp.inf, jnp.float32)
    l_i = jnp.zeros((b, h, sq, 1), jnp.float32)
    o_i = jnp.zeros((b, h, sq, d), jnp.float32)

    q_pos = q_off + jnp.arange(sq)  # global query positions

    chunks = [
        (k[:, :, c * s_sub : (c + 1) * s_sub], v[:, :, c * s_sub : (c + 1) * s_sub])
        for c in range(plan.num_channels)
    ]

    def online_update(q_blk, qp, kr, vr, kp, carry):
        """One (block_q, block_kv) online-softmax update of (m, l, o)."""
        m_i, l_i, o_i = carry
        scores = jnp.einsum(
            "bhqd,bhkd->bhqk",
            q_blk,
            kr.astype(jnp.float32),
            preferred_element_type=accum,
        ).astype(jnp.float32)
        mask = None
        if causal:
            mask = qp[:, None] >= kp[None, :]
        if window is not None:
            wmask = (qp[:, None] - kp[None, :]) < window
            mask = wmask if mask is None else (mask & wmask)
        if mask is not None:
            scores = jnp.where(mask[None, None], scores, -jnp.inf)

        m_new = jnp.maximum(m_i, scores.max(axis=-1, keepdims=True))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(jnp.where(jnp.isfinite(scores), scores - m_safe, -jnp.inf))
        alpha = jnp.exp(jnp.where(jnp.isfinite(m_i), m_i - m_safe, -jnp.inf))
        l_new = l_i * alpha + p.sum(axis=-1, keepdims=True)
        o_new = o_i * alpha + jnp.einsum(
            "bhqk,bhkd->bhqd",
            p,
            vr.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, o_new

    def softmax_tile(ctx, kv, carry):
        kc, vc = kv
        k_pos = ctx.src * s_loc + ctx.channel * s_sub + jnp.arange(s_sub)
        if kv_select and kv_need < hkv:
            kc = lax.dynamic_slice_in_dim(kc, grp_start, kv_need, axis=1)
            vc = lax.dynamic_slice_in_dim(vc, grp_start, kv_need, axis=1)
        kr = jnp.repeat(kc, rep, axis=1) if rep > 1 else kc
        vr = jnp.repeat(vc, rep, axis=1) if rep > 1 else vc
        if bq == sq and bk == s_sub:
            return online_update(q32, q_pos, kr, vr, k_pos, carry)
        # blocked consumer (the tuned CompSpec half): query blocks update
        # independently; KV blocks fold sequentially through the same
        # online-softmax rescaling, so any (bq, bk) computes the same result
        m_i, l_i, o_i = carry
        m_out, l_out, o_out = [], [], []
        for qi in range(sq // bq):
            qs = slice(qi * bq, (qi + 1) * bq)
            blk = (m_i[:, :, qs], l_i[:, :, qs], o_i[:, :, qs])
            for ki in range(s_sub // bk):
                ks = slice(ki * bk, (ki + 1) * bk)
                blk = online_update(
                    q32[:, :, qs], q_pos[qs], kr[:, :, ks], vr[:, :, ks], k_pos[ks], blk
                )
            m_out.append(blk[0])
            l_out.append(blk[1])
            o_out.append(blk[2])
        def cat(xs):
            return xs[0] if len(xs) == 1 else jnp.concatenate(xs, axis=2)

        return cat(m_out), cat(l_out), cat(o_out)

    m_f, l_f, o_f = run_plan(plan, softmax_tile, state=chunks, carry=(m_i, l_i, o_i))
    out = o_f / jnp.maximum(l_f, 1e-30)
    return out.astype(q.dtype)


def ag_attention_baseline(
    q,
    k,
    v,
    *,
    axis: str,
    causal: bool = False,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    kv_select: bool = False,
):
    """Non-overlapping reference: AllGather full KV, then one dense attention."""
    rank = lax.axis_index(axis)
    world = lax.psum(1, axis)
    b, h, sq, d = q.shape
    s_loc = k.shape[2]
    kg = lax.all_gather(k, axis, axis=2, tiled=True)
    vg = lax.all_gather(v, axis, axis=2, tiled=True)
    hkv = kg.shape[1]
    if kv_select and world > 1:
        # per-KV-group GQA: keep only this rank's head group of the
        # full-width gathered KV (mirrors ring_attention's kv_select)
        kv_need = max(1, hkv // world)
        share = max(1, world // hkv)
        grp_start = (lax.axis_index(axis) // share) * kv_need
        kg = lax.dynamic_slice_in_dim(kg, grp_start, kv_need, axis=1)
        vg = lax.dynamic_slice_in_dim(vg, grp_start, kv_need, axis=1)
    rep = h // kg.shape[1]
    if rep > 1:
        kg = jnp.repeat(kg, rep, axis=1)
        vg = jnp.repeat(vg, rep, axis=1)
    scale = scale if scale is not None else d**-0.5
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk",
        (q * scale).astype(jnp.float32),
        kg.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    s_glob = kg.shape[2]
    # queries either sharded alongside the KV (rank offset) or pre-gathered
    q_off = 0 if sq == s_glob else rank * s_loc
    q_pos = q_off + jnp.arange(sq)
    k_pos = jnp.arange(s_glob)
    mask = None
    if causal:
        mask = q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        wmask = (q_pos[:, None] - k_pos[None, :]) < window
        mask = wmask if mask is None else (mask & wmask)
    if mask is not None:
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
    m = scores.max(axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(scores - m)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vg.astype(jnp.float32)) / jnp.maximum(
        p.sum(axis=-1, keepdims=True), 1e-30
    )
    return out.astype(q.dtype)
