"""XLA overlap backend — tile-granular compute/communication overlap in shard_map.

This module lowers TileLink tile programs to JAX/XLA:TPU primitives.  The paper's
resource-mapping choice "communication on the copy engine" is realized by
expressing the producer/consumer tile graph as SSA dataflow over
``lax.ppermute`` steps: XLA:TPU's latency-hiding scheduler issues each
``collective-permute-start`` on the ICI DMA engines and overlaps it with the MXU
compute of the previously received tile.  The paper's barriers become SSA data
dependencies — release/acquire consistency is structural (a tile's matmul
consumes exactly the permuted value, so it can never be hoisted above the
"wait"), which satisfies §4.2 of the paper by construction.

Every function here is a *per-shard* function: call it inside ``shard_map`` (the
model layers do), or through the ``shard_mapped`` convenience wrapper.

Functions come in paper-faithful pairs:

  non-overlapping baseline            overlapped (TileLink)
  ----------------------------------  -------------------------------------
  ag_matmul_baseline                  ag_matmul          (AG + GEMM)
  matmul_rs_baseline                  matmul_rs          (GEMM + ring RS, Fig. 4)
  ag_attention_baseline               ring_attention     (AG-KV + attn, Fig. 6)
  ag_moe_baseline                     ag_moe             (AG + MoE, Fig. 5)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.backend import axis_size
from repro.core.channels import BlockChannel

__all__ = [
    "ag_matmul", "ag_matmul_baseline",
    "matmul_rs", "matmul_rs_baseline",
    "ring_attention", "ag_attention_baseline",
    "psum_scatter_ring",
]


def _dot(a, b, accum=jnp.float32):
    """MXU-friendly contraction of the last dim of a with first dim of b."""
    return lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())), preferred_element_type=accum
    )


def _row_update(out, part, row):
    """dynamic_update_slice of ``part`` into dim -2 of ``out`` at ``row``."""
    idx = (0,) * (out.ndim - 2) + (row, 0)
    return lax.dynamic_update_slice(out, part, idx)


def _row_slice(x, row, m):
    """dynamic_slice of ``m`` rows from dim -2 at ``row``."""
    idx = (0,) * (x.ndim - 2) + (row, 0)
    sizes = x.shape[:-2] + (m, x.shape[-1])
    return lax.dynamic_slice(x, idx, sizes)


# -----------------------------------------------------------------------------
# AG + GEMM  (column-parallel producer/consumer pair)
# -----------------------------------------------------------------------------

def ag_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    axis: str,
    channel: Optional[BlockChannel] = None,
    out_dtype=None,
):
    """Overlapped AllGather(x) @ w.

    Per-shard shapes: ``x``: [..., m_loc, K] (sharded along M over ``axis``),
    ``w``: [K, n_loc].  Returns [..., R * m_loc, n_loc].

    Ring schedule: at step ``s`` the chunk that originated at rank ``(r - s) % R``
    is multiplied while the next chunk is in flight on the ICI ring
    (``lax.ppermute`` to the right neighbour).  With ``channel.num_channels = C``
    the local shard is split into C sub-chunks ringed independently — C in-flight
    DMAs, the paper's channel mapping f_C.  ``comm.order == "bidir_ring"`` splits
    chunks into two counter-rotating rings, halving ring latency.
    """
    channel = channel or BlockChannel(axis=axis)
    out_dtype = out_dtype or x.dtype
    r_axis = axis_size(axis)
    rank = lax.axis_index(axis)

    m_loc, k_dim = x.shape[-2], x.shape[-1]
    n_loc = w.shape[-1]

    num_ch = max(1, channel.num_channels)
    bidir = channel.comm.order == "bidir_ring" and r_axis > 2
    if bidir and num_ch % 2:
        num_ch *= 2
    if m_loc % num_ch:
        num_ch = 1  # fall back: indivisible chunking
        bidir = False
    m_sub = m_loc // num_ch

    fwd = [(j, (j + 1) % r_axis) for j in range(r_axis)]
    bwd = [(j, (j - 1) % r_axis) for j in range(r_axis)]

    out = jnp.zeros(x.shape[:-2] + (r_axis * m_loc, n_loc), dtype=out_dtype)
    # chunks[c] currently held sub-chunk of channel c (leading dims preserved
    # so DP/FSDP-sharded batch dims partition cleanly)
    chunks = [_row_slice(x, c * m_sub, m_sub) for c in range(num_ch)]
    # direction per channel: bidir splits channels across the two rings
    dirs = [(-1 if (bidir and c % 2) else 1) for c in range(num_ch)]

    for s in range(r_axis):
        nxt = []
        if s < r_axis - 1:
            # producer: issue all channel DMAs for step s+1 (tile_push_data)
            for c in range(num_ch):
                nxt.append(lax.ppermute(chunks[c], axis, fwd if dirs[c] > 0 else bwd))
        # consumer: compute on the tiles received at step s (consumer_tile_wait is
        # the SSA dependence on chunks[c])
        for c in range(num_ch):
            src = (rank - s * dirs[c]) % r_axis  # f_R^{-1} of the held tile
            part = _dot(chunks[c], w).astype(out_dtype)
            out = _row_update(out, part, src * m_loc + c * m_sub)
        if s < r_axis - 1:
            chunks = nxt

    return out


def ag_matmul_baseline(x, w, *, axis: str, out_dtype=None):
    """Non-overlapping reference: operator-centric AllGather then GEMM."""
    out_dtype = out_dtype or x.dtype
    xg = lax.all_gather(x, axis, axis=x.ndim - 2, tiled=True)
    return _dot(xg, w).astype(out_dtype)


# -----------------------------------------------------------------------------
# GEMM + ring ReduceScatter  (paper Fig. 4)
# -----------------------------------------------------------------------------

def matmul_rs(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    axis: str,
    channel: Optional[BlockChannel] = None,
    out_dtype=None,
):
    """Overlapped (x @ w) reduce-scattered along M over ``axis``.

    Per-shard shapes: ``x``: [..., M, k_loc], ``w``: [k_loc, N];
    returns [..., M / R, N].

    Faithful port of the paper's Fig. 4 ring: at stage ``s`` rank ``r`` computes
    the GEMM tile for segment ``(r + s + 1) % R`` (schedules.ring_rs_segment),
    adds the partial accumulator arriving from rank ``r + 1``, and forwards the
    sum to rank ``r - 1`` — the stage-s GEMM overlaps the in-flight permute of
    the stage-(s-1) accumulator.  After R stages the accumulator at rank ``r``
    holds the fully reduced segment ``r``.
    """
    channel = channel or BlockChannel(axis=axis)
    r_axis = axis_size(axis)
    rank = lax.axis_index(axis)
    out_dtype = out_dtype or x.dtype

    m_glob, k_loc = x.shape[-2], x.shape[-1]
    assert m_glob % r_axis == 0, (m_glob, r_axis)
    m_loc = m_glob // r_axis

    to_left = [(j, (j - 1) % r_axis) for j in range(r_axis)]  # paper: to_rank = r-1

    # flow dtype of the ring partials: fp32 (default, reduction-exact) or bf16
    # (halves ring bytes — §Perf optimization).  The partial dot must PRODUCE
    # the flow dtype natively (preferred_element_type): a separate convert is
    # commuted past the permute by XLA's algebraic simplifier, leaving fp32 on
    # the wire.
    flow = jnp.dtype(channel.comp.accum_dtype)

    acc = None
    for s in range(r_axis):
        seg = (rank + s + 1) % r_axis
        xs = _row_slice(x, seg * m_loc, m_loc)
        part = lax.dot_general(
            xs, w, (((xs.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=flow)
        if acc is None:
            acc = part
        else:
            acc = lax.ppermute(acc, axis, to_left) + part  # peer_tile_wait/notify
    return acc.astype(out_dtype)


def matmul_rs_baseline(x, w, *, axis: str, out_dtype=None):
    """Non-overlapping reference: GEMM then operator-centric ReduceScatter."""
    out_dtype = out_dtype or x.dtype
    part = _dot(x, w)
    out = lax.psum_scatter(part, axis, scatter_dimension=part.ndim - 2, tiled=True)
    return out.astype(out_dtype)


def psum_scatter_ring(x, *, axis: str):
    """Ring reduce-scatter of a precomputed partial (no fused GEMM).

    Used for epilogue reductions (e.g. MoE combine) where the partials already
    exist; still overlaps the adds with the permutes.
    """
    r_axis = axis_size(axis)
    rank = lax.axis_index(axis)
    m_glob = x.shape[-2]
    m_loc = m_glob // r_axis
    to_left = [(j, (j - 1) % r_axis) for j in range(r_axis)]
    acc = None
    for s in range(r_axis):
        seg = (rank + s + 1) % r_axis
        part = _row_slice(x, seg * m_loc, m_loc)
        acc = part if acc is None else lax.ppermute(acc, axis, to_left) + part
    return acc


# -----------------------------------------------------------------------------
# AG-KV + self-attention  (paper Fig. 6) — sequence parallel
# -----------------------------------------------------------------------------

def ring_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    axis: str,
    causal: bool = False,
    scale: Optional[float] = None,
    window: Optional[int] = None,
):
    """Overlapped sequence-parallel attention with online softmax.

    Per-shard shapes: ``q``: [B, H, s_loc, D], ``k``/``v``: [B, Hkv, s_loc, D]
    (sequence sharded over ``axis``).  KV chunks rotate around the ring while
    flash-style online softmax consumes each arrived chunk — the TileLink AG-KV
    + flash-attention kernel with the AG mapped to the ICI DMA engine.

    ``causal`` masks with *global* positions (rank-offset aware).
    ``window`` (sliding-window attention) skips ring steps entirely outside the
    window — chunks whose global key range cannot attend are never computed.
    """
    r_axis = axis_size(axis)
    rank = lax.axis_index(axis)
    b, h, s_loc, d = q.shape
    hkv = k.shape[1]
    rep = h // hkv
    scale = scale if scale is not None else d ** -0.5

    fwd = [(j, (j + 1) % r_axis) for j in range(r_axis)]

    q32 = (q * scale).astype(jnp.float32)
    m_i = jnp.full((b, h, s_loc, 1), -jnp.inf, jnp.float32)
    l_i = jnp.zeros((b, h, s_loc, 1), jnp.float32)
    o_i = jnp.zeros((b, h, s_loc, d), jnp.float32)

    q_pos = rank * s_loc + jnp.arange(s_loc)  # global query positions

    kc, vc = k, v
    for s in range(r_axis):
        src = (rank - s) % r_axis
        if s < r_axis - 1:
            k_nxt = lax.ppermute(kc, axis, fwd)
            v_nxt = lax.ppermute(vc, axis, fwd)
        k_pos = src * s_loc + jnp.arange(s_loc)

        kr = jnp.repeat(kc, rep, axis=1) if rep > 1 else kc
        vr = jnp.repeat(vc, rep, axis=1) if rep > 1 else vc
        scores = jnp.einsum(
            "bhqd,bhkd->bhqk", q32, kr.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        mask = None
        if causal:
            mask = q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            wmask = (q_pos[:, None] - k_pos[None, :]) < window
            mask = wmask if mask is None else (mask & wmask)
        if mask is not None:
            scores = jnp.where(mask[None, None], scores, -jnp.inf)

        m_new = jnp.maximum(m_i, scores.max(axis=-1, keepdims=True))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(jnp.where(jnp.isfinite(scores), scores - m_safe, -jnp.inf))
        alpha = jnp.exp(jnp.where(jnp.isfinite(m_i), m_i - m_safe, -jnp.inf))
        l_i = l_i * alpha + p.sum(axis=-1, keepdims=True)
        o_i = o_i * alpha + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vr.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        m_i = m_new
        if s < r_axis - 1:
            kc, vc = k_nxt, v_nxt

    out = o_i / jnp.maximum(l_i, 1e-30)
    return out.astype(q.dtype)


def ag_attention_baseline(q, k, v, *, axis: str, causal: bool = False,
                          scale: Optional[float] = None, window: Optional[int] = None):
    """Non-overlapping reference: AllGather full KV, then one dense attention."""
    r_axis = axis_size(axis)
    rank = lax.axis_index(axis)
    b, h, s_loc, d = q.shape
    kg = lax.all_gather(k, axis, axis=2, tiled=True)
    vg = lax.all_gather(v, axis, axis=2, tiled=True)
    rep = h // kg.shape[1]
    if rep > 1:
        kg = jnp.repeat(kg, rep, axis=1)
        vg = jnp.repeat(vg, rep, axis=1)
    scale = scale if scale is not None else d ** -0.5
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", (q * scale).astype(jnp.float32), kg.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    s_glob = kg.shape[2]
    q_pos = rank * s_loc + jnp.arange(s_loc)
    k_pos = jnp.arange(s_glob)
    mask = None
    if causal:
        mask = q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        wmask = (q_pos[:, None] - k_pos[None, :]) < window
        mask = wmask if mask is None else (mask & wmask)
    if mask is not None:
        scores = jnp.where(mask[None, None], scores, -jnp.inf)
    m = scores.max(axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(scores - m)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vg.astype(jnp.float32)) / jnp.maximum(
        p.sum(axis=-1, keepdims=True), 1e-30
    )
    return out.astype(q.dtype)
