"""QuantSpec — the wire-dtype half of the design space, split from accumulation.

Historically ``CompSpec.accum_dtype`` meant two things at once: the dtype
partial reductions accumulate in AND the dtype tiles/partials travel the wire
in.  That conflation made int8/fp8 flows and weight-only dequant-GEMM — the
flagship pairing in tile-lang's exemplars — unreachable: the tuner could
never price a quantized wire because the IR had no word for it.

:class:`QuantSpec` is that word.  It rides :class:`~repro.core.channels.BlockChannel`
next to ``CommSpec``/``CompSpec`` and describes ONLY what travels:

  ``wire_dtype``     what tiles / flowing partials travel the wire in.
                     ``None`` (default) inherits ``CompSpec.accum_dtype`` —
                     the pre-split behavior, bitwise identical (the encode /
                     decode edges are literal identity functions, not casts).
                     A float wire ("bfloat16") is a cast at the send edge; a
                     quantized wire ("int8", fp8 where the backend has it)
                     sends scaled integer payloads with their scales riding
                     the same permute (``WirePayload``).
  ``granularity``    scale granularity for quantized wires: "per_tile" (one
                     scale per flowing tile — each tile is quantized exactly
                     ONCE at its send edge, so end-to-end error is independent
                     of world size) or "per_channel" (one scale per trailing
                     output channel — tighter for skewed activations).
  ``weight_dtype``   optional weight-only quantization ("int8" | "int4"):
                     weights are packed once (:func:`pack_weight`) and
                     dequantized per-tile INSIDE the consumer GEMM
                     (``core/comp_tiles.blocked_dot``; in VMEM before the MXU
                     on the Pallas backend) — bytes-on-wire AND VMEM both drop.
  ``zero_point``     asymmetric weight quantization (per-channel zero points);
                     only meaningful with ``weight_dtype``.

``accum_dtype`` reverts to meaning only the reduction dtype.  The executors
quantize at the send edge and dequantize fused into the per-tile compute
callbacks; reductions always accumulate in ``accum_dtype``.

This module is also the ONE quantization codepath in the tree:
``training/compression.py``'s gradient compression re-exports
:func:`quantize_int8` / :func:`dequantize_int8` from here.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "QuantSpec",
    "WirePayload",
    "PackedWeight",
    "WIRE_DTYPES",
    "GRANULARITIES",
    "WEIGHT_DTYPES",
    "quantize_int8",
    "dequantize_int8",
    "quantize",
    "dequantize",
    "encode_tree",
    "decode_tree",
    "pack_weight",
    "dequantize_weight",
    "wire_itemsize",
]

_FP8 = getattr(jnp, "float8_e4m3fn", None)

# float wires are casts; quantized wires carry scales
_FLOAT_WIRES = ("float32", "bfloat16", "float16")
_QUANT_WIRES = ("int8",) + (("float8_e4m3fn",) if _FP8 is not None else ())
WIRE_DTYPES = _FLOAT_WIRES + _QUANT_WIRES
GRANULARITIES = ("per_tile", "per_channel")
WEIGHT_DTYPES = ("int8", "int4")

# symmetric ranges: int8 uses +/-127 (matches the gradient-compression
# contract pinned in test_properties.py); fp8 e4m3 saturates at 448
_QMAX = {"int8": 127.0, "float8_e4m3fn": 448.0}
_WEIGHT_QMAX = {"int8": 127.0, "int4": 7.0}


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Wire/flow dtype descriptor — validated at construction."""

    wire_dtype: Optional[str] = None
    granularity: str = "per_tile"
    weight_dtype: Optional[str] = None
    zero_point: bool = False

    def __post_init__(self):
        if self.wire_dtype is not None and self.wire_dtype not in WIRE_DTYPES:
            raise ValueError(
                f"unsupported wire_dtype {self.wire_dtype!r}; "
                f"supported: {WIRE_DTYPES} (None inherits accum_dtype)")
        if self.granularity not in GRANULARITIES:
            raise ValueError(
                f"unsupported quant granularity {self.granularity!r}; "
                f"supported: {GRANULARITIES}")
        if self.weight_dtype is not None and self.weight_dtype not in WEIGHT_DTYPES:
            raise ValueError(
                f"unsupported weight_dtype {self.weight_dtype!r}; "
                f"supported: {WEIGHT_DTYPES} (None = full-precision weights)")
        if self.zero_point and self.weight_dtype is None:
            raise ValueError(
                "zero_point=True is only meaningful with weight_dtype set "
                "(asymmetric weight-only quantization)")

    # ---- derived ---------------------------------------------------------
    @property
    def is_quantized(self) -> bool:
        """True when the wire carries scaled integer/fp8 payloads."""
        return self.wire_dtype in _QUANT_WIRES

    def resolve_wire(self, accum_dtype: str) -> str:
        """The dtype that actually travels, given the reduction dtype."""
        return self.wire_dtype if self.wire_dtype is not None else str(
            jnp.dtype(accum_dtype))

    def is_identity(self, accum_dtype: str) -> bool:
        """True when encode/decode are no-ops (bitwise-identical path)."""
        return self.resolve_wire(accum_dtype) == str(jnp.dtype(accum_dtype))

    def scale_slots(self, flow: str, world: int, num_channels: int,
                    steps: int) -> int:
        """Scale-table coverage the executors allocate for a quantized wire.

        One scale per quantize site: "ag" tiles are quantized ONCE at their
        origin (world x C slots); flowing reductions ("rs", and the rs halves
        of "ag_rs"/"a2a_rs") are re-encoded at every send edge
        ((steps - 1) x C slots).  The verifier checks this coverage against
        the plan's schedule (analysis/verify.check_quant).
        """
        if not self.is_quantized:
            return 0
        if flow == "ag":
            return world * num_channels
        if flow in ("rs", "a2a"):
            return max(0, steps - 1) * num_channels
        if flow in ("ag_rs", "a2a_rs"):  # tiles AND a flowing reduction
            return world * num_channels + max(0, steps - 1) * num_channels
        raise ValueError(f"unknown flow kind {flow!r}")


# ---- wire payloads ---------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class WirePayload:
    """A quantized tile on the wire: integer payload + its scale(s).

    Registered as a pytree so the generic executor's ``ppermute`` tree-maps
    straight through it — the scales ride the same permute as the payload,
    exactly like the a2a routing tables ride the token tiles.
    """

    q: Any
    scale: Any

    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedWeight:
    """A weight tensor packed for weight-only dequant-GEMM.

    ``q``: integer codes (int4 codes live in an int8 container), same shape
    as the source weight [k, n].  ``scale``: per-output-channel scales [n].
    ``zero``: per-output-channel zero points [n] (asymmetric) or None.
    ``dtype``: the logical code dtype ("int8" | "int4") — aux data, so two
    packings with different code widths never compare pytree-equal.
    """

    q: Any
    scale: Any
    zero: Any = None
    dtype: str = "int8"

    @property
    def shape(self):
        return self.q.shape

    def col_slice(self, lo: int, hi: int) -> "PackedWeight":
        """The packed view of ``w[..., lo:hi]`` (scales/zeros are per-column)."""
        return PackedWeight(
            self.q[..., lo:hi], self.scale[lo:hi],
            None if self.zero is None else self.zero[lo:hi], self.dtype)

    def tree_flatten(self):
        return (self.q, self.scale, self.zero), self.dtype

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], children[2], aux)


# ---- the one quantization codepath ----------------------------------------


def quantize_int8(g) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8: (codes, float32 scale).

    The gradient-compression primitive (scale floor 1e-12, +/-127 clip) —
    semantics pinned by ``tests/test_properties.py``'s error-feedback bound.
    """
    scale = jnp.maximum(jnp.abs(g).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def quantize(x, wire_dtype: str, granularity: str = "per_tile"
             ) -> WirePayload:
    """Symmetric absmax quantization of one flowing tile.

    "per_tile": one scalar scale for the whole tile.  "per_channel": one
    scale per trailing output channel (reduced over every other axis), shape
    ``x.shape[-1:]`` — broadcasts cleanly against the payload at dequant.
    """
    qmax = _QMAX[wire_dtype]
    x32 = x.astype(jnp.float32)
    if granularity == "per_channel" and x.ndim >= 1:
        absmax = jnp.abs(x32).max(axis=tuple(range(x.ndim - 1)))
    else:
        absmax = jnp.abs(x32).max()
    scale = (jnp.maximum(absmax, 1e-12) / qmax).astype(jnp.float32)
    if wire_dtype == "int8":
        q = jnp.clip(jnp.round(x32 / scale), -qmax, qmax).astype(jnp.int8)
    else:  # fp8: the cast itself rounds; scaling keeps the payload in range
        q = (x32 / scale).astype(_FP8)
    return WirePayload(q, scale)


def dequantize(payload: WirePayload, dtype) -> jnp.ndarray:
    return (payload.q.astype(jnp.float32) * payload.scale).astype(dtype)


# ---- send-edge encode / receive-edge decode (executor hooks) ---------------


def encode_tree(tree, spec: QuantSpec, accum_dtype):
    """Encode a pytree of flowing values for the wire.

    Identity (bitwise) when the wire inherits ``accum_dtype``; a cast for a
    float wire; quantized :class:`WirePayload` leaves for int8/fp8 — the
    scales travel with the payloads through the same ``ppermute``.  Non-float
    leaves (e.g. a2a routing tables riding the token tiles) pass through
    untouched.
    """
    if spec.is_identity(accum_dtype):
        return tree
    wire = jnp.dtype(spec.resolve_wire(accum_dtype)) if not spec.is_quantized else None

    def enc(a):
        if not jnp.issubdtype(jnp.result_type(a), jnp.floating):
            return a
        if spec.is_quantized:
            return quantize(a, spec.wire_dtype, spec.granularity)
        return a.astype(wire)

    return jax.tree_util.tree_map(enc, tree)


def decode_tree(tree, spec: QuantSpec, accum_dtype):
    """Inverse of :func:`encode_tree`, back to the reduction dtype."""
    if spec.is_identity(accum_dtype):
        return tree
    dt = jnp.dtype(accum_dtype)

    def dec(v):
        if isinstance(v, WirePayload):
            return dequantize(v, dt)
        if not jnp.issubdtype(jnp.result_type(v), jnp.floating):
            return v
        return v.astype(dt)

    return jax.tree_util.tree_map(
        dec, tree, is_leaf=lambda v: isinstance(v, WirePayload))


# ---- weight-only packing ---------------------------------------------------


def pack_weight(w, spec: QuantSpec) -> PackedWeight:
    """Pack a [k, n] weight for weight-only dequant-GEMM.

    Per-output-channel scales (axis -1).  Symmetric by default; with
    ``spec.zero_point`` the full asymmetric range is used (min/max affine),
    which matters for int4's 16 codes.  Codes are stored in an int8
    container either way — dequant happens per-tile inside the GEMM, so no
    packed-nibble arithmetic is ever needed.
    """
    if spec.weight_dtype is None:
        raise ValueError("pack_weight requires QuantSpec.weight_dtype")
    qmax = _WEIGHT_QMAX[spec.weight_dtype]
    w32 = w.astype(jnp.float32)
    axes = tuple(range(w.ndim - 1))
    if spec.zero_point:
        lo = w32.min(axis=axes)
        hi = w32.max(axis=axes)
        scale = jnp.maximum(hi - lo, 1e-12) / (2.0 * qmax)
        zero = jnp.round(-qmax - lo / scale)
        q = jnp.clip(jnp.round(w32 / scale) + zero, -qmax - 1, qmax)
    else:
        scale = jnp.maximum(jnp.abs(w32).max(axis=axes), 1e-12) / qmax
        zero = None
        q = jnp.clip(jnp.round(w32 / scale), -qmax, qmax)
    return PackedWeight(q.astype(jnp.int8), scale.astype(jnp.float32),
                        None if zero is None else zero.astype(jnp.float32),
                        spec.weight_dtype)


def dequantize_weight(q, scale, zero=None, dtype=jnp.float32):
    """Dequantize weight codes (or any [k-slice, n-slice] block of them).

    The per-tile form of this runs inside ``blocked_dot`` — in VMEM before
    the MXU on the Pallas backend.
    """
    w = q.astype(jnp.float32)
    if zero is not None:
        w = w - zero
    return (w * scale).astype(dtype)


def wire_itemsize(wire_dtype: str) -> int:
    """Bytes per element on the wire — what the cost model prices."""
    return jnp.dtype(wire_dtype).itemsize
