"""Tile-centric mappings (paper §4.1).

TileLink's backend links communication and computation through three mappings:

  f_S : tile_id -> shape range  (which slice of the global tensor a tile covers)
  f_R : tile_id -> rank         (which device owns / produces the tile)
  f_C : tile_id -> channel      (which barrier/semaphore channel guards the tile)

Mappings come in two flavors:

  * **Static** (affine, decidable at compile/trace time) — used for fixed sharding
    such as tensor-parallel MLP and sequence-parallel attention.  Implemented with
    the exact affine formulas of the paper:

        M_per_rank    = ceil(M / R)
        M_per_channel = ceil(M / (R * C))
        range_M       = [tile_id * Tm, tile_id * Tm + Tm)
        src_rank      = floor(tile_id / floor(M_per_rank / Tm))
        channel       = floor(tile_id / floor(M_per_channel / Tm))

  * **Dynamic** (lookup tables filled at runtime) — required when the sharding is
    data-dependent (MoE routing).  The *access pattern* to the tables is fixed at
    trace time; the table *values* are runtime tensors.

Every function exists in two forms: a Python-int form (used while building static
schedules at trace time) and a traced ``jnp`` form (used inside kernels / jitted
code, including Pallas kernel bodies).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Tuple

import jax.numpy as jnp

from repro.core.comp_tiles import largest_divisor

__all__ = ["StaticTileMapping", "DynamicTileMapping", "cdiv", "effective_channels"]


def cdiv(a: int, b: int) -> int:
    """Ceiling division (host-side)."""
    return -(-a // b)


# fallbacks already reported, keyed (kind, extent, requested) — autotune
# sweeps probe the same infeasible counts hundreds of times per trace, and
# one line per unique clamp is signal where one line per call is spam
_WARNED_CLAMPS = set()


def effective_channels(extent: int, requested: int, *, kind: str = "", warn: bool = True) -> int:
    """f_C feasibility: largest channel count <= ``requested`` dividing ``extent``.

    The affine channel mapping needs C | extent (each channel owns an equal
    sub-chunk).  When the requested C does not divide, fall back to the largest
    divisor <= C — never silently to 1 — and warn once per unique
    (kind, extent, requested) clamp so sweeps notice without drowning in
    repeats.  ``warn=False`` is for feasibility *probes* (the candidate
    enumerator) that expect clamping and must not consume the one-shot
    warning a later runtime fallback should still emit.
    """
    req = max(1, int(requested))
    # ONE clamping rule for both halves of the design space: the comm half
    # here, the compute half in comp_tiles.resolve_tile
    c = largest_divisor(extent, req)
    if c != req and warn:
        key = (kind, int(extent), req)
        if key not in _WARNED_CLAMPS:
            _WARNED_CLAMPS.add(key)
            warnings.warn(
                f"{kind or 'tile plan'}: num_channels={requested} does not divide "
                f"extent {extent}; using largest divisor {c}",
                stacklevel=2,
            )
    return c


@dataclasses.dataclass(frozen=True)
class StaticTileMapping:
    """Affine tile-centric mapping over a 1-D sharded dimension of extent ``dim``.

    Args:
      dim:          global extent of the sharded dimension (paper's M).
      tile:         producer tile size along the dimension (paper's Tm_p).
      world_size:   number of ranks R.
      num_channels: barrier channels per rank C (paper's channel mapping).
    """

    dim: int
    tile: int
    world_size: int
    num_channels: int = 1

    # ---- derived (host ints) -------------------------------------------------
    @property
    def per_rank(self) -> int:
        return cdiv(self.dim, self.world_size)

    @property
    def per_channel(self) -> int:
        return cdiv(self.dim, self.world_size * self.num_channels)

    @property
    def tiles_per_rank(self) -> int:
        return max(1, self.per_rank // self.tile)

    @property
    def tiles_per_channel(self) -> int:
        return max(1, self.per_channel // self.tile)

    @property
    def num_tiles(self) -> int:
        return cdiv(self.dim, self.tile)

    # ---- f_S / f_R / f_C : host-side -----------------------------------------
    def shape_range(self, tile_id: int) -> Tuple[int, int]:
        """f_S — [lo, hi) slice of the global dimension covered by ``tile_id``."""
        lo = tile_id * self.tile
        return lo, min(lo + self.tile, self.dim)

    def rank(self, tile_id: int) -> int:
        """f_R — source rank of ``tile_id`` (paper's src_rank formula)."""
        return tile_id // self.tiles_per_rank

    def channel(self, tile_id: int) -> int:
        """f_C — global channel index of ``tile_id`` (paper's channel formula)."""
        return tile_id // self.tiles_per_channel

    def channel_in_rank(self, tile_id: int) -> int:
        """Channel index local to the owning rank (0..C-1)."""
        return self.channel(tile_id) % self.num_channels

    def tiles_of_rank(self, rank: int) -> range:
        """Inverse of f_R: tile ids produced by ``rank``."""
        return range(rank * self.tiles_per_rank, (rank + 1) * self.tiles_per_rank)

    # ---- f_S / f_R / f_C : traced (usable inside jit / Pallas) ---------------
    def shape_range_t(self, tile_id):
        lo = tile_id * self.tile
        return lo, jnp.minimum(lo + self.tile, self.dim)

    def rank_t(self, tile_id):
        return tile_id // self.tiles_per_rank

    def channel_t(self, tile_id):
        return tile_id // self.tiles_per_channel

    def validate(self) -> None:
        if self.dim % self.tile:
            raise ValueError(f"tile {self.tile} must divide dim {self.dim}")
        if self.per_rank % self.tile:
            raise ValueError(f"tile {self.tile} must divide per-rank extent {self.per_rank}")
        if self.tiles_per_rank % self.num_channels:
            # the paper's affine f_C assumes channels evenly tile a rank's tiles
            raise ValueError(
                f"num_channels {self.num_channels} must divide tiles-per-rank "
                f"{self.tiles_per_rank}"
            )


@dataclasses.dataclass
class DynamicTileMapping:
    """Lookup-table mapping (paper §4.1, dynamic mapping).

    ``f_S_low/f_S_high/f_R/f_C`` are runtime integer arrays indexed by tile_id.
    The values are produced by dynamic logic (e.g. MoE routing); the *access*
    (a gather at ``tile_id``) is fixed at trace time — exactly the paper's design.
    """

    f_S_low: jnp.ndarray  # [num_tiles] int32 — inclusive low of shape range
    f_S_high: jnp.ndarray  # [num_tiles] int32 — exclusive high
    f_R: jnp.ndarray  # [num_tiles] int32 — owning rank
    f_C: jnp.ndarray  # [num_tiles] int32 — channel

    def shape_range_t(self, tile_id):
        return self.f_S_low[tile_id], self.f_S_high[tile_id]

    def rank_t(self, tile_id):
        return self.f_R[tile_id]

    def channel_t(self, tile_id):
        return self.f_C[tile_id]

    @property
    def num_tiles(self) -> int:
        return int(self.f_S_low.shape[0])

    @staticmethod
    def from_group_sizes(group_sizes: jnp.ndarray, tile: int, experts_per_rank: int):
        """Build the MoE dynamic mapping from per-expert token counts.

        Given ``group_sizes[e]`` = number of tokens routed to expert ``e`` (already
        aligned/padded to ``tile``), returns a mapping whose tile ``t`` covers rows
        ``[f_S_low[t], f_S_high[t])`` of the expert-sorted token buffer, owned by
        rank ``f_R[t] = e // experts_per_rank``.

        All shapes are static (max tiles); empty tiles have low == high.
        """
        # table layout: offsets = [0, cumsum(group_sizes)]; tiles laid out
        # per-expert with a static max (capacity / tile) — see the
        # capacity-static builder below, which is what callers must use
        raise NotImplementedError(
            "Use build_moe_dynamic_mapping (capacity-static version); "
            "kept here as documentation of the table layout."
        )


def build_moe_dynamic_mapping(
    group_offsets: jnp.ndarray,
    tiles_per_expert: int,
    tile: int,
    experts_per_rank: int,
) -> DynamicTileMapping:
    """Capacity-static MoE dynamic mapping.

    Args:
      group_offsets: [E+1] int32 prefix sums of (tile-aligned) per-expert rows in
        the expert-sorted token buffer.
      tiles_per_expert: static max tiles each expert may occupy (capacity / tile).
      tile: row-tile size.
      experts_per_rank: experts hosted per rank (EP layout) — defines f_R.

    Returns a DynamicTileMapping with ``E * tiles_per_expert`` tiles; tile ``t``
    belongs to expert ``t // tiles_per_expert``; tiles past an expert's actual row
    count are empty (low == high) and consumers skip them.
    """
    num_experts = group_offsets.shape[0] - 1
    e_ids = jnp.repeat(jnp.arange(num_experts, dtype=jnp.int32), tiles_per_expert)
    t_in_e = jnp.tile(jnp.arange(tiles_per_expert, dtype=jnp.int32), num_experts)
    base = group_offsets[e_ids]
    end = group_offsets[e_ids + 1]
    low = jnp.minimum(base + t_in_e * tile, end)
    high = jnp.minimum(low + tile, end)
    ranks = e_ids // experts_per_rank
    channels = e_ids  # one channel per expert
    return DynamicTileMapping(
        f_S_low=low.astype(jnp.int32),
        f_S_high=high.astype(jnp.int32),
        f_R=ranks.astype(jnp.int32),
        f_C=channels.astype(jnp.int32),
    )
