"""Tile-program plans — the IR between ``BlockChannel`` and the executors.

``compile_overlap`` no longer dispatches to hand-written ring loops: it builds
a :class:`TilePlan` from ``(kind, BlockChannel, world)`` and hands it to the
single generic schedule executor (``core/overlap.run_plan``) or to the fused
Pallas kernels.  The plan is the one place where the *communication* half of
the design space (``CommSpec.order``, ``num_channels``) is turned into
concrete per-step schedules, so every workload kind sweeps the same space.

A plan captures a producer/consumer tile graph over ``world`` ranks:

  * per channel ``c`` a **source schedule** sigma_c(rank, step) — which peer's
    tile rank holds/consumes at each step.  Sources come from
    ``schedules.SCHEDULES`` (ring / bidir_ring / all2all); channels may run
    mirrored (direction = -1) so a bidirectional order drives both ICI link
    directions at once;
  * the **flow permutations** between consecutive steps, derived from sigma by
    inversion (rank j forwards its held tile to the rank that needs it next) —
    these become ``lax.ppermute`` tables on the XLA backend and remote-DMA
    destination tables in the Pallas kernels;
  * the **flow kind**: "ag" (tiles flow, consumer accumulates locally), "rs"
    (partial results flow and reduce; the segment schedule is the time
    reversal of sigma, ending at the home rank — paper Fig. 4), "ag_rs"
    (MoE double ring: tiles flow forward while a reduction flows alongside,
    plus a final alignment hop), "a2a" (expert-parallel dispatch: each step
    is a *direct* pairwise exchange of the ranks' own token tiles — rank r
    receives origin sigma(r, s)'s tile straight from the holder, nothing is
    forwarded), or "a2a_rs" (expert-parallel combine: per-step partial expert
    outputs are returned along the reversed exchange edge and accumulated on
    the home rank);
  * the **dtype axis, split**: ``accum_dtype`` (``CompSpec.accum_dtype``) is
    what partial reductions accumulate in; the **wire dtype** is what tiles
    and flowing partials travel in, described by the plan's ``quant``
    (:class:`~repro.core.quant.QuantSpec`) — ``plan.flow_dtype`` derives from
    it (wire inherits accum when unset).  Quantized wires carry their
    scale/zero-point tables through the same permutes the payload rides,
    exactly like the a2a routing tables (``quant_table_spec`` names the
    coverage the verifier checks).

Plans are host-side, hashable, and cached: ``build_plan`` is keyed on
``(kind, channel, world, num_channels)`` (bounded LRU; ``plan_cache_info``
surfaces hits/misses to the bench gate).

Invariants — every plan must satisfy these; each is proven statically by the
named pass in ``repro.analysis`` on every ``build_plan`` miss (``REPRO_VERIFY=0``
opts out) and exhaustively by ``python -m repro.analysis.verify --all``:

  * sigma(., step) is a permutation of ranks and sigma(r, 0) == r
    (``per_step_permutation`` / ``seed_identity``);
  * every rank consumes every origin exactly once over a pass
    (``ag_coverage``; with channels: ``slot_partition``);
  * ``flow_perm(step)`` delivers exactly sigma(., step + 1) and ``rs_perm``
    delivers the time-reversed segment schedule (``flow_composition`` /
    ``rs_composition``);
  * ``rs_segment`` is the time reversal of sigma ending at the home rank
    (``rs_time_reversal`` / ``rs_home``); ``align_perm`` routes the ag_rs
    reduction to the origin of the tile held last (``align_home``);
  * the semaphore protocol the fused kernels run over these tables is
    deadlock- and race-free (``analysis.protocol``: ``sem_count`` /
    ``deadlock`` / ``read_before_signal`` / ``overwritten_before_wait`` /
    ``double_write``);
  * **seam composition** (multi-op :class:`SeqPlan`): an RS producer chained
    into an AG consumer over the same axis must land every channel's fully
    reduced segment on its home rank exactly where the consumer seeds its
    local tile — ``rs_segment(r, world-1) == r == sigma(r, 0)`` with matching
    world and channel counts — so the seam hands off rank-locally, with no
    resharding collective and no serialized drain->fill between the two ring
    passes (``seam_composition``, plus a combined producer+consumer protocol
    pass over the concatenated per-rank streams).
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Tuple

from repro.analysis.errors import PlanVerificationError
from repro.core import schedules
from repro.core.channels import BlockChannel, ORDERS
from repro.core.quant import QuantSpec

__all__ = [
    "ChannelSchedule",
    "TilePlan",
    "SeqPlan",
    "build_plan",
    "build_seq_plan",
    "plan_cache_info",
    "FLOW_OF_KIND",
]

# flow type of each workload kind (see module docstring)
FLOW_OF_KIND = {
    "ag_matmul": "ag",
    "ag_attention": "ag",
    "matmul_rs": "rs",
    "psum_scatter": "rs",
    "ag_moe": "ag_rs",
    "a2a_dispatch": "a2a",
    "combine_rs": "a2a_rs",
}


@dataclasses.dataclass(frozen=True)
class ChannelSchedule:
    """One channel's realization of a tile order over ``world`` ranks.

    ``direction=-1`` mirrors the base schedule (rank -> 2*rank - sigma), i.e.
    the counter-rotating twin of the same order — bidirectional plans split
    channels across the two directions so both ring links carry traffic.
    """

    order: str
    world: int
    direction: int = 1

    def __post_init__(self):
        if self.order not in ORDERS:
            raise ValueError(f"unknown tile order {self.order!r}; one of {ORDERS}")

    # ---- sigma: source schedule ---------------------------------------------
    def source(self, rank: int, step: int) -> int:
        """sigma(rank, step): origin rank of the tile held at ``step``."""
        src = schedules.SCHEDULES[self.order](rank, step, self.world)
        if self.direction < 0 and self.order != "all2all":
            src = (2 * rank - src) % self.world  # mirrored (counter-rotating)
        return src

    def source_table(self, step: int) -> Tuple[int, ...]:
        """sigma(., step) for every rank — index with a traced rank."""
        return tuple(self.source(r, step) for r in range(self.world))

    # ---- flow permutations (AG direction) -----------------------------------
    def flow_perm(self, step: int) -> Tuple[Tuple[int, int], ...]:
        """ppermute pairs moving held tiles from ``step`` to ``step + 1``.

        Rank j holds the tile of sigma(j, step); it must reach the rank d that
        consumes that tile next: sigma(d, step + 1) == sigma(j, step).
        """
        inv = {self.source(d, step + 1): d for d in range(self.world)}
        if len(inv) != self.world:
            # normally caught at verify-time (build_plan runs the analysis
            # passes); raised structured here too so a REPRO_VERIFY=0 run
            # still reports the same diagnosis as the tuner's candidate filter
            raise PlanVerificationError(
                "source schedule is not a per-step permutation",
                check="per_step_permutation",
                order=self.order,
                world=self.world,
                step=step + 1,
            )
        return tuple((j, inv[self.source(j, step)]) for j in range(self.world))

    # ---- all-to-all exchange view (direct pairwise, no forwarding) ----------
    def a2a_perm(self, step: int) -> Tuple[Tuple[int, int], ...]:
        """ppermute pairs of the *direct* exchange landing step ``step``.

        Unlike ``flow_perm`` (which forwards the currently held tile), every
        a2a step permutes the ranks' *own* tiles: rank j sends its tile to
        the rank d that consumes it at ``step`` (sigma(d, step) == j).  For
        the all2all XOR order this is the involution ``d = j ^ step``.
        """
        inv = {self.source(d, step): d for d in range(self.world)}
        if len(inv) != self.world:
            raise PlanVerificationError(
                "source schedule is not a per-step permutation",
                check="per_step_permutation",
                order=self.order,
                world=self.world,
                step=step,
            )
        return tuple((j, inv[j]) for j in range(self.world))

    def combine_perm(self, step: int) -> Tuple[Tuple[int, int], ...]:
        """ppermute pairs returning step ``step``'s partial to its home rank.

        At ``step`` rank j holds the expert output for tokens of origin
        sigma(j, step); send it back there — the per-step generalization of
        ``align_perm`` (which is exactly ``combine_perm(world - 1)``).
        """
        return tuple((j, self.source(j, step)) for j in range(self.world))

    def align_perm(self) -> Tuple[Tuple[int, int], ...]:
        """Final hop routing a tile-following reduction to its home rank.

        After the last step rank j holds the reduction for the tiles of rank
        sigma(j, world - 1); send it there (MoE double ring's last permute).
        """
        return tuple((j, self.source(j, self.world - 1)) for j in range(self.world))

    # ---- reduce-scatter view (time-reversed sigma) --------------------------
    def rs_segment(self, rank: int, step: int) -> int:
        """Segment reduced by ``rank`` at ``step`` of an RS flow.

        The time reversal of sigma: seg(r, world-1) == sigma(r, 0) == r, so
        after the last step every rank holds its own fully reduced segment.
        For the ring order in the plan's default orientation (direction -1,
        see ``_directions``) this is exactly the paper's Fig. 4 schedule
        ``seg = (rank + step + 1) % world`` (``schedules.ring_rs_segment``),
        with partials flowing to rank r-1.
        """
        return self.source(rank, self.world - 1 - step)

    def rs_segment_table(self, step: int) -> Tuple[int, ...]:
        return tuple(self.rs_segment(r, step) for r in range(self.world))

    def rs_perm(self, step: int) -> Tuple[Tuple[int, int], ...]:
        """ppermute pairs moving partials from ``step`` to ``step + 1``."""
        inv = {self.rs_segment(d, step + 1): d for d in range(self.world)}
        return tuple((j, inv[self.rs_segment(j, step)]) for j in range(self.world))


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """A compiled tile program: what every rank does at every step.

    ``channels[c]`` gives channel c's schedule; the executor runs all channels
    each step (C outstanding transfers per rank — the paper's f_C).
    """

    kind: str
    axis: str
    world: int
    flow: str  # "ag" | "rs" | "ag_rs"
    num_channels: int  # effective (validated divisor of the extent)
    accum_dtype: str  # CompSpec.accum_dtype — reduction dtype only
    channels: Tuple[ChannelSchedule, ...]
    quant: QuantSpec = QuantSpec()  # the wire half of the dtype axis

    @property
    def steps(self) -> int:
        return self.world

    @property
    def flow_dtype(self) -> str:
        """The wire dtype — what actually travels (quant descriptor view).

        Derived: the quant spec's wire dtype, inheriting ``accum_dtype`` when
        unset.  Kernels that size wire buffers read this; accumulation reads
        ``accum_dtype`` — the two are independent after the split.
        """
        return self.quant.resolve_wire(self.accum_dtype)

    def quant_table_spec(self) -> int:
        """Scale-table slot coverage a quantized wire needs for this plan.

        0 for float wires.  One scale per quantize site (see
        ``QuantSpec.scale_slots``); the verifier checks executor-declared
        coverage against this alongside schedule legality.
        """
        return self.quant.scale_slots(
            self.flow, self.world, self.num_channels, self.steps)

    # ---- flat tables for the Pallas kernels ---------------------------------
    # [num_channels][steps][world] nested tuples; wrappers jnp.asarray them and
    # kernels index [c, s, my_rank] with traced scalars — one schedule source
    # of truth for both backends.
    def src_tables(self) -> Tuple[Tuple[Tuple[int, ...], ...], ...]:
        """AG: origin rank (== gather-buffer slot) consumed per (c, step, rank)."""
        return tuple(
            tuple(ch.source_table(s) for s in range(self.steps)) for ch in self.channels
        )

    def flow_dst_tables(self) -> Tuple[Tuple[Tuple[int, ...], ...], ...]:
        """AG: remote rank each rank pushes its held tile to, per (c, step).

        The last step pushes nowhere; its row is the identity (unused).
        """
        ident = tuple(range(self.world))
        return tuple(
            tuple(
                tuple(dst for _, dst in ch.flow_perm(s)) if s < self.steps - 1 else ident
                for s in range(self.steps)
            )
            for ch in self.channels
        )

    def rs_seg_tables(self) -> Tuple[Tuple[Tuple[int, ...], ...], ...]:
        """RS: segment reduced per (c, step, rank)."""
        return tuple(
            tuple(ch.rs_segment_table(s) for s in range(self.steps)) for ch in self.channels
        )

    def rs_dst_tables(self) -> Tuple[Tuple[Tuple[int, ...], ...], ...]:
        """RS: remote rank each rank pushes its partial to, per (c, step)."""
        ident = tuple(range(self.world))
        return tuple(
            tuple(
                tuple(dst for _, dst in ch.rs_perm(s)) if s < self.steps - 1 else ident
                for s in range(self.steps)
            )
            for ch in self.channels
        )

    def a2a_dst_tables(self) -> Tuple[Tuple[Tuple[int, ...], ...], ...]:
        """A2A: rank each rank sends its *own* tile to, per (c, step).

        Step 0 is the local/seed step (identity row).  The combine return
        destinations need no extra table — they are exactly ``src_tables``
        (rank j returns step s's partial to sigma(j, s)).
        """
        return tuple(
            tuple(
                tuple(dst for _, dst in ch.a2a_perm(s)) for s in range(self.steps)
            )
            for ch in self.channels
        )


def _directions(order: str, num_channels: int) -> Tuple[int, ...]:
    """Channel -> ring direction.

    ring      : unidirectional by definition — every channel direction -1,
                i.e. the paper's orientation: AG chunks flow to rank r+1 and
                the RS view reduces to exactly Fig. 4's
                ``seg = (rank + step + 1) % world`` with partials flowing to
                rank r-1 (asserted by tests against
                ``schedules.ring_rs_segment``).
    bidir_ring: odd channels mirrored so both link directions carry traffic
                every step (with C == 1 the alternating +-hop schedule itself
                uses both directions across steps).
    all2all   : pairwise exchange, direction-less.
    """
    if order == "bidir_ring":
        return tuple(1 if c % 2 == 0 else -1 for c in range(num_channels))
    if order == "ring":
        return (-1,) * num_channels
    return (1,) * num_channels


@functools.lru_cache(maxsize=256)
def build_plan(kind: str, channel: BlockChannel, world: int, num_channels: int) -> TilePlan:
    """Build (and cache) the tile plan for ``kind`` over ``world`` ranks.

    ``num_channels`` is the *effective* channel count — callers run the
    requested ``channel.num_channels`` through ``mapping.effective_channels``
    against the chunked extent first, so the cache key is exact.  The cache is
    a bounded LRU (long-running serving processes sweep many shapes); every
    miss is statically verified by the ``repro.analysis`` passes unless
    ``REPRO_VERIFY=0``.
    """
    if kind not in FLOW_OF_KIND:
        raise ValueError(f"unknown workload kind {kind!r}; one of {tuple(FLOW_OF_KIND)}")
    order = channel.comm.order
    chans = tuple(
        ChannelSchedule(order=order, world=world, direction=d)
        for d in _directions(order, num_channels)
    )
    plan = TilePlan(
        kind=kind,
        axis=channel.axis,
        world=world,
        flow=FLOW_OF_KIND[kind],
        num_channels=num_channels,
        accum_dtype=channel.comp.accum_dtype,
        channels=chans,
        quant=channel.quant,
    )
    if os.environ.get("REPRO_VERIFY", "1").lower() not in ("0", "false", "off"):
        from repro import analysis  # lazy: analysis imports back into core

        analysis.verify_plan(plan)
    return plan


@dataclasses.dataclass(frozen=True)
class SeqPlan:
    """A multi-op plan graph: op N's outbound flow feeds op N+1's inbound flow.

    Two shapes are supported: the layer seam ``matmul_rs -> ag_matmul`` (one
    RS ring pass whose home segments become, in place, the consumer's step-0
    local tiles for a second ring pass over the *same* axis and channel
    split), and the expert-parallel MoE pair ``a2a_dispatch -> combine_rs``
    (each dispatch step's direct pairwise exchange lands token tiles whose
    expert outputs return along the reversed edge while the next exchange is
    in flight).  The composition invariants (module docstring) guarantee the
    handoff is rank-local for every order, so the executors
    (``core/overlap.run_seq_plan`` / ``run_a2a_seq``) never materialize a
    resharded intermediate across a shard_map boundary and never serialize
    the producer drain against the consumer fill.
    """

    ops: Tuple[TilePlan, ...]

    def __post_init__(self):
        if len(self.ops) != 2:
            raise ValueError(f"SeqPlan supports exactly 2 chained ops, got {len(self.ops)}")
        a, b = self.ops
        if (a.flow, b.flow) not in (("rs", "ag"), ("a2a", "a2a_rs")):
            raise ValueError(
                f"SeqPlan must chain an rs producer into an ag consumer or an "
                f"a2a dispatch into an a2a_rs combine, got flows {(a.flow, b.flow)}"
            )
        if a.axis != b.axis or a.world != b.world or a.num_channels != b.num_channels:
            raise ValueError(
                "seam ops must share axis/world/channel count, got "
                f"axis={(a.axis, b.axis)} world={(a.world, b.world)} "
                f"C={(a.num_channels, b.num_channels)}"
            )

    @property
    def axis(self) -> str:
        return self.ops[0].axis

    @property
    def world(self) -> int:
        return self.ops[0].world

    @property
    def num_channels(self) -> int:
        return self.ops[0].num_channels


@functools.lru_cache(maxsize=256)
def build_seq_plan(
    kinds: Tuple[str, ...],
    channels: Tuple[BlockChannel, ...],
    world: int,
    num_channels: int,
) -> SeqPlan:
    """Build (and cache) the fused seam plan for ``kinds`` over ``world`` ranks.

    ``channels`` may differ per op (e.g. different tile orders for the RS and
    AG halves) but must agree on axis; ``num_channels`` is the shared
    *effective* channel count, pre-clamped by the caller against both chunked
    extents.  Every cache miss is verified by ``analysis.verify_seq_plan``
    (schedule legality per op, the seam-composition invariant, and a combined
    race/deadlock protocol pass) unless ``REPRO_VERIFY=0``.
    """
    if len(kinds) != len(channels):
        raise ValueError(f"got {len(kinds)} kinds but {len(channels)} channels")
    ops = tuple(
        build_plan(kind, ch, world, num_channels) for kind, ch in zip(kinds, channels)
    )
    seq = SeqPlan(ops=ops)
    if os.environ.get("REPRO_VERIFY", "1").lower() not in ("0", "false", "off"):
        from repro import analysis  # lazy: analysis imports back into core

        analysis.verify_seq_plan(seq)
    return seq


def plan_cache_info():
    """Cache statistics for the plan layer (hits == reused compilations)."""
    return build_plan.cache_info()
