"""TileLink frontend: compile ``(kind, BlockChannel)`` tile programs.

The paper's frontend takes (communication spec, computation spec, BlockChannel)
and emits a fused kernel.  ``compile_overlap`` is that entry point, and it is a
real (if small) compiler pipeline:

  1. **validate** — ``BlockChannel`` fields are checked at construction; the
     (kind, backend) pair is checked here, with one structured
     ``NotImplementedError`` for every unsupported combination;
  2. **plan** — ``core/plan.build_plan`` lowers the channel's CommSpec/CompSpec
     into a :class:`~repro.core.plan.TilePlan`: per-channel per-step peer
     schedules (from ``schedules.SCHEDULES``), flow permutations, flow kind,
     and the wire dtype (``plan.flow_dtype``, resolved from the channel's
     QuantSpec against its accum dtype).  Plans are cached on ``(kind,
     channel, world,
     num_channels)`` — ``plan.plan_cache_info()`` shows reuse;
  3. **execute** — one of two backends consumes the SAME plan:

     backend="xla"     the generic schedule executor (``core/overlap.run_plan``)
                       runs the plan over ``lax.ppermute`` — communication on
                       XLA async collectives ("copy engine"), compiles on any
                       platform incl. the 512-device dry-run.  All four kinds.
     backend="pallas"  fused Pallas kernels with explicit semaphores + remote
                       DMAs (``repro/kernels/ag_gemm.py``, ``gemm_rs.py``)
                       consume the plan's schedule tables — the literal
                       kernel-fusion analogue; runs on TPU, validated on CPU
                       via the ``repro.backend`` emulated target.

Because both backends execute the same plan, the whole ``CommSpec x CompSpec``
space (order x num_channels x accum_dtype x compute tile) is sweepable
uniformly across every kind — see ``benchmarks/kernel_bench.py --smoke``.

``channel="auto"`` autotunes instead of hard-coding a design point: the
returned callable resolves the best ``BlockChannel`` for its actual operand
shapes through ``repro.tune`` (persistent per-mesh cache; analytic cost model
at trace time, measured winners wherever the cache was pre-warmed — see
``repro/tune/__init__.py``), then lowers through the normal pipeline above.

``comp`` selects the *computation* half independently (the paper's decoupled
CompSpec): ``comp="auto"`` adds the pruned (tm, tn, tk) consumer-tile
lattice to the search — with ``channel="auto"`` the two halves are searched
jointly; with an explicit channel only the compute half is tuned, the comm
half held fixed.  An explicit ``CompSpec`` overrides the whole compute half
(tile AND accum dtype) without tuning; a bare (tm, tn, tk) tuple overrides
the tile ONLY, leaving the accum dtype to the channel (or, with
``channel="auto"``, to the comm search).

``quant`` selects the *wire* half (the :class:`~repro.core.quant.QuantSpec`
axis — what travels, decoupled from what accumulates): an explicit
``QuantSpec`` pins it on every candidate/channel; ``quant="auto"`` (or
``True``) opens the wire-dtype flow axis to the search
(``tune.QUANT_SPACE``-style, enumerated for the ``QUANT_WIRE_KINDS`` only),
so a comm-bound shape can resolve an int8 wire that beats the best
full-width candidate on modeled cost; ``None`` (default) keeps the
channel's own QuantSpec — the identity wire unless the caller set one.

``interpret=None`` defers to ``repro.backend.default_interpret()``: interpret
on CPU-only hosts, Mosaic on real TPUs.

The returned callable must be invoked inside shard_map over ``channel.axis``.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Callable, Optional, Tuple, Union

from repro.core.channels import BlockChannel, CompSpec
from repro.core.quant import QuantSpec
from repro.core import overlap as _xla

__all__ = [
    "compile_overlap",
    "SeamFallbackWarning",
    "KINDS",
    "SEQ_KINDS",
    "BACKENDS",
    "PALLAS_KINDS",
    "unsupported_error",
]

KINDS = ("ag_matmul", "matmul_rs", "ag_attention", "ag_moe")
BACKENDS = ("xla", "pallas")
# kinds with a fused-kernel lowering; the others map their communication to
# the copy engine via host primitives (paper Fig. 5/6), i.e. backend="xla"
PALLAS_KINDS = ("ag_matmul", "matmul_rs")
# op sequences with a fused lowering (compile_overlap list form): the RS->AG
# layer seam and the expert-parallel MoE dispatch/combine pair
SEQ_KINDS = (("matmul_rs", "ag_matmul"), ("a2a_dispatch", "combine_rs"))
A2A_SEQ = ("a2a_dispatch", "combine_rs")


def unsupported_error(kind: str, backend: str) -> NotImplementedError:
    """The one structured error for every unsupported (kind, backend) pair."""
    supported = PALLAS_KINDS if backend == "pallas" else KINDS
    return NotImplementedError(
        f"compile_overlap: kind={kind!r} is not supported on "
        f"backend={backend!r} (supported there: {supported}); "
        "the paper maps this workload's communication to the copy engine "
        "(host primitives) — use backend='xla'"
    )


def _normalize_comp(comp) -> Union[None, str, CompSpec, Tuple[int, int, int]]:
    """None | "auto" | CompSpec | (tm, tn, tk).

    A bare tuple stays a tuple: it pins the TILE only, leaving the channel's
    (or the search's) accum dtype untouched; a full CompSpec pins the whole
    compute half (tile AND accum dtype).
    """
    if comp is None or comp == "auto":
        return comp
    if isinstance(comp, CompSpec):
        return comp
    if isinstance(comp, (tuple, list)) and len(comp) == 3:
        tile = tuple(int(t) for t in comp)
        if any(t < 1 for t in tile):
            raise ValueError(f"comp tile must be 3 positive ints, got {comp!r}")
        return tile
    raise ValueError(
        f"comp must be None, 'auto', a CompSpec, or a (tm, tn, tk) tuple, got {comp!r}"
    )


def _normalize_quant(quant) -> Union[None, str, QuantSpec]:
    """None | "auto" | QuantSpec (``True`` is shorthand for ``"auto"``)."""
    if quant is None or isinstance(quant, QuantSpec):
        return quant
    if quant is True or quant == "auto":
        return "auto"
    raise ValueError(
        f"quant must be None, 'auto'/True, or a QuantSpec, got {quant!r}"
    )


def compile_overlap(
    kind,
    channel: Union[BlockChannel, str, None] = None,
    *,
    comp=None,
    quant=None,
    backend: str = "xla",
    overlapped: bool = True,
    interpret: Optional[bool] = None,
    axis: str = "model",
    mesh=None,
    tune_ranker: Optional[str] = None,
    **kw,
) -> Callable:
    """Compile a tile program. See module docstring.

    ``kind`` is a single kind name, or a list/tuple of kinds (optionally
    ``(kind, channel)`` pairs) naming a fused op sequence — the supported
    sequences are ``["matmul_rs", "ag_matmul"]`` (the shared-ring layer seam)
    and ``["a2a_dispatch", "combine_rs"]`` (the expert-parallel MoE
    dispatch/combine pair).  ``channel`` is either an explicit :class:`BlockChannel` or
    the string ``"auto"`` (seq form also accepts None for the default
    channel); ``comp`` is None (use the channel's CompSpec), ``"auto"``
    (tune the compute half), or an explicit CompSpec / (tm, tn, tk) tuple;
    ``quant`` is None (use the channel's QuantSpec), ``"auto"``/``True``
    (open the wire-dtype flow axis to the search), or an explicit
    :class:`~repro.core.quant.QuantSpec` pin;
    ``axis``/``mesh``/``tune_ranker`` only apply to auto resolution (a mesh
    widens the tuning-cache fingerprint to the full topology).
    """
    if isinstance(kind, (list, tuple)):
        if comp is not None or interpret is not None:
            raise ValueError(
                "compile_overlap: comp/interpret apply to single-kind programs "
                "only; a seam sequence takes per-op (kind, channel) entries"
            )
        return _compile_seq(
            kind,
            channel=channel,
            backend=backend,
            overlapped=overlapped,
            axis=axis,
            mesh=mesh,
            tune_ranker=tune_ranker,
            quant=quant,
            **kw,
        )
    if kind not in KINDS:
        raise ValueError(f"unknown kind {kind!r}; expected one of {KINDS}")
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    if backend == "pallas" and kind not in PALLAS_KINDS:
        # keep the unsupported-(kind, backend) contract loud at BUILD time —
        # no resolution mode (channel="auto", comp="auto") may defer it into
        # the first trace
        raise unsupported_error(kind, backend)
    comp = _normalize_comp(comp)
    quant = _normalize_quant(quant)
    if isinstance(channel, str):
        if channel != "auto":
            raise ValueError(f"channel must be a BlockChannel or 'auto', got {channel!r}")
        base = None
        if isinstance(comp, CompSpec):
            # pinned compute half, tuned comm half: the explicit CompSpec
            # fixes the tile AND the accum dtype; every candidate inherits it
            # through the base channel and the narrowed space built in
            # _auto_overlap
            base = BlockChannel(axis=axis, comp=comp)
        if isinstance(quant, QuantSpec):
            # pinned wire half: every candidate inherits it through the base
            # channel (the flow axis stays closed — nothing to search)
            base = (base or BlockChannel(axis=axis)).with_(quant=quant)
        return _auto_overlap(
            kind,
            backend=backend,
            overlapped=overlapped,
            interpret=interpret,
            axis=axis,
            mesh=mesh,
            tune_ranker=tune_ranker,
            comp=comp,
            quant="auto" if quant == "auto" else None,
            base=base,
            **kw,
        )
    if not isinstance(channel, BlockChannel):
        raise TypeError(f"channel must be a BlockChannel, got {type(channel)}")
    if isinstance(quant, QuantSpec):
        channel = channel.with_(quant=quant)
        quant = None
    if isinstance(comp, CompSpec):
        channel = channel.with_(comp=comp)
    elif isinstance(comp, tuple):
        # tile-only override: the channel's accum dtype is untouched
        channel = channel.with_(comp=dataclasses.replace(channel.comp, tile=comp))
    if comp == "auto" or quant == "auto":
        # explicit comm half, tuned compute and/or wire half: resolve per
        # call shapes with the channel's own comm point as the (only) comm
        # candidate
        return _auto_overlap(
            kind,
            backend=backend,
            overlapped=overlapped,
            interpret=interpret,
            axis=channel.axis,
            mesh=mesh,
            tune_ranker=tune_ranker,
            comp=comp if comp == "auto" else None,
            quant=quant,
            base=channel,
            **kw,
        )

    if backend == "xla":
        if kind == "ag_moe":
            from repro.core import moe_overlap

            fn = moe_overlap.ag_moe if overlapped else moe_overlap.ag_moe_baseline
        else:
            table = {
                ("ag_matmul", True): _xla.ag_matmul,
                ("ag_matmul", False): _xla.ag_matmul_baseline,
                ("matmul_rs", True): _xla.matmul_rs,
                ("matmul_rs", False): _xla.matmul_rs_baseline,
                ("ag_attention", True): _xla.ring_attention,
                ("ag_attention", False): _xla.ag_attention_baseline,
            }
            fn = table[(kind, overlapped)]
        if overlapped:
            # every overlapped kind lowers kind -> plan -> generic executor;
            # the plan itself is built (and cached) at trace time, once the
            # mesh world size is known inside shard_map
            return functools.partial(fn, axis=channel.axis, channel=channel, **kw)
        return functools.partial(fn, axis=channel.axis, **kw)

    # backend == "pallas"
    from repro import kernels as _k

    table = {
        "ag_matmul": _k.ag_gemm_shard,
        "matmul_rs": _k.gemm_rs_shard,
    }
    if kind not in table:
        raise unsupported_error(kind, backend)
    # interpret=None flows through to backend.resolve_interpret inside the
    # kernel's pallas_call — the target policy lives in one place only
    return functools.partial(table[kind], channel=channel, interpret=interpret, **kw)


class SeamFallbackWarning(UserWarning):
    """A requested fused seam degraded loudly to the unfused op pair.

    Raised-as-warning exactly once per (axis, extents, channel-request) so a
    schedule-incompatible seam is never a silent perf cliff: the unfused pair
    is numerically identical, but the seam's collective time is exposed.
    """


_WARNED_SEAMS = set()


def _seam_incompatibility(ch_rs, ch_ag, world, m_glob, n_mid) -> Optional[str]:
    """Why this seam cannot fuse (None when it can).

    The fused executor hands each RS home segment to the AG half per channel,
    so both halves must resolve the SAME effective channel count — but RS
    chunks the N columns while AG chunks the M/R rows, and the two extents
    can clamp a shared request differently (or the ops may simply request
    different counts / run over different axes = different worlds).
    """
    from repro.core.mapping import effective_channels

    if ch_rs.axis != ch_ag.axis:
        return (
            f"producer runs over axis {ch_rs.axis!r} but consumer over "
            f"{ch_ag.axis!r} (mismatched worlds)"
        )
    if m_glob % world:
        return f"RS rows {m_glob} are not divisible by world {world}"
    nch_rs = effective_channels(n_mid, ch_rs.num_channels, kind="matmul_rs", warn=False)
    nch_ag = effective_channels(m_glob // world, ch_ag.num_channels, kind="ag_matmul", warn=False)
    if nch_rs != nch_ag:
        return (
            f"effective channel counts diverge: RS extent {n_mid} gives "
            f"C={nch_rs} (requested {ch_rs.num_channels}) but AG extent "
            f"{m_glob // world} gives C={nch_ag} (requested {ch_ag.num_channels})"
        )
    return None


def _warn_seam_fallback(reason: str, key) -> None:
    if key not in _WARNED_SEAMS:
        _WARNED_SEAMS.add(key)
        warnings.warn(
            SeamFallbackWarning(
                f"compile_overlap: seam is schedule-incompatible — {reason}; "
                "degrading to the unfused matmul_rs + ag_matmul pair (numerically "
                "identical, but the seam collective time is exposed)"
            ),
            stacklevel=3,
        )


def _seq_unfused(ch_rs, ch_ag, *, overlapped: bool, **kw) -> Callable:
    """The unfused reference composition with the same (y, ag_out) contract."""
    rs = compile_overlap("matmul_rs", ch_rs, backend="xla", overlapped=overlapped, **kw)
    ag = compile_overlap("ag_matmul", ch_ag, backend="xla", overlapped=overlapped, **kw)

    def pair_fn(x, w1, w2, *, residual=None, glue=None, **call_kw):
        out = rs(x, w1, **call_kw)
        y = out if residual is None else residual + out
        h = y if glue is None else glue(y)
        return y, ag(h, w2, **call_kw)

    return pair_fn


def _compile_seq(
    ops,
    *,
    channel: Union[BlockChannel, str, None] = None,
    backend: str = "xla",
    overlapped: bool = True,
    axis: str = "model",
    mesh=None,
    tune_ranker: Optional[str] = None,
    tune_base: Optional[BlockChannel] = None,
    tune_space=None,
    quant=None,
    **kw,
) -> Callable:
    """Compile a fused multi-op sequence (the ``compile_overlap`` list form).

    ``ops`` is a sequence of kind names or ``(kind, channel)`` pairs; the
    supported sequences are:

    ``["matmul_rs", "ag_matmul"]`` — the layer seam where a down/out
    projection's reduce-scatter hands its home segments directly to the next
    op's all-gather over one shared ring pass (``core/overlap.matmul_rs_ag``
    via ``core/plan.build_seq_plan``).  The returned callable has the
    signature

        fn(x, w1, w2, *, residual=None, glue=None) -> (y, ag_out)

    where ``y = residual + matmul_rs(x, w1)`` (the residual-stream value) and
    ``ag_out = ag_matmul(glue(y), w2)`` — ``glue`` is the rank-local seam
    elementwise (e.g. the consumer block's rms_norm), applied to the full
    home segment so the float ops match the unfused pair exactly.

    ``["a2a_dispatch", "combine_rs"]`` — the expert-parallel MoE pair: each
    step's direct pairwise exchange lands a peer's token tile + routing
    tables, the local experts' grouped GEMM runs while the next exchange is
    in flight, and the weighted partial returns home along the reversed edge
    (``core/moe_overlap.a2a_moe``).  The returned callable has the signature

        fn(x, topk_ids, topk_w, w_gu, w_down, *, capacity_factor=..., act=...)
            -> [m_loc, d]

    ``channel`` is a shared :class:`BlockChannel`, ``"auto"`` (the pair-aware
    tuner resolves both halves jointly per shape — ``repro.tune.resolve_seq``
    / ``resolve_a2a``), or None (the default channel); a per-op ``(kind,
    channel)`` entry overrides it for that op.  ``overlapped=False`` compiles
    the operator-centric unfused baseline pair (``a2a_moe_baseline`` for the
    MoE pair, with matching per-sub-chunk capacity semantics).

    If the RS->AG halves are schedule-incompatible at call time (mismatched
    worlds, or channel counts whose extents clamp differently), the call
    degrades LOUDLY to the unfused pair via one :class:`SeamFallbackWarning`
    — never a silent perf cliff, never a crash.  The a2a pair has no such
    cliff: both halves chunk the same token extent, so their effective
    channel counts always agree.
    """
    kinds, chans = [], []
    for op in ops:
        if isinstance(op, (tuple, list)):
            k, ch = op
        else:
            k, ch = op, channel
        kinds.append(k)
        chans.append(ch)
    kinds = tuple(kinds)
    if backend != "xla" or kinds not in SEQ_KINDS:
        raise NotImplementedError(
            f"compile_overlap: op sequence {kinds!r} is not supported on "
            f"backend={backend!r} (supported: {SEQ_KINDS} on backend='xla'); "
            "lower each op separately via single-kind compile_overlap calls"
        )
    quant = _normalize_quant(quant)
    if kinds == A2A_SEQ:
        return _compile_a2a(
            chans,
            channel=channel,
            overlapped=overlapped,
            axis=axis,
            mesh=mesh,
            tune_ranker=tune_ranker,
            tune_base=tune_base,
            tune_space=tune_space,
            quant=quant,
            **kw,
        )
    if any(ch == "auto" for ch in chans):
        base = next((ch for ch in chans if isinstance(ch, BlockChannel)), tune_base)
        if isinstance(quant, QuantSpec):
            base = (base or BlockChannel(axis=axis)).with_(quant=quant)
        elif quant == "auto":
            tune_space = _widen_flows(tune_space)
        return _auto_overlap_seq(
            axis=base.axis if base is not None else axis,
            mesh=mesh,
            tune_ranker=tune_ranker,
            base=base,
            space=tune_space,
            overlapped=overlapped,
            **kw,
        )
    ch_rs, ch_ag = (
        ch if isinstance(ch, BlockChannel) else BlockChannel(axis=axis) for ch in chans
    )
    if isinstance(quant, QuantSpec):
        ch_rs, ch_ag = ch_rs.with_(quant=quant), ch_ag.with_(quant=quant)
    elif quant == "auto":
        # quant-only search over explicit seam channels: pin the comm and
        # compute halves to the producer's point, search only the flow axis
        from repro.tune import Space as _Space

        return _auto_overlap_seq(
            axis=ch_rs.axis,
            mesh=mesh,
            tune_ranker=tune_ranker,
            base=ch_rs,
            space=_Space(
                orders=(ch_rs.comm.order,),
                channel_counts=(ch_rs.num_channels,),
                accum_dtypes=(ch_rs.comp.accum_dtype,),
                comp_tiles=(tuple(ch_rs.comp.tile),),
                flows=(None, "int8"),
            ),
            overlapped=overlapped,
            **kw,
        )
    if not overlapped:
        return _seq_unfused(ch_rs, ch_ag, overlapped=False, **kw)

    def seq_fn(x, w1, w2, *, residual=None, glue=None, **call_kw):
        import jax.numpy as jnp

        from repro import backend as _backend

        world = int(_backend.axis_size(ch_rs.axis))
        m_glob, n_mid = jnp.shape(x)[-2], jnp.shape(w1)[-1]
        reason = _seam_incompatibility(ch_rs, ch_ag, world, m_glob, n_mid)
        if reason is not None:
            _warn_seam_fallback(
                reason, (ch_rs.axis, ch_ag.axis, world, m_glob, n_mid,
                         ch_rs.num_channels, ch_ag.num_channels),
            )
            return _seq_unfused(ch_rs, ch_ag, overlapped=True, **kw)(
                x, w1, w2, residual=residual, glue=glue, **call_kw
            )
        return _xla.matmul_rs_ag(
            x, w1, w2,
            axis=ch_rs.axis, channel=ch_rs, channel2=ch_ag,
            residual=residual, glue=glue, **kw, **call_kw,
        )

    return seq_fn


def _widen_flows(space):
    """Open the wire-dtype flow axis on ``space`` (None = the default)."""
    from repro.tune import DEFAULT_SPACE

    return dataclasses.replace(space or DEFAULT_SPACE, flows=(None, "int8"))


def _compile_a2a(
    chans,
    *,
    channel,
    overlapped: bool,
    axis: str,
    mesh,
    tune_ranker: Optional[str],
    tune_base: Optional[BlockChannel] = None,
    tune_space=None,
    quant=None,
    **kw,
) -> Callable:
    """Compile the expert-parallel ``a2a_dispatch -> combine_rs`` pair.

    See :func:`_compile_seq` for the public contract.  Unlike the RS->AG seam
    there is no schedule-incompatibility fallback: both halves chunk the same
    local token extent, so their effective channel counts always agree and the
    a2a-seam invariants hold for every order (proven per ``build_seq_plan``
    miss).
    """
    from repro.core import moe_overlap

    if any(ch == "auto" for ch in chans):
        base = next((ch for ch in chans if isinstance(ch, BlockChannel)), tune_base)
        if isinstance(quant, QuantSpec):
            base = (base or BlockChannel(axis=axis)).with_(quant=quant)
        # quant="auto" is a no-op for the a2a pair: the MoE kinds are not
        # QUANT_WIRE_KINDS, so the enumerator never opens the flow axis there
        return _auto_overlap_a2a(
            axis=base.axis if base is not None else axis,
            mesh=mesh,
            tune_ranker=tune_ranker,
            base=base,
            space=tune_space,
            overlapped=overlapped,
            **kw,
        )
    ch_d, ch_c = (
        ch if isinstance(ch, BlockChannel) else BlockChannel(axis=axis) for ch in chans
    )
    if isinstance(quant, QuantSpec):
        ch_d, ch_c = ch_d.with_(quant=quant), ch_c.with_(quant=quant)
    if not overlapped:
        return functools.partial(
            moe_overlap.a2a_moe_baseline,
            axis=ch_d.axis,
            num_channels=ch_d.num_channels,
            **kw,
        )
    return functools.partial(
        moe_overlap.a2a_moe, axis=ch_d.axis, channel=ch_d, channel2=ch_c, **kw
    )


def _auto_overlap_a2a(
    *,
    axis: str,
    mesh,
    tune_ranker: Optional[str],
    base: Optional[BlockChannel],
    space=None,
    overlapped: bool,
    **kw,
) -> Callable:
    """Pair-aware auto resolution for the MoE dispatch/combine.

    ``repro.tune.resolve_a2a`` resolves both halves jointly (shared effective
    C, like seams) on the a2a cost model — per-step wire priced from the real
    peer hop counts of the order — and verdicts fused vs. the unfused
    AG+GroupGEMM+RS baseline per shape.
    """

    def auto_fn(x, topk_ids, topk_w, w_gu, w_down, **call_kw):
        import jax.numpy as jnp

        from repro import backend as _backend
        from repro.core import moe_overlap
        from repro.tune import resolve_a2a

        world = int(mesh.shape[axis]) if mesh is not None else int(_backend.axis_size(axis))
        resolve_kw = {} if space is None else {"space": space}
        fused, ch_d, ch_c = resolve_a2a(
            shapes=(
                jnp.shape(x),
                jnp.shape(topk_ids),
                jnp.shape(topk_w),
                jnp.shape(w_gu),
                jnp.shape(w_down),
            ),
            mesh=mesh,
            axis=axis,
            world=world,
            base=base,
            ranker=tune_ranker,
            capacity_factor=call_kw.get("capacity_factor"),
            **resolve_kw,
        )
        if fused and overlapped:
            fn = functools.partial(
                moe_overlap.a2a_moe, axis=axis, channel=ch_d, channel2=ch_c, **kw
            )
        else:
            fn = functools.partial(
                moe_overlap.a2a_moe_baseline,
                axis=axis,
                num_channels=ch_d.num_channels,
                **kw,
            )
        return fn(x, topk_ids, topk_w, w_gu, w_down, **call_kw)

    return auto_fn


def _auto_overlap_seq(
    *,
    axis: str,
    mesh,
    tune_ranker: Optional[str],
    base: Optional[BlockChannel],
    space=None,
    overlapped: bool,
    **kw,
) -> Callable:
    """Seam-aware auto resolution: fused vs. unfused decided per shape.

    ``repro.tune.resolve_seq`` prices the fused seam (shared-C candidates,
    with the eliminated exposed-collective time credited) against the best
    unfused per-op pair on the same cost model and returns the cheaper plan;
    an unfused verdict here is a deliberate tuner decision, so no fallback
    warning is emitted on that path.
    """

    def auto_fn(x, w1, w2, *, residual=None, glue=None, **call_kw):
        import jax.numpy as jnp

        from repro import backend as _backend
        from repro.tune import resolve_seq

        world = int(mesh.shape[axis]) if mesh is not None else int(_backend.axis_size(axis))
        resolve_kw = {} if space is None else {"space": space}
        fused, ch_rs, ch_ag = resolve_seq(
            shapes=(jnp.shape(x), jnp.shape(w1), jnp.shape(w2)),
            mesh=mesh,
            axis=axis,
            world=world,
            base=base,
            ranker=tune_ranker,
            **resolve_kw,
        )
        fn = (
            _compile_seq(
                [("matmul_rs", ch_rs), ("ag_matmul", ch_ag)],
                overlapped=overlapped, axis=axis, **kw,
            )
            if fused
            else _seq_unfused(ch_rs, ch_ag, overlapped=overlapped, **kw)
        )
        return fn(x, w1, w2, residual=residual, glue=glue, **call_kw)

    return auto_fn


def _auto_overlap(
    kind: str,
    *,
    backend: str,
    overlapped: bool,
    interpret: Optional[bool],
    axis: str,
    mesh,
    tune_ranker: Optional[str],
    comp=None,
    quant=None,
    base=None,
    **kw,
) -> Callable:
    """Auto resolution: defer design-point choice to the operand shapes.

    Shapes are only known when the returned callable runs (inside shard_map,
    like every compiled op), so resolution happens there: a pure host-side
    cache lookup / cost-model ranking via ``repro.tune.resolve_channel`` —
    trace-safe — then the normal ``compile_overlap`` lowering.  The tuning
    cache memo makes repeated layer calls resolve once per (kind, shape).

    ``comp="auto"`` widens the search to the compute-tile lattice: jointly
    with the comm half when ``base`` is None, or comp-only (the base
    channel's comm point held fixed) when ``base`` is an explicit channel.
    ``quant="auto"`` opens the wire-dtype flow axis on top of whichever
    space the rest of the request selected (an explicit base channel with
    nothing else tuned pins the comm+comp halves, so only the flow axis is
    searched).
    """

    def auto_fn(*args, **call_kw):
        import jax.numpy as jnp

        from repro import backend as _backend
        from repro.tune import COMP_TILE_LATTICE, DEFAULT_SPACE, JOINT_SPACE, Space
        from repro.tune import resolve_channel

        world = int(mesh.shape[axis]) if mesh is not None else int(_backend.axis_size(axis))
        if isinstance(comp, CompSpec):
            # pinned compute half (tile + accum dtype), tuned comm half: the
            # single-tile space is honored (clamped, never pruned) and every
            # candidate inherits the rest of the CompSpec through ``base``
            space = Space(accum_dtypes=(comp.accum_dtype,), comp_tiles=(tuple(comp.tile),))
        elif isinstance(comp, tuple):
            # pinned tile only: the accum dtype stays part of the comm search
            space = Space(comp_tiles=(comp,))
        elif comp == "auto" and base is not None:
            space = Space(
                orders=(base.comm.order,),
                channel_counts=(base.num_channels,),
                accum_dtypes=(base.comp.accum_dtype,),
                comp_tiles=COMP_TILE_LATTICE,
            )
        elif comp == "auto":
            space = JOINT_SPACE
        elif quant == "auto" and base is not None:
            # quant-only search over an explicit channel: pin the comm and
            # compute halves to the base's own point
            space = Space(
                orders=(base.comm.order,),
                channel_counts=(base.num_channels,),
                accum_dtypes=(base.comp.accum_dtype,),
                comp_tiles=(tuple(base.comp.tile),),
            )
        else:
            space = DEFAULT_SPACE
        if quant == "auto":
            space = dataclasses.replace(space, flows=(None, "int8"))
        channel = resolve_channel(
            kind,
            shapes=[jnp.shape(a) for a in args],
            mesh=mesh,
            axis=axis,
            world=world,
            base=base,
            ranker=tune_ranker,
            space=space,
        )
        fn = compile_overlap(
            kind, channel, backend=backend, overlapped=overlapped, interpret=interpret, **kw
        )
        return fn(*args, **call_kw)

    return auto_fn
