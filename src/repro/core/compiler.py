"""TileLink frontend: compile tile programs to a chosen backend.

The paper's frontend takes (communication spec, computation spec, BlockChannel)
and emits a fused kernel.  Here ``compile_overlap`` is that entry point: given a
workload kind and a BlockChannel, it returns a *per-shard callable* lowered to
one of two backends:

  backend="xla"     decomposed-inside-jit ring schedules (core/overlap.py) —
                    communication on XLA async collectives ("copy engine"),
                    compiles on any platform incl. the 512-device dry-run.
  backend="pallas"  fused Pallas kernels with explicit semaphores + remote DMAs
                    (repro/kernels/ag_gemm.py etc.) — the literal kernel-fusion
                    analogue; runs on TPU, validated on CPU via the
                    ``repro.backend`` emulated target (interpret mode).

``interpret=None`` defers to ``repro.backend.default_interpret()``: interpret
on CPU-only hosts, Mosaic on real TPUs.

The returned callable must be invoked inside shard_map over ``channel.axis``.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

from repro.core.channels import BlockChannel
from repro.core import overlap as _xla

__all__ = ["compile_overlap", "KINDS"]

KINDS = ("ag_matmul", "matmul_rs", "ag_attention", "ag_moe")


def compile_overlap(
    kind: str,
    channel: BlockChannel,
    *,
    backend: str = "xla",
    overlapped: bool = True,
    interpret: Optional[bool] = None,
    **kw,
) -> Callable:
    """Compile a tile program. See module docstring."""
    if kind not in KINDS:
        raise ValueError(f"unknown kind {kind!r}; expected one of {KINDS}")

    if backend == "xla":
        table = {
            ("ag_matmul", True): _xla.ag_matmul,
            ("ag_matmul", False): _xla.ag_matmul_baseline,
            ("matmul_rs", True): _xla.matmul_rs,
            ("matmul_rs", False): _xla.matmul_rs_baseline,
            ("ag_attention", True): _xla.ring_attention,
            ("ag_attention", False): _xla.ag_attention_baseline,
        }
        if kind == "ag_moe":
            from repro.core import moe_overlap

            fn = moe_overlap.ag_moe if overlapped else moe_overlap.ag_moe_baseline
            return functools.partial(fn, axis=channel.axis, **kw)
        fn = table[(kind, overlapped)]
        if kind in ("ag_matmul", "matmul_rs") and overlapped:
            return functools.partial(fn, axis=channel.axis, channel=channel, **kw)
        return functools.partial(fn, axis=channel.axis, **kw)

    if backend == "pallas":
        from repro import kernels as _k

        table = {
            "ag_matmul": _k.ag_gemm_shard,
            "matmul_rs": _k.gemm_rs_shard,
        }
        if kind not in table:
            # Paper Fig. 6 maps AG-KV + attention comm to the *copy engine via
            # host primitives* — that resource mapping IS the xla backend here.
            # MoE's grouped GEMM runs as kernels/grouped_matmul inside the xla ring.
            raise NotImplementedError(
                f"pallas backend for {kind}: the paper maps this workload's "
                "communication to the copy engine (host primitives) — use backend='xla'"
            )
        # interpret=None flows through to backend.resolve_interpret inside the
        # kernel's pallas_call — the target policy lives in one place only
        return functools.partial(table[kind], channel=channel, interpret=interpret, **kw)

    raise ValueError(f"unknown backend {backend!r}")
