"""Tile orders (paper §3.1, Fig. 2b).

A schedule decides, for every rank and every step, which *peer's* tile is
communicated/consumed.  Communication and computation may follow different
orders; the mapping (f_R) reconciles them.

All schedules are expressed two ways:
  * ``peer(rank, step)`` — host ints, for building unrolled shard_map programs;
  * ``peer_t(rank, step)`` — traced, for use inside kernels/fori_loops.

Conventions (match the paper's Fig. 4 pseudo-code):
  ring       : at step s, rank r handles the segment of rank (r + s + 1) % R
               (reduce-scatter direction: partial results flow to rank r-1).
  ring_ag    : all-gather direction — at step s rank r holds the chunk that
               originated at rank (r + s) % R (chunks flow to rank r+1).
  all2all    : full-mesh — step s pairs rank r with (r ^ s) when R is a power of
               two (bandwidth-optimal pairwise exchange), else (r + s) % R.
  bidir_ring : even steps move clockwise, odd steps counter-clockwise, halving
               ring latency when both link directions are available.

``SCHEDULES`` (order name -> source schedule) is consumed by the plan layer
(``core/plan.ChannelSchedule``), which derives per-step ppermute tables and
remote-DMA destination tables by inverting the schedule; reduce-scatter
segment orders are its time reversal (for the ring order in the plan's
default orientation, that reversal == ring_rs_segment).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "ring_rs_segment",
    "ring_ag_source",
    "all2all_peer",
    "bidir_ring_source",
    "SCHEDULES",
]


def ring_rs_segment(rank: int, step: int, world: int) -> int:
    """Segment handled by ``rank`` at ``step`` of a ring reduce-scatter."""
    return (rank + step + 1) % world


def ring_ag_source(rank: int, step: int, world: int) -> int:
    """Origin rank of the chunk held by ``rank`` after ``step`` ring hops (AG)."""
    return (rank + step) % world


def all2all_peer(rank: int, step: int, world: int) -> int:
    """Full-mesh pairwise peer (XOR schedule when world is a power of two)."""
    if world & (world - 1) == 0:
        return rank ^ step
    return (rank + step) % world


def bidir_ring_source(rank: int, step: int, world: int) -> int:
    """Bidirectional ring: alternate direction per step, covering ±ceil(s/2)."""
    hop = (step + 1) // 2
    if step % 2 == 1:
        return (rank + hop) % world
    return (rank - hop) % world


# traced variants -------------------------------------------------------------


def ring_rs_segment_t(rank, step, world):
    return jnp.remainder(rank + step + 1, world)


def ring_ag_source_t(rank, step, world):
    return jnp.remainder(rank + step, world)


SCHEDULES = {
    "ring": ring_ag_source,
    "bidir_ring": bidir_ring_source,
    "all2all": all2all_peer,
}
