"""AG + MoE overlap (paper Fig. 5) — dynamic mapping, XLA backend.

The paper's hardest case: AllGather + Gather + GroupGEMM + TopkReduce +
ReduceScatter with *dynamic* tile mappings (token routing known only at
runtime).  Here it is lowered as a fused **double ring** inside shard_map:

  * an all-gather ring rotates token chunks (+ their routing tables) around the
    EP axis — the dynamic mapping tables f_R/f_S travel with the data exactly as
    the paper's lookup tables do;
  * a reduce-scatter ring accumulates combined expert outputs, consuming each
    token chunk one hop after it arrives.

Stage ``s`` of the RS ring computes the local-expert FFN for the chunk that the
AG ring delivered at stage ``s`` while both rings' permutes are in flight — an
extended producer-consumer chain (AG -> GroupGEMM -> TopkReduce -> RS) matching
the paper's §7.2 MoE kernel, with the ICI DMA engine as the copy resource.

Expert dispatch inside a chunk uses capacity-based one-hot dispatch (GShard
style) — the XLA-friendly realization of the paper's Gather/Scatter fusion; the
Pallas backend (kernels/grouped_matmul.py) implements the sorted-token
group-GEMM with explicit dynamic mapping tables instead.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.backend import axis_size

__all__ = ["ag_moe", "ag_moe_baseline", "local_expert_ffn", "moe_router"]


def moe_router(x, w_router, *, num_experts: int, top_k: int, valid_experts: Optional[int] = None):
    """Top-k softmax router. Returns (topk_ids i32 [m,k], topk_w f32 [m,k], aux_loss).

    ``valid_experts`` masks padding experts (EP divisibility padding) with -inf
    logits so they are never selected.
    """
    logits = jnp.einsum("md,de->me", x.astype(jnp.float32), w_router.astype(jnp.float32))
    if valid_experts is not None and valid_experts < num_experts:
        pad_mask = jnp.arange(num_experts) >= valid_experts
        logits = jnp.where(pad_mask[None, :], -jnp.inf, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_ids = lax.top_k(probs, top_k)
    topk_w = topk_w / jnp.maximum(topk_w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss
    ne = valid_experts or num_experts
    me = probs.mean(axis=0)
    ce = jnp.zeros((num_experts,), jnp.float32).at[topk_ids.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(ce.sum(), 1.0)
    aux = ne * jnp.sum(me * ce)
    return topk_ids.astype(jnp.int32), topk_w, aux


def _dispatch_tables(local_ids, valid, e_loc: int, cap: int, dtype):
    """Capacity dispatch [m, E_loc, cap] from per-(token,k) local expert ids."""
    m, k = local_ids.shape
    onehot = jax.nn.one_hot(local_ids, e_loc, dtype=jnp.float32) * valid[..., None]
    flat = onehot.reshape(m * k, e_loc)
    pos = jnp.cumsum(flat, axis=0) - flat  # position within expert, per (t,k)
    keep = (pos < cap) * flat
    disp = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32) * keep[..., None]
    return disp.reshape(m, k, e_loc, cap).astype(dtype)


def local_expert_ffn(
    x, topk_ids, topk_w, w_gu, w_down, *, e_lo: int, cap: int, act=jax.nn.silu
):
    """FFN through the experts hosted locally; zeros for foreign-routed tokens.

    x: [m, d]; topk_ids/topk_w: [m, k]; w_gu: [E_loc, d, 2f] fused gate+up;
    w_down: [E_loc, f, d].  Returns [m, d] partial combined output.
    """
    e_loc = w_gu.shape[0]
    local = topk_ids - e_lo
    valid = ((local >= 0) & (local < e_loc)).astype(jnp.float32)
    local = jnp.where(valid > 0, local, 0).astype(jnp.int32)

    disp_mkec = _dispatch_tables(local, valid, e_loc, cap, x.dtype)  # [m,k,E,c]
    disp = disp_mkec.sum(axis=1)  # [m, E, c] — 0/1 (slots unique per (t,k))
    comb = jnp.einsum("mkec,mk->mec", disp_mkec, topk_w.astype(x.dtype))

    x_e = jnp.einsum("mec,md->ecd", disp, x)  # gather to [E_loc, cap, d]
    f = w_down.shape[1]
    h = jnp.einsum("ecd,edf->ecf", x_e, w_gu, preferred_element_type=jnp.float32)
    h = (act(h[..., :f]) * h[..., f:]).astype(x.dtype)
    y_e = jnp.einsum("ecf,efd->ecd", h, w_down, preferred_element_type=jnp.float32)
    return jnp.einsum("mec,ecd->md", comb, y_e.astype(x.dtype))


def ag_moe(
    x, topk_ids, topk_w, w_gu, w_down, *, axis: str, capacity_factor: float = 1.25,
    act=jax.nn.silu,
):
    """Overlapped AG + MoE + RS double ring (see module docstring).

    Per-shard: x [m_loc, d] (token chunk, sharded over ``axis``), expert weights
    local to the rank (EP).  Returns [m_loc, d] combined outputs for the local
    token chunk.
    """
    r_axis = axis_size(axis)
    rank = lax.axis_index(axis)
    m_loc, d = x.shape
    k = topk_ids.shape[1]
    e_loc = w_gu.shape[0]
    e_total = e_loc * r_axis
    cap = _capacity(m_loc, k, e_total, capacity_factor)

    to_left = [(j, (j - 1) % r_axis) for j in range(r_axis)]
    e_lo = rank * e_loc

    cur, cur_ids, cur_w = x, topk_ids, topk_w
    acc = None
    for s in range(r_axis):
        if s < r_axis - 1:
            nxt = lax.ppermute(cur, axis, to_left)       # tile_push_data (tokens)
            nxt_ids = lax.ppermute(cur_ids, axis, to_left)  # dynamic f_R table travels
            nxt_w = lax.ppermute(cur_w, axis, to_left)
        part = local_expert_ffn(
            cur, cur_ids, cur_w, w_gu, w_down, e_lo=e_lo, cap=cap, act=act
        )
        acc = part if s == 0 else lax.ppermute(acc, axis, to_left) + part
        if s < r_axis - 1:
            cur, cur_ids, cur_w = nxt, nxt_ids, nxt_w
    # acc at rank r holds segment (r-1): one final hop aligns segments to ranks
    return lax.ppermute(acc, axis, to_left)


def ag_moe_baseline(
    x, topk_ids, topk_w, w_gu, w_down, *, axis: str, capacity_factor: float = 1.25,
    act=jax.nn.silu,
):
    """Non-overlapping reference: AllGather tokens+tables, GroupGEMM, ReduceScatter."""
    r_axis = axis_size(axis)
    rank = lax.axis_index(axis)
    m_loc, _ = x.shape
    k = topk_ids.shape[1]
    e_loc = w_gu.shape[0]
    e_total = e_loc * r_axis
    cap = _capacity(m_loc, k, e_total, capacity_factor)  # per-chunk capacity

    xg = lax.all_gather(x, axis, axis=0, tiled=False)          # [R, m_loc, d]
    idg = lax.all_gather(topk_ids, axis, axis=0, tiled=False)
    wg = lax.all_gather(topk_w, axis, axis=0, tiled=False)
    e_lo = rank * e_loc

    # chunk-wise expert FFN keeps capacity semantics identical to the ring path
    part = jax.vmap(
        lambda xc, ic, wc: local_expert_ffn(
            xc, ic, wc, w_gu, w_down, e_lo=e_lo, cap=cap, act=act
        )
    )(xg, idg, wg)  # [R, m_loc, d]
    out = lax.psum_scatter(part, axis, scatter_dimension=0, tiled=False)
    return out.reshape(m_loc, -1)


def _capacity(m: int, k: int, e_total: int, factor: float) -> int:
    cap = int(m * k / e_total * factor) + 1
    return max(8, -(-cap // 8) * 8)  # round up to multiple of 8
