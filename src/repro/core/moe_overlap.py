"""AG + MoE overlap (paper Fig. 5) — dynamic mapping, XLA backend.

The paper's hardest case: AllGather + Gather + GroupGEMM + TopkReduce +
ReduceScatter with *dynamic* tile mappings (token routing known only at
runtime).  Here it is lowered as an "ag_rs" tile plan run by the generic
schedule executor (``core/overlap.run_plan``) — the fused **double ring**
generalized to any ``CommSpec.order``:

  * token tiles (+ their routing tables) flow per the plan's per-step
    permutes — the dynamic mapping tables f_R/f_S travel with the data
    exactly as the paper's lookup tables do;
  * a reduction of combined expert outputs travels the *same* permutes
    (arriving partials fuse one hop after each token tile is consumed), plus
    a final alignment hop delivering each rank its own tokens' outputs.

Step ``s`` computes the local-expert FFN for the tile the flow delivered at
step ``s`` while both flows' permutes are in flight — an extended
producer-consumer chain (AG -> GroupGEMM -> TopkReduce -> RS) matching the
paper's §7.2 MoE kernel, with the ICI DMA engine as the copy resource.
``num_channels`` splits the local token chunk into independently scheduled
flows; the reduction accumulates in ``CompSpec.accum_dtype`` (the reduction
dtype) and travels the wire per ``BlockChannel.quant`` — with the default
QuantSpec the wire inherits the accum dtype; a quantized wire re-encodes at
each send edge inside the generic executor.

Expert dispatch inside a chunk uses capacity-based one-hot dispatch (GShard
style) — the XLA-friendly realization of the paper's Gather/Scatter fusion; the
Pallas backend (kernels/grouped_matmul.py) implements the sorted-token
group-GEMM with explicit dynamic mapping tables instead.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.backend import axis_size
from repro.core.channels import BlockChannel
from repro.core.comp_tiles import DEFAULT_TILE, blocked_dot
from repro.core.mapping import effective_channels
from repro.core.overlap import _plan_for, run_a2a_seq, run_plan
from repro.core.plan import build_seq_plan

__all__ = [
    "ag_moe",
    "ag_moe_baseline",
    "a2a_moe",
    "a2a_moe_baseline",
    "local_expert_ffn",
    "moe_router",
]


def moe_router(x, w_router, *, num_experts: int, top_k: int, valid_experts: Optional[int] = None):
    """Top-k softmax router. Returns (topk_ids i32 [m,k], topk_w f32 [m,k], aux_loss).

    ``valid_experts`` masks padding experts (EP divisibility padding) with -inf
    logits so they are never selected.
    """
    logits = jnp.einsum("md,de->me", x.astype(jnp.float32), w_router.astype(jnp.float32))
    if valid_experts is not None and valid_experts < num_experts:
        pad_mask = jnp.arange(num_experts) >= valid_experts
        logits = jnp.where(pad_mask[None, :], -jnp.inf, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_ids = lax.top_k(probs, top_k)
    topk_w = topk_w / jnp.maximum(topk_w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss
    ne = valid_experts or num_experts
    me = probs.mean(axis=0)
    ce = jnp.zeros((num_experts,), jnp.float32).at[topk_ids.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(ce.sum(), 1.0)
    aux = ne * jnp.sum(me * ce)
    return topk_ids.astype(jnp.int32), topk_w, aux


def _dispatch_tables(local_ids, valid, e_loc: int, cap: int, dtype):
    """Capacity dispatch [m, E_loc, cap] from per-(token,k) local expert ids."""
    m, k = local_ids.shape
    onehot = jax.nn.one_hot(local_ids, e_loc, dtype=jnp.float32) * valid[..., None]
    flat = onehot.reshape(m * k, e_loc)
    pos = jnp.cumsum(flat, axis=0) - flat  # position within expert, per (t,k)
    keep = (pos < cap) * flat
    disp = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32) * keep[..., None]
    return disp.reshape(m, k, e_loc, cap).astype(dtype)


def local_expert_ffn(
    x,
    topk_ids,
    topk_w,
    w_gu,
    w_down,
    *,
    e_lo: int,
    cap: int,
    act=jax.nn.silu,
    tile: Optional[Tuple[int, int, int]] = None,
):
    """FFN through the experts hosted locally; zeros for foreign-routed tokens.

    x: [m, d]; topk_ids/topk_w: [m, k]; w_gu: [E_loc, d, 2f] fused gate+up;
    w_down: [E_loc, f, d].  Returns [m, d] partial combined output.

    ``tile``: an optional CompSpec (tm, tn, tk) — non-default tiles run the
    per-expert GEMMs through ``core/comp_tiles.blocked_dot`` (clamped per
    extents), the same decomposition the Pallas grouped-matmul kernel
    blocks with, so a tuned MoE tile means the same thing on both backends.
    """
    e_loc = w_gu.shape[0]
    local = topk_ids - e_lo
    valid = ((local >= 0) & (local < e_loc)).astype(jnp.float32)
    local = jnp.where(valid > 0, local, 0).astype(jnp.int32)

    disp_mkec = _dispatch_tables(local, valid, e_loc, cap, x.dtype)  # [m,k,E,c]
    disp = disp_mkec.sum(axis=1)  # [m, E, c] — 0/1 (slots unique per (t,k))
    comb = jnp.einsum("mkec,mk->mec", disp_mkec, topk_w.astype(x.dtype))

    x_e = jnp.einsum("mec,md->ecd", disp, x)  # gather to [E_loc, cap, d]
    f = w_down.shape[1]
    if tile is not None and tuple(tile) != DEFAULT_TILE:
        tile = tuple(tile)

        def expert_dot(a, b):
            return blocked_dot(a, b, tile, accum=jnp.float32)

        h = jax.vmap(expert_dot)(x_e, w_gu)  # [E_loc, cap, 2f] f32
        h = (act(h[..., :f]) * h[..., f:]).astype(x.dtype)
        y_e = jax.vmap(expert_dot)(h, w_down)
    else:
        h = jnp.einsum("ecd,edf->ecf", x_e, w_gu, preferred_element_type=jnp.float32)
        h = (act(h[..., :f]) * h[..., f:]).astype(x.dtype)
        y_e = jnp.einsum("ecf,efd->ecd", h, w_down, preferred_element_type=jnp.float32)
    return jnp.einsum("mec,ecd->md", comb, y_e.astype(x.dtype))


def ag_moe(
    x,
    topk_ids,
    topk_w,
    w_gu,
    w_down,
    *,
    axis: str,
    capacity_factor: float = 1.25,
    act=jax.nn.silu,
    channel: Optional[BlockChannel] = None,
):
    """Overlapped AG + MoE + RS double flow (see module docstring).

    Per-shard: x [m_loc, d] (token chunk, sharded over ``axis``), expert weights
    local to the rank (EP).  Returns [m_loc, d] combined outputs for the local
    token chunk.
    """
    channel = channel or BlockChannel(axis=axis)
    rank = lax.axis_index(axis)
    m_loc, d = x.shape
    k = topk_ids.shape[1]
    e_loc = w_gu.shape[0]

    plan = _plan_for("ag_moe", channel, axis, m_loc)
    e_total = e_loc * plan.world
    m_sub = m_loc // plan.num_channels
    cap = _capacity(m_sub, k, e_total, capacity_factor)
    accum = jnp.dtype(plan.accum_dtype)
    comp_tile = tuple(channel.comp.tile)  # per-expert GEMM blocking (CompSpec)
    e_lo = rank * e_loc

    # token tiles + their dynamic routing tables flow together per channel
    chunks = [
        (
            x[c * m_sub : (c + 1) * m_sub],
            topk_ids[c * m_sub : (c + 1) * m_sub],
            topk_w[c * m_sub : (c + 1) * m_sub],
        )
        for c in range(plan.num_channels)
    ]

    def moe_tile(ctx, tile, _carry):
        xs, ids, wts = tile
        part = local_expert_ffn(
            xs, ids, wts, w_gu, w_down, e_lo=e_lo, cap=cap, act=act, tile=comp_tile
        )
        return part.astype(accum)  # the executor encodes the wire edges

    accs = run_plan(plan, moe_tile, state=chunks)
    out = accs[0] if plan.num_channels == 1 else jnp.concatenate(accs, axis=0)
    return out.astype(x.dtype)


def ag_moe_baseline(
    x,
    topk_ids,
    topk_w,
    w_gu,
    w_down,
    *,
    axis: str,
    capacity_factor: float = 1.25,
    act=jax.nn.silu,
):
    """Non-overlapping reference: AllGather tokens+tables, GroupGEMM, ReduceScatter."""
    r_axis = axis_size(axis)
    rank = lax.axis_index(axis)
    m_loc, _ = x.shape
    k = topk_ids.shape[1]
    e_loc = w_gu.shape[0]
    e_total = e_loc * r_axis
    cap = _capacity(m_loc, k, e_total, capacity_factor)  # per-chunk capacity

    xg = lax.all_gather(x, axis, axis=0, tiled=False)  # [R, m_loc, d]
    idg = lax.all_gather(topk_ids, axis, axis=0, tiled=False)
    wg = lax.all_gather(topk_w, axis, axis=0, tiled=False)
    e_lo = rank * e_loc

    # chunk-wise expert FFN keeps capacity semantics identical to the ring path
    part = jax.vmap(
        lambda xc, ic, wc: local_expert_ffn(
            xc, ic, wc, w_gu, w_down, e_lo=e_lo, cap=cap, act=act
        )
    )(xg, idg, wg)  # [R, m_loc, d]
    out = lax.psum_scatter(part, axis, scatter_dimension=0, tiled=False)
    return out.reshape(m_loc, -1)


def a2a_moe(
    x,
    topk_ids,
    topk_w,
    w_gu,
    w_down,
    *,
    axis: str,
    capacity_factor: float = 1.25,
    act=jax.nn.silu,
    channel: Optional[BlockChannel] = None,
    channel2: Optional[BlockChannel] = None,
):
    """Overlapped expert-parallel MoE: fused a2a dispatch -> GroupGEMM -> combine.

    Per-shard: x [m_loc, d] (token chunk, sharded over ``axis``), expert
    weights local to the rank (EP over the same axis).  Each step's direct
    pairwise exchange (``a2a_dispatch`` plan) lands a peer's token tile *and
    its routing tables* (the paper's f_R/f_S travel with the data); the local
    experts' grouped GEMM runs on the landed tile while the next exchange is
    in flight, and the weighted partial returns straight home along the
    reversed edge (``combine_rs`` plan).  Capacity/dropping happens at tile
    granularity: every (landing rank, origin sub-chunk) pair applies the same
    per-sub-chunk capacity slice, and dropped tokens simply contribute a zero
    partial to the combine — the same mask the unfused baseline computes, so
    the kept/dropped token set matches it bitwise.

    Returns [m_loc, d] combined outputs for the local token chunk.
    """
    channel = channel or BlockChannel(axis=axis)
    channel2 = channel2 or channel
    rank = lax.axis_index(axis)
    m_loc, _d = x.shape
    k = topk_ids.shape[1]
    e_loc = w_gu.shape[0]
    world = axis_size(axis)

    nch = effective_channels(m_loc, channel.num_channels, kind="a2a_dispatch")
    seq = build_seq_plan(("a2a_dispatch", "combine_rs"), (channel, channel2), world, nch)
    dispatch = seq.ops[0]
    e_total = e_loc * world
    m_sub = m_loc // nch
    cap = _capacity(m_sub, k, e_total, capacity_factor)
    accum = jnp.dtype(dispatch.accum_dtype)
    comp_tile = tuple(channel.comp.tile)  # per-expert GEMM blocking (CompSpec)
    e_lo = rank * e_loc

    # token tiles + their dynamic routing tables exchange together per channel
    chunks = [
        (
            x[c * m_sub : (c + 1) * m_sub],
            topk_ids[c * m_sub : (c + 1) * m_sub],
            topk_w[c * m_sub : (c + 1) * m_sub],
        )
        for c in range(nch)
    ]

    def moe_tile(ctx, tile, _carry):
        xs, ids, wts = tile
        part = local_expert_ffn(
            xs, ids, wts, w_gu, w_down, e_lo=e_lo, cap=cap, act=act, tile=comp_tile
        )
        return part.astype(accum)  # the executor encodes the wire edges

    accs = run_a2a_seq(seq, moe_tile, state=chunks)
    out = accs[0] if nch == 1 else jnp.concatenate(accs, axis=0)
    return out.astype(x.dtype)


def a2a_moe_baseline(
    x,
    topk_ids,
    topk_w,
    w_gu,
    w_down,
    *,
    axis: str,
    capacity_factor: float = 1.25,
    act=jax.nn.silu,
    num_channels: int = 1,
):
    """Non-overlapping EP reference: AllGather tokens+tables, GroupGEMM, ReduceScatter.

    ``num_channels`` must be the overlapped path's *effective* channel count:
    capacity is applied per ``m_loc / num_channels`` sub-chunk, exactly the
    tile granularity ``a2a_moe`` drops at, so the two paths keep/drop the
    same token set bitwise and differ only in summation order.
    """
    world = axis_size(axis)
    rank = lax.axis_index(axis)
    m_loc, d = x.shape
    k = topk_ids.shape[1]
    e_loc = w_gu.shape[0]
    e_total = e_loc * world
    nch = effective_channels(m_loc, num_channels, kind="a2a_dispatch", warn=False)
    m_sub = m_loc // nch
    cap = _capacity(m_sub, k, e_total, capacity_factor)  # per-sub-chunk capacity
    e_lo = rank * e_loc

    xg = lax.all_gather(x, axis, axis=0, tiled=False)  # [R, m_loc, d]
    idg = lax.all_gather(topk_ids, axis, axis=0, tiled=False)
    wg = lax.all_gather(topk_w, axis, axis=0, tiled=False)

    # sub-chunk-wise expert FFN keeps capacity semantics identical to the
    # overlapped path's per-channel tiles
    part = jax.vmap(
        lambda xc, ic, wc: local_expert_ffn(
            xc, ic, wc, w_gu, w_down, e_lo=e_lo, cap=cap, act=act
        )
    )(
        xg.reshape(world * nch, m_sub, d),
        idg.reshape(world * nch, m_sub, k),
        wg.reshape(world * nch, m_sub, k),
    )
    part = part.reshape(world, m_loc, d).astype(jnp.float32)
    out = lax.psum_scatter(part, axis, scatter_dimension=0, tiled=False)
    return out.reshape(m_loc, d).astype(x.dtype)


def _capacity(m: int, k: int, e_total: int, factor: float) -> int:
    cap = int(m * k / e_total * factor) + 1
    return max(8, -(-cap // 8) * 8)  # round up to multiple of 8
