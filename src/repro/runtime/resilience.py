"""Fault tolerance & straggler mitigation for long-running jobs.

Three cooperating pieces:

  * ``StepWatchdog`` — tracks per-step wall time; flags a straggler when a step
    exceeds ``threshold x`` the running median.  At fleet scale the same logic
    runs per host against the heartbeat stream; a persistent straggler is
    reported for eviction (triggering an elastic remesh).
  * ``ElasticMesh`` — picks the best (pod, data, model) factorization for the
    devices that are actually alive, preferring to shrink the data axis first
    (keeps TP intact so checkpoints re-place without resharding weight math).
  * ``run_resilient`` — the restart loop: run the train loop, on failure
    restore the latest checkpoint (mesh-agnostic) and continue with a freshly
    built mesh.  Tests drive it with injected failures.

The data pipeline's global cursor (data/pipeline.py) guarantees exactly-once
sample delivery across remeshes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import numpy as np

__all__ = ["StepWatchdog", "ElasticMesh", "run_resilient"]


@dataclasses.dataclass
class StepWatchdog:
    threshold: float = 3.0
    window: int = 32
    min_samples: int = 5
    _times: List[float] = dataclasses.field(default_factory=list)
    _last_start: Optional[float] = None
    stragglers: int = 0

    def start(self):
        self._last_start = time.monotonic()

    def stop(self) -> bool:
        """Record the step; True if this step was a straggler."""
        dt = time.monotonic() - self._last_start
        flagged = False
        if len(self._times) >= self.min_samples:
            med = float(np.median(self._times[-self.window:]))
            if dt > self.threshold * med:
                self.stragglers += 1
                flagged = True
        self._times.append(dt)
        return flagged

    def median(self) -> float:
        return float(np.median(self._times)) if self._times else 0.0


class ElasticMesh:
    """Factorize a (possibly reduced) device count into mesh axes."""

    def __init__(self, target_model: int = 16, axis_names=("pod", "data", "model")):
        self.target_model = target_model
        self.axis_names = axis_names

    def plan(self, n_devices: int):
        """Largest usable (pod, data, model) with model as close to target as
        possible (shrinks data first, then model by powers of two)."""
        model = self.target_model
        while model > 1 and n_devices % model:
            model //= 2
        rest = n_devices // model
        # pods only if rest splits evenly in 2 (multi-pod); else single pod
        pod = 2 if rest % 2 == 0 and rest >= 2 else 1
        data = rest // pod
        return {"pod": pod, "data": data, "model": model}

    def build(self, n_devices: Optional[int] = None):
        import jax
        from repro.compat import make_mesh

        n = n_devices or len(jax.devices())
        p = self.plan(n)
        usable = p["pod"] * p["data"] * p["model"]
        return make_mesh((p["pod"], p["data"], p["model"]), self.axis_names), usable


def run_resilient(make_state: Callable, run: Callable, *, max_failures: int = 3,
                  on_failure: Optional[Callable] = None):
    """Restart loop.

    make_state() -> state   (builds mesh, restores latest checkpoint)
    run(state)   -> result  (train loop; raises on simulated/real failure)
    """
    failures = 0
    while True:
        state = make_state()
        try:
            return run(state)
        except Exception as e:  # noqa: BLE001 — any device/host failure
            failures += 1
            if failures > max_failures:
                raise
            if on_failure is not None:
                on_failure(e, failures)
