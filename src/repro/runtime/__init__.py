from repro.runtime.resilience import StepWatchdog, ElasticMesh, run_resilient

__all__ = ["StepWatchdog", "ElasticMesh", "run_resilient"]
