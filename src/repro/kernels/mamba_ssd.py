"""Mamba-2 SSD (state-space duality) — chunked-parallel form.

``ssd_chunked`` is the MXU-friendly O(L·Q) chunked algorithm (Dao & Gu 2024):
quadratic attention-like intra-chunk matmuls + a lax.scan over chunk states.
Oracle: kernels.ref.ssd_ref (sequential recurrence).  Used by the mamba2/zamba2
architectures; sub-quadratic in sequence length (long_500k shapes).

``ssd_intra_chunk`` is the Pallas kernel for the quadratic intra-chunk term
(the compute hot-spot), tiled per (batch·chunk, head) with fp32 accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import backend
from repro.backend import pl

__all__ = ["ssd_chunked", "ssd_intra_chunk"]


def ssd_chunked(x, dt, a_log, b, c, *, chunk: int = 64, h_init=None, return_state: bool = False):
    """Chunked SSD. Shapes as in ref.ssd_ref:

    x [B,L,H,P], dt [B,L,H] (positive), a_log [H], b/c [B,L,G,N] -> y [B,L,H,P].
    """
    bsz, length, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    q = min(chunk, length)
    orig_len = length
    if length % q:
        # pad to a chunk multiple with dt=0 steps (decay 1, zero input —
        # exact identity on the state), slice the output back
        pad = q - length % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        length = length + pad
    nc = length // q

    a = -jnp.exp(a_log.astype(jnp.float32))  # [H] negative
    dt32 = dt.astype(jnp.float32)
    da = dt32 * a[None, None, :]  # [B,L,H] per-step log-decay
    bx = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    cx = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    xdt = x.astype(jnp.float32) * dt32[..., None]  # dt-weighted inputs

    # chunked views: [B, NC, Q, ...]
    def chunked(t):
        return t.reshape(bsz, nc, q, *t.shape[2:])

    da_c = chunked(da)  # [B,NC,Q,H]
    cum = jnp.cumsum(da_c, axis=2)  # within-chunk cumulative
    total = cum[:, :, -1]  # [B,NC,H] chunk log-decay
    x_c, b_c, c_c = chunked(xdt), chunked(bx), chunked(cx)

    # ---- intra-chunk (quadratic in Q, attention-like) ----
    # L[qi, qj] = exp(cum_qi - cum_qj) for qj <= qi
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,NC,Q,Q,H]
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", c_c, b_c)  # C_q · B_k
    y_intra = jnp.einsum("bcqkh,bcqkh,bckhp->bcqhp", scores, decay, x_c)

    # ---- chunk states & inter-chunk scan ----
    # S_c = sum_k exp(total - cum_k) B_k ⊗ xdt_k   [B,NC,H,N,P]
    state_decay = jnp.exp(total[:, :, None, :] - cum)  # [B,NC,Q,H]
    s_c = jnp.einsum("bckhn,bckh,bckhp->bchnp", b_c, state_decay, x_c)

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32) if h_init is None else h_init.astype(jnp.float32)

    def scan_fn(hprev, inp):
        s_chunk, tot = inp  # [B,H,N,P], [B,H]
        hnew = hprev * jnp.exp(tot)[..., None, None] + s_chunk
        return hnew, hprev

    (h_last, h_prevs) = jax.lax.scan(
        scan_fn,
        h0,
        (jnp.moveaxis(s_c, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # [B,NC,H,N,P]

    # ---- inter-chunk contribution ----
    y_inter = jnp.einsum("bcqhn,bcqh,bchnp->bcqhp", c_c, jnp.exp(cum), h_prevs)

    y = (y_intra + y_inter).reshape(bsz, length, h, p)[:, :orig_len].astype(x.dtype)
    if return_state:
        return y, h_last
    return y


# -----------------------------------------------------------------------------
# Pallas kernel for the intra-chunk quadratic term
# -----------------------------------------------------------------------------


def _ssd_intra_kernel(cum_ref, cb_ref, x_ref, o_ref, *, q: int):
    """One (batch-chunk, head) tile: y = (CB * exp(cum_i - cum_j) * tril) @ x.

    cum_ref: [1, q, 1] cumulative log-decay; cb_ref: [1, q, q] C·B scores;
    x_ref: [1, q, p] dt-weighted inputs; o_ref: [1, q, p].
    """
    cum = cum_ref[0].astype(jnp.float32)  # [q, 1]
    diff = cum - cum.reshape(1, q)  # [q, q] cum_i - cum_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where(ii >= jj, jnp.exp(diff), 0.0)
    g = cb_ref[0].astype(jnp.float32) * decay
    o_ref[0] = jax.lax.dot_general(
        g,
        x_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(cum, cb, xdt, *, interpret=False):
    """Intra-chunk SSD term. cum: [T, Q] (T = B*NC*H tiles), cb: [T, Q, Q],
    xdt: [T, Q, P] -> y: [T, Q, P]."""
    t, q = cum.shape
    p = xdt.shape[-1]
    return backend.pallas_call(
        functools.partial(_ssd_intra_kernel, q=q),
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, q, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, q), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, q, p), xdt.dtype),
        dimension_semantics=("parallel",),
        interpret=interpret,
    )(cum.reshape(t, q, 1), cb, xdt)
