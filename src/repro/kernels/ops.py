"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to auto: True when no TPU is present (CPU validation via
the backend's emulated target), False on real TPUs (Mosaic lowering).
"""
from __future__ import annotations

from repro import backend

from repro.kernels.matmul import matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels.grouped_matmul import grouped_matmul
from repro.kernels.ag_gemm import ag_gemm_shard
from repro.kernels.gemm_rs import gemm_rs_shard
from repro.kernels.mamba_ssd import ssd_chunked, ssd_intra_chunk

__all__ = [
    "matmul",
    "flash_attention",
    "grouped_matmul",
    "ag_gemm_shard",
    "gemm_rs_shard",
    "ssd_chunked",
    "ssd_intra_chunk",
    "auto_interpret",
]


def auto_interpret() -> bool:
    """True when running without a TPU (kernels execute in interpret mode)."""
    return backend.default_interpret()
