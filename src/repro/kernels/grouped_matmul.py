"""Grouped (MoE) matmul Pallas kernel driven by *dynamic mapping tables*.

The paper's dynamic tile-centric mapping (§4.1): tile -> expert assignment is a
runtime lookup table (f_R), filled by the router; only the *access pattern* is
compiled.  Here the table is a scalar-prefetch operand — Mosaic reads
``tile_expert[tile_id]`` inside the BlockSpec index_map to choose which
expert's weight block to DMA into VMEM.  This is the TPU-native equivalent of
the paper's table-driven Triton codegen (Fig. 5).

x rows are expert-sorted and tile-aligned (build_moe_dynamic_mapping pads each
group to the row-tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import backend
from repro.backend import pl
from repro.core.comp_tiles import largest_divisor

__all__ = ["grouped_matmul"]


@functools.partial(jax.jit, static_argnames=("tile", "out_dtype", "interpret"))
def grouped_matmul(x, w, tile_expert, *, tile=(128, 128, 128), out_dtype=None, interpret=False):
    """x: [M, K] (expert-sorted), w: [E, K, N], tile_expert: [M // bm] i32.

    Returns [M, N] with rows of tile t multiplied by w[tile_expert[t]].

    ``tile`` accepts any tuner-resolved (tm, tn, tk): each dim clamps to the
    largest divisor of its extent (the shared CompSpec degrade rule) instead
    of refusing non-dividing requests — note the row tile must still match
    the ``tile_expert`` table the mapping was built with.
    """
    out_dtype = out_dtype or x.dtype
    m, k = x.shape
    _, k2, n = w.shape
    assert k == k2
    bm = largest_divisor(m, min(int(tile[0]), m))
    bn = largest_divisor(n, min(int(tile[1]), n))
    bk = largest_divisor(k, min(int(tile[2]), k))
    assert tile_expert.shape == (m // bm,), (tile_expert.shape, m, bm)
    n_k = k // bk

    grid_spec = backend.prefetch_grid_spec(
        num_scalar_prefetch=1,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk, expert: (i, kk)),
            # dynamic mapping f_R: the runtime table chooses the weight block
            pl.BlockSpec((1, bk, bn), lambda i, j, kk, expert: (expert[i], kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk, expert: (i, j)),
        scratch_shapes=[backend.vmem_scratch((bm, bn), jnp.float32)],
    )

    def _kernel(expert_ref, x_ref, w_ref, o_ref, acc_ref):
        del expert_ref  # consumed by the index_maps above

        @pl.when(pl.program_id(2) == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(x_ref[...], w_ref[0], preferred_element_type=jnp.float32)

        @pl.when(pl.program_id(2) == n_k - 1)
        def _store():
            o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    return backend.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        interpret=interpret,
    )(tile_expert, x, w)
