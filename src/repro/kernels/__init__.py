"""Pallas TPU kernels for TileLink's compute hot-spots.

Compute kernels: matmul, flash_attention, grouped_matmul (dynamic-mapping MoE),
ssd (Mamba-2).  Fused compute-communication kernels (remote DMA + semaphores):
ag_gemm_shard, gemm_rs_shard.  Oracles live in ref.py; tests sweep shapes and
dtypes against them.
"""
from repro.kernels.ops import (
    matmul,
    flash_attention,
    grouped_matmul,
    ag_gemm_shard,
    gemm_rs_shard,
    ssd_chunked,
    ssd_intra_chunk,
    auto_interpret,
)
from repro.kernels import ref

__all__ = [
    "matmul",
    "flash_attention",
    "grouped_matmul",
    "ag_gemm_shard",
    "gemm_rs_shard",
    "ssd_chunked",
    "ssd_intra_chunk",
    "auto_interpret",
    "ref",
]
