"""Fused GEMM + ring ReduceScatter Pallas kernel — faithful port of paper Fig. 4.

Stage ``s`` at rank ``r``:
  1. ``consumer_tile_wait``   — wait for the partial accumulator pushed by rank
     ``r+1`` at its stage ``s-1`` (``wait_recv`` on the per-stage DMA semaphore);
  2. compute the GEMM tile for segment ``(r + s + 1) % R``
     (``schedules.ring_rs_segment`` — the paper's ``seg = (rank+stage+1) % W``)
     while the *next* incoming partial is still in flight;
  3. add the received partial (TopK-reduce-style epilogue fusion);
  4. ``tile_push_data`` + ``peer_tile_notify`` — push the new partial to rank
     ``r-1`` (paper line 11: ``to_rank = (rank - 1 + WORLD_SIZE) % WORLD_SIZE``).

After R stages the accumulator holds the fully reduced segment ``r`` and is
stored to the local output (paper lines 22-23).

Race-freedom: receive buffers are slot-per-stage (written exactly once per ring
pass — no credit counters needed); the outgoing staging buffer is reused across
stages, guarded by ``wait_send`` (release, §4.2) before each overwrite.
Partials flow in fp32 for reduction fidelity.

VMEM budget: the flowing accumulator is [m_loc, N] resident in VMEM; pick
m_loc * N * 4B ≲ 4 MiB per call (the TP shard sizes used by the models obey
this; larger N is tiled by the caller over column blocks).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import backend
from repro.backend import pl
from repro.core import primitives
from repro.core.channels import BlockChannel

__all__ = ["gemm_rs_shard"]


def _gemm_rs_kernel(x_ref, w_ref, o_ref, x_vmem, acc, prev, out_stage, out_cast,
                    copy_sem, send_sem, recv_sems, rbuf, *, axis: str,
                    world: int, n_tiles: int, m_loc: int, bn: int):
    s = pl.program_id(0)
    j = pl.program_id(1)
    my = lax.axis_index(axis)
    left = lax.rem((my - 1) + world, world)
    seg = lax.rem(my + s + 1, world)

    def _push_rdma(stage):
        # identical descriptor on sender & receiver (SPMD) — sender start()s,
        # receiver wait_recv()s, sender wait_send()s before staging reuse
        return primitives.make_tile_push(
            src_ref=out_stage,
            dst_ref=rbuf.at[stage],
            send_sem=send_sem,
            recv_sem=recv_sems.at[stage],
            rank=left,
        )

    @pl.when(j == 0)
    def _stage_setup():
        # shape mapping f_S: bring segment `seg` of x into VMEM
        c = backend.make_async_copy(
            x_ref.at[pl.ds(seg * m_loc, m_loc), :], x_vmem, copy_sem
        )
        c.start()
        c.wait()

        @pl.when(s > 0)
        def _recv_prev():
            # consumer_tile_wait (acquire): partial from rank r+1, stage s-1
            _push_rdma(s - 1).wait_recv()
            c2 = backend.make_async_copy(rbuf.at[s - 1], prev, copy_sem)
            c2.start()
            c2.wait()
            # release: our stage s-1 push drained before out_stage is reused
            _push_rdma(s - 1).wait_send()

    # GEMM tile j for segment `seg` (+ fused reduction of the incoming partial)
    part = jnp.dot(x_vmem[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(s > 0)
    def _add_prev():
        acc[:, pl.ds(j * bn, bn)] = part + prev[:, pl.ds(j * bn, bn)]

    @pl.when(s == 0)
    def _no_prev():
        acc[:, pl.ds(j * bn, bn)] = part

    @pl.when(j == n_tiles - 1)
    def _stage_finish():
        @pl.when(s < world - 1)
        def _push():
            out_stage[...] = acc[...]
            _push_rdma(s).start()  # tile_push_data + peer_tile_notify

        @pl.when(s == world - 1)
        def _store():
            # paper lines 22-23: final stage stores the reduced segment (== my)
            out_cast[...] = acc[...].astype(out_cast.dtype)
            c = backend.make_async_copy(out_cast, o_ref, copy_sem)
            c.start()
            c.wait()


def gemm_rs_shard(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    channel: Optional[BlockChannel] = None,
    world_size: int,
    bn: int = 128,
    interpret: bool = True,
):
    """Per-shard fused GEMM+RS. x: [M, k_loc], w: [k_loc, N] -> [M/R, N].

    Call inside shard_map over ``channel.axis``; partials accumulate in fp32.
    ``interpret=False`` lowers to Mosaic only on TPU hosts — on a CPU-only
    host the emulated backend target interprets regardless.
    """
    channel = channel or BlockChannel(axis="model")
    axis = channel.axis
    m_glob, k_loc = x.shape
    _, n = w.shape
    assert m_glob % world_size == 0
    m_loc = m_glob // world_size
    bn = min(bn, n)
    assert n % bn == 0
    n_tiles = n // bn

    kern = functools.partial(
        _gemm_rs_kernel, axis=axis, world=world_size, n_tiles=n_tiles,
        m_loc=m_loc, bn=bn,
    )
    return backend.pallas_call(
        kern,
        grid=(world_size, n_tiles),
        in_specs=[
            pl.BlockSpec(memory_space=backend.ANY),
            pl.BlockSpec((k_loc, bn), lambda s, j: (0, j)),
        ],
        out_specs=pl.BlockSpec(memory_space=backend.ANY),
        out_shape=jax.ShapeDtypeStruct((m_loc, n), x.dtype),
        scratch_shapes=[
            backend.vmem_scratch((m_loc, k_loc), x.dtype),   # x segment
            backend.vmem_scratch((m_loc, n), jnp.float32),   # stage accumulator
            backend.vmem_scratch((m_loc, n), jnp.float32),   # received partial
            backend.vmem_scratch((m_loc, n), jnp.float32),   # staged outgoing
            backend.vmem_scratch((m_loc, n), x.dtype),       # final cast
            backend.dma_semaphore(),                         # local copies
            backend.dma_semaphore(),                         # sends
            backend.dma_semaphore((world_size,)),            # per-stage recv
            backend.vmem_scratch((world_size, m_loc, n), jnp.float32),  # rbuf
        ],
        dimension_semantics=("arbitrary", "arbitrary"),
        interpret=interpret,
    )(x, w)
