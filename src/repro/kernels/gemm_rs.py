"""Fused GEMM + ReduceScatter Pallas kernel — paper Fig. 4, plan-driven.

Driven by the SAME :class:`~repro.core.plan.TilePlan` as the XLA backend: the
plan's reduce-scatter view (the time reversal of the order's source schedule —
for "ring" in the plan's default orientation exactly the paper's
``seg = (rank + stage + 1) % W``) is baked in
as int32 segment/destination tables, so ``CommSpec.order``, ``num_channels``
(column chunking, C independent flows), ``CompSpec.accum_dtype`` (the dtype
partials are *reduced* in), ``BlockChannel.quant`` (the wire dtype partials
*travel* in — a float wire is cast at each send edge and widened back before
the add) and the CompSpec (tm, tn, tk) compute tile behave identically on
both backends.

Stage ``s``, channel ``c`` at rank ``r``:
  1. ``consumer_tile_wait``   — wait for the partial pushed by the plan's
     stage-(s-1) peer (``wait_recv`` on the per-(stage, channel) semaphore);
  2. compute the GEMM tiles for segment ``seg_tbl[c, s, r]`` while the *next*
     incoming partial is still in flight;
  3. add the received partial (TopK-reduce-style epilogue fusion);
  4. ``tile_push_data`` + ``peer_tile_notify`` — push the new partial to
     ``dst_tbl[c, s, r]`` (for "ring": rank r-1, paper line 11).

After R stages each channel's accumulator holds the fully reduced home
segment and is stored to the local output columns (paper lines 22-23).

Race-freedom: receive buffers are slot-per-(stage, channel) (written exactly
once per pass — no credit counters needed); the outgoing partial is pushed
straight from the accumulator's channel columns, guarded by ``wait_send``
(release, §4.2) on a *per-channel* send semaphore before those columns are
overwritten next stage (a shared send semaphore makes the release credits of
concurrent channels interchangeable — a WAR race ``repro.analysis`` flags).

VMEM budget: the flowing accumulator is [m_loc, N] resident in VMEM; pick
m_loc * N * 4B ≲ 4 MiB per call (the TP shard sizes used by the models obey
this; larger N is tiled by the caller over column blocks).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import backend
from repro.backend import pl
from repro.core import primitives
from repro.core.channels import BlockChannel
from repro.core.comp_tiles import DEFAULT_TILE, blocked_dot, largest_divisor
from repro.core.mapping import effective_channels
from repro.core.plan import build_plan
from repro.core.quant import PackedWeight

__all__ = ["gemm_rs_shard"]


def _gemm_rs_kernel(
    *refs,
    axis: str,
    world: int,
    nch: int,
    n_tiles: int,
    m_loc: int,
    n_sub: int,
    tm: int,
    bn: int,
    tk: int,
    accum,
    packed: bool,
    split: bool,
):
    if packed:
        # weight-only dequant-GEMM: int8/int4 codes + per-column scale/zero
        (x_ref, w_ref, scale_ref, zero_ref, seg_tbl, dst_tbl, o_ref,
         x_vmem, acc, prev, out_cast, copy_sem, send_sems, recv_sems,
         rbuf, *rest) = refs
    else:
        (x_ref, w_ref, seg_tbl, dst_tbl, o_ref,
         x_vmem, acc, prev, out_cast, copy_sem, send_sems, recv_sems,
         rbuf, *rest) = refs
        scale_ref = zero_ref = None
    # when the wire dtype differs from the accumulation dtype partials are
    # cast into a per-channel send staging buffer before each hop (the
    # accumulator itself stays in accum dtype)
    send_buf = rest[0] if split else None
    s = pl.program_id(0)
    c = pl.program_id(1)
    j = pl.program_id(2)
    my = lax.axis_index(axis)
    flat = (c * world + s) * world + my
    seg = seg_tbl[flat]  # segment this rank reduces at stage s
    dst = dst_tbl[flat]  # peer that reduces it at stage s+1

    def _push_rdma(stage):
        # identical descriptor on sender & receiver (SPMD) — sender start()s,
        # receiver wait_recv()s, sender wait_send()s before the source
        # columns are overwritten.  Source: the channel's accumulator columns
        # (wire == accum), or the channel's rows of the wire-dtype staging
        # buffer (wire != accum).  The send semaphore is per-channel: with a
        # shared one the wait_send credits of concurrent channels are
        # interchangeable, so channel c's stage-(s-1) push could still be
        # reading its source when stage s overwrites it (analysis.protocol
        # flags this as overwritten_before_wait for num_channels >= 2).
        if split:
            src = send_buf.at[pl.ds(c * m_loc, m_loc), :]
        else:
            src = acc.at[:, pl.ds(c * n_sub, n_sub)]
        return primitives.make_tile_push(
            src_ref=src,
            dst_ref=rbuf.at[stage * nch + c],
            send_sem=send_sems.at[c],
            recv_sem=recv_sems.at[stage * nch + c],
            rank=dst,
        )

    # channels sharing a direction reduce the same segment at the same stage
    # (always for ring/all2all) — skip the HBM->VMEM refetch when the segment
    # x_vmem already holds (previous channel, same stage) is the one we need
    prev_flat = (jnp.maximum(c - 1, 0) * world + s) * world + my
    seg_is_stale = jnp.logical_or(c == 0, seg != seg_tbl[prev_flat])

    @pl.when(j == 0)
    def _stage_setup():
        @pl.when(seg_is_stale)
        def _fetch_seg():
            # shape mapping f_S: bring segment `seg` of x into VMEM
            cp = backend.make_async_copy(x_ref.at[pl.ds(seg * m_loc, m_loc), :], x_vmem, copy_sem)
            cp.start()
            cp.wait()

        @pl.when(s > 0)
        def _recv_prev():
            # consumer_tile_wait (acquire): stage s-1 partial for channel c
            _push_rdma(s - 1).wait_recv()
            cp2 = backend.make_async_copy(rbuf.at[(s - 1) * nch + c], prev, copy_sem)
            cp2.start()
            cp2.wait()
            # release: our stage s-1 push drained before acc cols are reused
            _push_rdma(s - 1).wait_send()

    # GEMM tile j for segment `seg` (+ fused reduction of the incoming
    # partial); a tuned (tm, tk) decomposes the [m_loc, k_loc] x [k_loc, bn]
    # contraction into explicit MXU blocks, the default keeps one dot
    w_val = w_ref[...]
    if packed:
        # dequant in VMEM right before the MXU: the [k_loc, bn] block arrives
        # as int8 codes (int4 codes in an int8 container), so HBM->VMEM moves
        # 1/2-1/4 the bytes; scales/zeros are per output column
        w_val = (w_val.astype(accum) - zero_ref[0, :][None, :]) * scale_ref[0, :][None, :]
    part = blocked_dot(x_vmem[...], w_val, (tm, bn, tk), accum=accum, unroll=True)
    col = c * n_sub + j * bn

    @pl.when(s > 0)
    def _add_prev():
        acc[:, pl.ds(col, bn)] = part + prev[:, pl.ds(j * bn, bn)].astype(part.dtype)

    @pl.when(s == 0)
    def _no_prev():
        acc[:, pl.ds(col, bn)] = part

    @pl.when(j == n_tiles - 1)
    def _stage_finish():
        @pl.when(s < world - 1)
        def _push():
            if split:
                # wire-dtype cast at the send edge; safe to overwrite — the
                # stage-(s-1) push from these rows drained at this stage's
                # j == 0 wait_send
                send_buf[pl.ds(c * m_loc, m_loc), :] = (
                    acc[:, pl.ds(c * n_sub, n_sub)].astype(send_buf.dtype))
            _push_rdma(s).start()  # tile_push_data + peer_tile_notify

        @pl.when(s == world - 1)
        def _store():
            # paper lines 22-23: final stage stores the reduced home segment
            out_cast[...] = acc[:, pl.ds(c * n_sub, n_sub)].astype(out_cast.dtype)
            cp = backend.make_async_copy(out_cast, o_ref.at[:, pl.ds(c * n_sub, n_sub)], copy_sem)
            cp.start()
            cp.wait()


def gemm_rs_shard(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    channel: Optional[BlockChannel] = None,
    world_size: int,
    bn: Optional[int] = None,
    interpret: bool = True,
):
    """Per-shard fused GEMM+RS. x: [M, k_loc], w: [k_loc, N] -> [M/R, N].

    Call inside shard_map over ``channel.axis``; the schedule (order,
    channels), the accumulation dtype (``channel.comp.accum_dtype``) and the
    wire dtype partials travel in (``channel.quant`` — a float wire casts at
    each send edge, the default inherits the accumulation dtype), and the
    (tm, tn, tk) compute tile come from ``channel`` via the plan layer;
    ``bn`` overrides ``channel.comp.tile[1]``.  ``w`` may be a
    :class:`~repro.core.quant.PackedWeight` (weight-only int8/int4): the
    weight blocks stream HBM->VMEM as integer codes and are dequantized in
    VMEM right before the MXU.  Quantized *activation* wires (int8/fp8) are
    XLA-backend only — the scale side-channel per remote DMA is not plumbed
    here.  ``interpret=False`` lowers to Mosaic only on TPU hosts — on a
    CPU-only host the emulated backend target interprets regardless.
    """
    channel = channel or BlockChannel(axis="model")
    if channel.quant.is_quantized:
        raise NotImplementedError(
            "gemm_rs_shard: quantized activation wires (QuantSpec.wire_dtype="
            f"{channel.quant.wire_dtype!r}) are not supported by the fused "
            "Pallas kernel; use backend='xla' (weight-only quantization via "
            "PackedWeight IS supported here)")
    axis = channel.axis
    m_glob, k_loc = x.shape
    packed = isinstance(w, PackedWeight)
    _, n = w.shape
    assert m_glob % world_size == 0
    m_loc = m_glob // world_size

    nch = effective_channels(n, channel.num_channels, kind="matmul_rs")
    plan = build_plan("matmul_rs", channel, world_size, nch)
    n_sub = n // nch
    comp_tile = tuple(channel.comp.tile)
    bn = bn or comp_tile[1]
    bn = largest_divisor(n_sub, bn)
    n_tiles = n_sub // bn
    if comp_tile == DEFAULT_TILE:
        # sentinel: backend-chosen blocking — whole-segment rows/contraction
        tm, tk = m_loc, k_loc
    else:
        tm = largest_divisor(m_loc, comp_tile[0])
        tk = largest_divisor(k_loc, comp_tile[2])
    accum = jnp.dtype(plan.accum_dtype)
    wire = jnp.dtype(plan.flow_dtype)
    split = wire != accum
    seg_tbl = jnp.asarray(plan.rs_seg_tables(), jnp.int32).reshape(-1)
    dst_tbl = jnp.asarray(plan.rs_dst_tables(), jnp.int32).reshape(-1)

    kern = functools.partial(
        _gemm_rs_kernel,
        axis=axis,
        world=world_size,
        nch=nch,
        n_tiles=n_tiles,
        m_loc=m_loc,
        n_sub=n_sub,
        tm=tm,
        bn=bn,
        tk=tk,
        accum=accum,
        packed=packed,
        split=split,
    )
    in_specs = [
        pl.BlockSpec(memory_space=backend.ANY),
        pl.BlockSpec((k_loc, bn), lambda s, c, j: (0, c * (n_sub // bn) + j)),
    ]
    operands = [x]
    if packed:
        operands.append(w.q)
        # per-output-column scale/zero ride as (1, bn) blocks next to the
        # weight block they dequantize (zero points default to 0 — symmetric)
        zero = w.zero if w.zero is not None else jnp.zeros_like(w.scale)
        operands.extend([w.scale.reshape(1, n), zero.reshape(1, n)])
        in_specs.extend([
            pl.BlockSpec((1, bn), lambda s, c, j: (0, c * (n_sub // bn) + j)),
            pl.BlockSpec((1, bn), lambda s, c, j: (0, c * (n_sub // bn) + j)),
        ])
    else:
        operands.append(w)
    in_specs.extend([
        pl.BlockSpec(memory_space=backend.ANY),  # segment schedule table
        pl.BlockSpec(memory_space=backend.ANY),  # push-dst schedule table
    ])
    operands.extend([seg_tbl, dst_tbl])
    scratch = [
        backend.vmem_scratch((m_loc, k_loc), x.dtype),  # x segment
        backend.vmem_scratch((m_loc, n), accum),  # stage accumulator
        backend.vmem_scratch((m_loc, n_sub), wire),  # received partial
        backend.vmem_scratch((m_loc, n_sub), x.dtype),  # final cast
        backend.dma_semaphore(),  # local copies
        backend.dma_semaphore((nch,)),  # per-channel sends (release order)
        backend.dma_semaphore((world_size * nch,)),  # per-(stage,ch) recv
        backend.vmem_scratch((world_size * nch, m_loc, n_sub), wire),  # rbuf
    ]
    if split:
        # per-channel wire-dtype send staging (rows c*m_loc:(c+1)*m_loc)
        scratch.append(backend.vmem_scratch((nch * m_loc, n_sub), wire))
    return backend.pallas_call(
        kern,
        grid=(world_size, nch, n_tiles),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(memory_space=backend.ANY),
        out_shape=jax.ShapeDtypeStruct((m_loc, n), x.dtype),
        scratch_shapes=scratch,
        dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        interpret=interpret,
    )(*operands)
