"""Fused AllGather + GEMM Pallas kernel (paper §5, AG+GEMM; push mode).

One kernel per device (launched under shard_map over the TP axis) both
*communicates* and *computes*, driven by the SAME :class:`~repro.core.plan.
TilePlan` the XLA backend executes — the plan's per-(channel, step, rank)
source and destination tables are baked into the kernel as int32 schedule
tables, so ``CommSpec.order`` (ring / bidir_ring / all2all) and
``num_channels`` behave identically on both backends:

  * step ``s``, channel ``c``: the sub-chunk this rank holds (origin
    ``src_tbl[c, s, my]``) is forwarded to ``dst_tbl[c, s, my]`` with
    ``tile_push_data`` (``pltpu.make_async_remote_copy`` on the ICI DMA
    engine) while the MXU computes GEMM tiles on it — communication and
    computation tiles are *decoupled*: the comm tile is the [m_sub, K]
    channel sub-chunk (f_C), the compute tile is the CompSpec (tm, bn, tk)
    blocking of it (``core/comp_tiles.blocked_dot``; the default tile keeps
    the whole-chunk dot), iterated in the inner grid dimension;
  * ``consumer_tile_wait`` is the ``wait_recv`` on the per-(step, channel)
    DMA semaphore — acquire semantics; loads of the gathered chunk are
    emitted only after it (paper §4.2's strict-dependency rule, enforced by
    construction).

Slot-per-(origin, channel) gather buffer makes the schedule race-free without
credit counters: every tile visits every rank exactly once (the plan's source
schedules are per-step and per-rank permutations), so each slot is written
exactly once per pass.

Validated on CPU via the backend's emulated target (the interpreter simulates
the inter-device DMAs + semaphores); on real TPU the same code lowers to
Mosaic with ICI RDMA.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import backend
from repro.backend import pl
from repro.core import primitives
from repro.core.channels import BlockChannel
from repro.core.comp_tiles import DEFAULT_TILE, blocked_dot, largest_divisor
from repro.core.mapping import effective_channels
from repro.core.plan import build_plan
from repro.core.quant import PackedWeight

__all__ = ["ag_gemm_shard"]


def _ag_gemm_kernel(
    *refs,
    axis: str,
    world: int,
    nch: int,
    n_tiles: int,
    m_loc: int,
    m_sub: int,
    tm: int,
    bn: int,
    tk: int,
    accum,
    packed: bool,
):
    if packed:
        # weight-only dequant-GEMM: int8/int4 codes + per-column scale/zero
        (x_ref, w_ref, scale_ref, zero_ref, src_tbl, dst_tbl, o_ref,
         buf, x_vmem, acc, out_tile, copy_sem, send_sem, recv_sems,
         out_sem) = refs
    else:
        (x_ref, w_ref, src_tbl, dst_tbl, o_ref,
         buf, x_vmem, acc, out_tile, copy_sem, send_sem, recv_sems,
         out_sem) = refs
        scale_ref = zero_ref = None
    s = pl.program_id(0)
    c = pl.program_id(1)
    j = pl.program_id(2)
    my = lax.axis_index(axis)
    flat = (c * world + s) * world + my
    src = src_tbl[flat]  # origin (== gather slot) consumed this step
    dst = dst_tbl[flat]  # peer the held tile is forwarded to
    slot = src * nch + c

    @pl.when(jnp.logical_and(s == 0, j == 0))
    def _local_seed():
        # stage channel c of the own shard into its gather slot (producer tile)
        cp = backend.make_async_copy(
            x_ref.at[pl.ds(c * m_sub, m_sub), :], buf.at[my * nch + c], copy_sem
        )
        cp.start()
        cp.wait()

    def _fwd_rdma():
        # forward from the VMEM staging copy (x_vmem) to the peer's gather
        # slot — src and dst must not alias for the DMA engine
        return primitives.make_tile_push(
            src_ref=x_vmem,
            dst_ref=buf.at[slot],
            send_sem=send_sem,
            recv_sem=recv_sems.at[s * nch + c],
            rank=dst,
        )

    @pl.when(j == 0)
    def _comm():
        # consumer_tile_wait + bring the tile to VMEM for the MXU
        cp = backend.make_async_copy(buf.at[slot], x_vmem, copy_sem)
        cp.start()
        cp.wait()

        # tile_push_data: forward the held tile along the plan's schedule
        # (overlaps with this step's GEMM tiles below)
        @pl.when(s < world - 1)
        def _():
            _fwd_rdma().start()

    # compute tile j of the consumer GEMM (CompSpec tile, accum dtype);
    # a tuned (tm, tk) decomposes the [m_sub, k] x [k, bn] contraction into
    # explicit MXU blocks, the default keeps the whole-chunk dot
    w_val = w_ref[...]
    if packed:
        # dequant in VMEM right before the MXU: the [k, bn] block arrives as
        # int8 codes (int4 codes in an int8 container), so HBM->VMEM moves
        # 1/2-1/4 the bytes; scales/zeros are per output column
        w_val = (w_val.astype(accum) - zero_ref[0, :][None, :]) * scale_ref[0, :][None, :]
    acc[...] = blocked_dot(x_vmem[...], w_val, (tm, bn, tk), accum=accum, unroll=True)
    out_tile[...] = acc[...].astype(out_tile.dtype)
    oc = backend.make_async_copy(
        out_tile,
        o_ref.at[pl.ds(src * m_loc + c * m_sub, m_sub), pl.ds(j * bn, bn)],
        out_sem,
    )
    oc.start()
    oc.wait()

    @pl.when(jnp.logical_and(j == n_tiles - 1, s < world - 1))
    def _finish_comm():
        # wait_send: x_vmem is drained (safe to reuse next channel/step);
        # wait_recv: the tile for step s+1 arrived
        _fwd_rdma().wait()


def ag_gemm_shard(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    channel: Optional[BlockChannel] = None,
    world_size: int,
    bn: Optional[int] = None,
    interpret: bool = True,
):
    """Per-shard fused AG+GEMM. x: [m_loc, K], w: [K, n_loc] -> [R*m_loc, n_loc].

    Call inside shard_map over ``channel.axis``.  The schedule (order,
    channels), the accumulation dtype (``channel.comp.accum_dtype`` — the
    reduction dtype, independent of what travels), and the (tm, tn, tk)
    compute tile come from ``channel`` via the plan layer; ``bn`` overrides
    ``channel.comp.tile[1]``.  ``w`` may be a
    :class:`~repro.core.quant.PackedWeight` (weight-only int8/int4): the
    weight blocks stream HBM->VMEM as integer codes and are dequantized in
    VMEM right before the MXU.  Quantized *activation* wires
    (``channel.quant.wire_dtype`` int8/fp8) are XLA-backend only — the scale
    side-channel per remote DMA is not plumbed here; this raises rather than
    silently sending unscaled codes.  ``interpret=True`` runs the
    interpreter (CPU validation); False lowers to Mosaic on TPU hosts — on a
    CPU-only host the emulated backend target interprets regardless, since
    there is no Mosaic toolchain to compile with.
    """
    channel = channel or BlockChannel(axis="model")
    if channel.quant.is_quantized:
        raise NotImplementedError(
            "ag_gemm_shard: quantized activation wires (QuantSpec.wire_dtype="
            f"{channel.quant.wire_dtype!r}) are not supported by the fused "
            "Pallas kernel; use backend='xla' (weight-only quantization via "
            "PackedWeight IS supported here)")
    axis = channel.axis
    m_loc, k = x.shape
    packed = isinstance(w, PackedWeight)
    _, n_loc = w.shape
    comp_tile = tuple(channel.comp.tile)
    bn = bn or comp_tile[1]
    bn = largest_divisor(n_loc, bn)
    n_tiles = n_loc // bn

    nch = effective_channels(m_loc, channel.num_channels, kind="ag_matmul")
    plan = build_plan("ag_matmul", channel, world_size, nch)
    m_sub = m_loc // nch
    if comp_tile == DEFAULT_TILE:
        # sentinel: backend-chosen blocking — whole-chunk rows/contraction
        tm, tk = m_sub, k
    else:
        tm = largest_divisor(m_sub, comp_tile[0])
        tk = largest_divisor(k, comp_tile[2])
    accum = jnp.dtype(plan.accum_dtype)
    src_tbl = jnp.asarray(plan.src_tables(), jnp.int32).reshape(-1)
    dst_tbl = jnp.asarray(plan.flow_dst_tables(), jnp.int32).reshape(-1)

    kern = functools.partial(
        _ag_gemm_kernel,
        axis=axis,
        world=world_size,
        nch=nch,
        n_tiles=n_tiles,
        m_loc=m_loc,
        m_sub=m_sub,
        tm=tm,
        bn=bn,
        tk=tk,
        accum=accum,
        packed=packed,
    )
    in_specs = [
        pl.BlockSpec(memory_space=backend.ANY),
        pl.BlockSpec((k, bn), lambda s, c, j: (0, j)),
    ]
    operands = [x]
    if packed:
        operands.append(w.q)
        # per-output-column scale/zero ride as (1, bn) blocks next to the
        # weight block they dequantize (zero points default to 0 — symmetric)
        zero = w.zero if w.zero is not None else jnp.zeros_like(w.scale)
        operands.extend([w.scale.reshape(1, n_loc), zero.reshape(1, n_loc)])
        in_specs.extend([
            pl.BlockSpec((1, bn), lambda s, c, j: (0, j)),
            pl.BlockSpec((1, bn), lambda s, c, j: (0, j)),
        ])
    else:
        operands.append(w)
    in_specs.extend([
        pl.BlockSpec(memory_space=backend.ANY),  # src schedule table
        pl.BlockSpec(memory_space=backend.ANY),  # dst schedule table
    ])
    operands.extend([src_tbl, dst_tbl])
    return backend.pallas_call(
        kern,
        grid=(world_size, nch, n_tiles),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(memory_space=backend.ANY),
        out_shape=jax.ShapeDtypeStruct((world_size * m_loc, n_loc), x.dtype),
        scratch_shapes=[
            backend.vmem_scratch((world_size * nch, m_sub, k), x.dtype),  # gather
            backend.vmem_scratch((m_sub, k), x.dtype),  # current tile
            backend.vmem_scratch((m_sub, bn), accum),  # accumulator
            backend.vmem_scratch((m_sub, bn), x.dtype),  # cast staging tile
            backend.dma_semaphore(),  # local copies
            backend.dma_semaphore(),  # sends
            backend.dma_semaphore((world_size * nch,)),  # per-(step, ch) recv
            backend.dma_semaphore(),  # out stores
        ],
        dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        interpret=interpret,
    )(*operands)
