"""Fused AllGather + GEMM Pallas kernel (paper §5, AG+GEMM; push mode, ring).

One kernel per device (launched under shard_map over the TP axis) both
*communicates* and *computes*:

  * ring step ``s``: the chunk that originated at rank ``(my - s) % R`` is
    forwarded to the right neighbour with ``tile_push_data``
    (``pltpu.make_async_remote_copy`` on the ICI DMA engine) while the MXU
    computes GEMM tiles on the chunk that arrived at step ``s`` — communication
    and computation tiles are *decoupled*: the comm tile is the whole
    [m_loc, K] shard, the compute tile is (m_loc, bn) (CompSpec), iterated in
    the inner grid dimension;
  * ``consumer_tile_wait`` is the ``wait_recv`` on the per-step DMA semaphore —
    acquire semantics; loads of the gathered chunk are emitted only after it
    (paper §4.2's strict-dependency rule, enforced by construction).

Slot-per-origin gather buffer (``buf[src]``) makes the schedule race-free
without credit counters: each slot is written exactly once per ring pass.

Validated on CPU via the backend's emulated target (the interpreter simulates
the inter-device DMAs + semaphores); on real TPU the same code lowers to
Mosaic with ICI RDMA.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import backend
from repro.backend import pl
from repro.core import primitives
from repro.core.channels import BlockChannel

__all__ = ["ag_gemm_shard"]


def _ag_gemm_kernel(x_ref, w_ref, o_ref, buf, x_vmem, acc, out_tile, copy_sem,
                    send_sem, recv_sems, out_sem, *, axis: str, world: int,
                    n_tiles: int, m_loc: int, bn: int):
    s = pl.program_id(0)
    j = pl.program_id(1)
    my = lax.axis_index(axis)
    right = lax.rem(my + 1, world)
    src = lax.rem((my - s) + world, world)

    @pl.when(jnp.logical_and(s == 0, j == 0))
    def _local_seed():
        # stage own shard into the gather buffer (producer tile 'my')
        c = backend.make_async_copy(x_ref, buf.at[my], copy_sem)
        c.start()
        c.wait()

    def _fwd_rdma(step, src_slot):
        # forward from the VMEM staging copy (x_vmem) to the right neighbour's
        # gather slot — src and dst must not alias for the DMA engine
        return primitives.make_tile_push(
            src_ref=x_vmem,
            dst_ref=buf.at[src_slot],
            send_sem=send_sem,
            recv_sem=recv_sems.at[step],
            rank=right,
        )

    @pl.when(j == 0)
    def _comm():
        # consumer_tile_wait + bring chunk to VMEM for the MXU
        c = backend.make_async_copy(buf.at[src], x_vmem, copy_sem)
        c.start()
        c.wait()

        # tile_push_data: forward the current chunk around the ring (overlaps
        # with this step's GEMM tiles below)
        @pl.when(s < world - 1)
        def _():
            _fwd_rdma(s, src).start()

    # compute tile j of the consumer GEMM (CompSpec tile)
    acc[...] = jnp.dot(x_vmem[...], w_ref[...], preferred_element_type=jnp.float32)
    out_tile[...] = acc[...].astype(out_tile.dtype)
    oc = backend.make_async_copy(
        out_tile, o_ref.at[pl.ds(src * m_loc, m_loc), pl.ds(j * bn, bn)], out_sem
    )
    oc.start()
    oc.wait()

    @pl.when(jnp.logical_and(j == n_tiles - 1, s < world - 1))
    def _finish_comm():
        # wait_send: our buffer slot is drained; wait_recv: next chunk arrived
        _fwd_rdma(s, src).wait()


def ag_gemm_shard(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    channel: Optional[BlockChannel] = None,
    world_size: int,
    bn: int = 128,
    interpret: bool = True,
):
    """Per-shard fused AG+GEMM. x: [m_loc, K], w: [K, n_loc] -> [R*m_loc, n_loc].

    Call inside shard_map over ``channel.axis``.  ``interpret=True`` runs the
    interpreter (CPU validation); False lowers to Mosaic on TPU hosts — on a
    CPU-only host the emulated backend target interprets regardless, since
    there is no Mosaic toolchain to compile with.
    """
    channel = channel or BlockChannel(axis="model")
    axis = channel.axis
    m_loc, k = x.shape
    _, n_loc = w.shape
    bn = min(bn, n_loc)
    assert n_loc % bn == 0
    n_tiles = n_loc // bn

    kern = functools.partial(
        _ag_gemm_kernel, axis=axis, world=world_size, n_tiles=n_tiles,
        m_loc=m_loc, bn=bn,
    )
    return backend.pallas_call(
        kern,
        grid=(world_size, n_tiles),
        in_specs=[
            pl.BlockSpec(memory_space=backend.ANY),
            pl.BlockSpec((k, bn), lambda s, j: (0, j)),
        ],
        out_specs=pl.BlockSpec(memory_space=backend.ANY),
        out_shape=jax.ShapeDtypeStruct((world_size * m_loc, n_loc), x.dtype),
        scratch_shapes=[
            backend.vmem_scratch((world_size, m_loc, k), x.dtype),  # gather buffer
            backend.vmem_scratch((m_loc, k), x.dtype),       # current chunk
            backend.vmem_scratch((m_loc, bn), jnp.float32),  # accumulator
            backend.vmem_scratch((m_loc, bn), x.dtype),      # cast staging tile
            backend.dma_semaphore(),                         # local copies
            backend.dma_semaphore(),                         # sends
            backend.dma_semaphore((world_size,)),            # per-step recv
            backend.dma_semaphore(),                         # out stores
        ],
        dimension_semantics=("arbitrary", "arbitrary"),
        interpret=interpret,
    )(x, w)
