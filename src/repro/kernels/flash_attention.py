"""Flash-attention Pallas kernel (online softmax, GQA, causal, sliding window).

The compute half of the paper's Fig. 6 (AG-KV + self-attention): this kernel
consumes KV tiles in any arrival order the communication schedule produces;
tile-order independence comes from the online-softmax rescaling.

Layout: q [BH, Sq, D], k/v [BHkv, Sk, D].  Grid (BH, Sq/bq, Sk/bk), KV
innermost; m/l/acc VMEM scratch persists across the KV dimension.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import backend
from repro.backend import pl
from repro.core.comp_tiles import DEFAULT_TILE, largest_divisor

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _fa_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    bq: int,
    bk: int,
    n_kv: int,
    sq: int,
    sk: int,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # global positions (queries right-aligned against keys, for decode/prefill)
    i = pl.program_id(1)
    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (sk - sq)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block-level skip: entirely-masked KV tiles do no work (tile-order freedom)
    run = True
    if causal:
        run = (j * bk) <= (i * bq + bq - 1 + (sk - sq))
    if window is not None:
        run = jnp.logical_and(run, (i * bq + (sk - sq) - (j * bk + bk - 1)) < window)

    @pl.when(run if isinstance(run, jnp.ndarray) else (run and True))
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        # scores [bq, bk]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        mask = None
        if causal:
            mask = q_pos >= k_pos
        if window is not None:
            wm = (q_pos - k_pos) < window
            mask = wm if mask is None else jnp.logical_and(mask, wm)
        if mask is not None:
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p,
            v_ref[0].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _store():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "bq", "bk", "tile", "interpret"),
)
def flash_attention(
    q,
    k,
    v,
    *,
    causal=False,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    bq=128,
    bk=128,
    tile: Optional[Tuple[int, int, int]] = None,
    interpret=False,
):
    """q: [BH, Sq, D], k/v: [BHkv, Sk, D] -> [BH, Sq, D].

    ``tile``: an optional CompSpec (tm, tn, tk) — the tuner's compute half.
    A non-default tile derives ``block_q``/``block_kv`` from (tm, tk),
    overriding ``bq``/``bk``; the (128, 128, 128) default is the
    backend-chosen sentinel and leaves them untouched.  Blocks clamp to
    divisors of the sequence extents (the shared largest-divisor rule), so
    any tuned tile runs instead of refusing on an awkward shape.
    """
    bh, sq, d = q.shape
    bhkv, sk, _ = k.shape
    rep = bh // bhkv
    scale = float(scale if scale is not None else d**-0.5)
    if tile is not None and tuple(tile) != DEFAULT_TILE:
        bq, bk = int(tile[0]), int(tile[2])
    bq = largest_divisor(sq, min(bq, sq))
    bk = largest_divisor(sk, min(bk, sk))
    n_kv = sk // bk

    kern = functools.partial(
        _fa_kernel,
        scale=scale,
        causal=causal,
        window=window,
        bq=bq,
        bk=bk,
        n_kv=n_kv,
        sq=sq,
        sk=sk,
    )
    return backend.pallas_call(
        kern,
        grid=(bh, sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, rep=rep: (b // rep, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, rep=rep: (b // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            backend.vmem_scratch((bq, 1), jnp.float32),
            backend.vmem_scratch((bq, 1), jnp.float32),
            backend.vmem_scratch((bq, d), jnp.float32),
        ],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(q, k, v)
