"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the mathematical specification the kernels are tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "matmul_ref",
    "flash_attention_ref",
    "grouped_matmul_ref",
    "ag_gemm_ref",
    "gemm_rs_ref",
    "ssd_ref",
]


def matmul_ref(x, w, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    return jnp.dot(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(out_dtype)


def flash_attention_ref(
    q, k, v, *, causal=False, window: Optional[int] = None, scale: Optional[float] = None
):
    """q: [BH, Sq, D], k/v: [BHkv, Sk, D] with BH % BHkv == 0 (GQA)."""
    bh, sq, d = q.shape
    bhkv, sk, _ = k.shape
    rep = bh // bhkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=0)
        v = jnp.repeat(v, rep, axis=0)
    scale = scale if scale is not None else d**-0.5
    s = jnp.einsum("bqd,bkd->bqk", (q * scale).astype(jnp.float32), k.astype(jnp.float32))
    qp = jnp.arange(sq)
    kp = jnp.arange(sk)
    mask = None
    if causal:
        # align ends: query i attends keys <= i + (sk - sq)
        mask = (qp[:, None] + (sk - sq)) >= kp[None, :]
    if window is not None:
        wmask = (qp[:, None] + (sk - sq) - kp[None, :]) < window
        mask = wmask if mask is None else mask & wmask
    if mask is not None:
        s = jnp.where(mask[None], s, -jnp.inf)
    m = s.max(-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m)
    o = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return (o / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)).astype(q.dtype)


def grouped_matmul_ref(x, w, tile_expert, tile_m: int, out_dtype=None):
    """x: [M, K] expert-sorted rows; w: [E, K, N]; tile_expert: [M // tile_m].

    Row i belongs to expert tile_expert[i // tile_m] (tile-aligned groups —
    the dynamic shape mapping f_R of the paper).
    """
    out_dtype = out_dtype or x.dtype
    row_expert = jnp.repeat(tile_expert, tile_m)
    wx = w[row_expert]  # [M, K, N]
    out = jnp.einsum("mk,mkn->mn", x.astype(jnp.float32), wx.astype(jnp.float32))
    return out.astype(out_dtype)


def ag_gemm_ref(x_shards, w_shards):
    """Global oracle: x_shards [R, m_loc, K], w_shards [R, K, n_loc] ->
    per-rank outputs [R, R*m_loc, n_loc] (every rank holds AG(x) @ its w)."""
    xg = x_shards.reshape(-1, x_shards.shape[-1]).astype(jnp.float32)
    out = jnp.stack([xg @ w.astype(jnp.float32) for w in w_shards])
    return out.astype(x_shards.dtype)


def gemm_rs_ref(x_shards, w_shards):
    """Global oracle for GEMM + reduce-scatter.

    x_shards: [R, M, k_loc] (k-sharded input), w_shards: [R, k_loc, N].
    Returns [R, M // R, N]: rank r's segment of sum_r(x_r @ w_r).
    """
    r, m, _ = x_shards.shape
    full = sum(x_shards[i].astype(jnp.float32) @ w_shards[i].astype(jnp.float32) for i in range(r))
    return full.reshape(r, m // r, -1).astype(x_shards.dtype)


def ssd_ref(x, dt, a_log, b, c, *, chunk: int = 64, d_init=None):
    """Mamba-2 SSD (state-space duality) reference — sequential scan.

    x:  [B, L, H, P]   inputs per head
    dt: [B, L, H]      softplus-activated step sizes (already positive)
    a_log: [H]         log of -A (A = -exp(a_log) < 0)
    b:  [B, L, G, N]   input projections (G groups, N state dim)
    c:  [B, L, G, N]   output projections
    Returns y: [B, L, H, P].  h_t = h_{t-1} * exp(dt*A) + dt * B_t x_t ;
    y_t = C_t . h_t  (einsum over N).
    """
    bsz, length, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))  # [H]
    bx = jnp.repeat(b, rep, axis=2)  # [B, L, H, N]
    cx = jnp.repeat(c, rep, axis=2)

    def step(hprev, inp):
        xt, dtt, bt, ct = inp  # [B,H,P], [B,H], [B,H,N], [B,H,N]
        decay = jnp.exp(dtt * a[None, :])  # [B,H]
        hnew = hprev * decay[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", bt * dtt[..., None], xt
        )
        yt = jnp.einsum("bhn,bhnp->bhp", ct, hnew)
        return hnew, yt

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32) if d_init is None else d_init
    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(bx.astype(jnp.float32), 1, 0),
        jnp.moveaxis(cx.astype(jnp.float32), 1, 0),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
