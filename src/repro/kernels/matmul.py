"""MXU-tiled matmul Pallas kernel (fp32 accumulation in VMEM scratch).

The consumer-side compute tile of TileLink programs: block shapes are the
CompSpec tile of the decoupled design space.  Grid is (M/bm, N/bn, K/bk) with
the K dimension innermost so the VMEM accumulator lives across K steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import backend
from repro.backend import pl

__all__ = ["matmul", "DEFAULT_TILE"]

DEFAULT_TILE = (128, 128, 128)  # (bm, bn, bk) — MXU-aligned


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "out_dtype", "interpret"))
def matmul(x, w, *, tile=DEFAULT_TILE, out_dtype=None, interpret=False):
    """x: [M, K] @ w: [K, N] -> [M, N]; M/N/K must divide by the tile."""
    out_dtype = out_dtype or x.dtype
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = (min(tile[0], m), min(tile[1], n), min(tile[2], k))
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (x.shape, w.shape, tile)
    n_k = k // bk

    return backend.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[backend.vmem_scratch((bm, bn), jnp.float32)],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(x, w)
