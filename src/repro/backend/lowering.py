"""Pallas lowering surface: the only path from kernels to ``pltpu``.

Kernels and tile primitives call these functions instead of touching
``jax.experimental.pallas.tpu`` — the rename churn (``CompilerParams`` /
``TPUCompilerParams``, ``InterpretParams``), the interpret-mode capability
differences, and the remote device-id representation are all absorbed here.

Remote device ids: every fused kernel addresses peers by *logical rank along
the single manual mesh axis* it runs under.  On real TPUs that lowers to a
MESH-coordinate device id (a 1-tuple); under the old-JAX generic interpreter
the MESH tuple path is broken, but a scalar LOGICAL id is equivalent for one
named axis and is what its discharge rule supports — ``_remote_device_id``
picks per target/version so kernels never spell the representation.
"""
from __future__ import annotations

import inspect

import jax.numpy as jnp

from repro.backend import features as _f
from repro.backend.target import is_emulated as _is_emulated
from repro.backend.target import resolve_interpret as _resolve_interpret

pl = _f.pl
pltpu = _f.pltpu

__all__ = [
    "pl",
    "ANY",
    "compiler_params",
    "pallas_call",
    "prefetch_grid_spec",
    "vmem_scratch",
    "smem_scratch",
    "dma_semaphore",
    "regular_semaphore",
    "make_async_copy",
    "make_async_remote_copy",
    "semaphore_signal",
    "semaphore_wait",
]

ANY = _f.MEMORY_SPACE_ANY


# ---- compile parameters ------------------------------------------------------

def compiler_params(*, dimension_semantics=None, **kw):
    """Build the TPU compiler-params object under its current name.

    Unknown ``**kw`` keys (fields a given JAX doesn't have) are dropped
    rather than erroring: they are tuning hints.  ``dimension_semantics`` is
    NOT a hint — the fused ring kernels rely on "arbitrary" to force
    sequential grid execution (each step waits on the previous step's DMA),
    so if a JAX ever renames that field away we refuse loudly instead of
    letting Mosaic parallelize the grid into deadlock/corruption.
    """
    accepted = {k: v for k, v in kw.items() if k in _f.COMPILER_PARAMS_FIELDS}
    if dimension_semantics is not None:
        if "dimension_semantics" not in _f.COMPILER_PARAMS_FIELDS:
            raise NotImplementedError(
                f"{_f.COMPILER_PARAMS_CLS.__name__} on this JAX has no "
                "dimension_semantics field, which the kernels need for "
                "correct grid ordering — add the new spelling to "
                "repro.backend.lowering.compiler_params"
            )
        accepted["dimension_semantics"] = tuple(dimension_semantics)
    return _f.COMPILER_PARAMS_CLS(**accepted)


def pallas_call(kernel, *, dimension_semantics=None, interpret=None,
                compiler_params_kw=None, **kw):
    """``pl.pallas_call`` with version-normalized params and interpret mode.

    ``interpret``: True/False, or None for "whatever the target needs"
    (the emulated target always interprets).
    """
    params = compiler_params(
        dimension_semantics=dimension_semantics, **(compiler_params_kw or {})
    )
    return pl.pallas_call(
        kernel,
        compiler_params=params,
        interpret=_resolve_interpret(interpret),
        **kw,
    )


def prefetch_grid_spec(*, num_scalar_prefetch, grid, in_specs, out_specs,
                       scratch_shapes=()):
    """Scalar-prefetch grid spec (dynamic-mapping kernels)."""
    kw = dict(
        num_scalar_prefetch=num_scalar_prefetch,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch_shapes,
    )
    if hasattr(pltpu, "PrefetchScalarGridSpec"):
        return pltpu.PrefetchScalarGridSpec(**kw)
    if "num_scalar_prefetch" in inspect.signature(pl.GridSpec).parameters:
        return pl.GridSpec(**kw)
    raise NotImplementedError(
        "no scalar-prefetch grid spec found on this JAX (neither "
        "pltpu.PrefetchScalarGridSpec nor a num_scalar_prefetch parameter on "
        "pl.GridSpec) — add the new spelling to repro.backend.lowering"
    )


# ---- scratch / semaphore allocation ------------------------------------------

def vmem_scratch(shape, dtype=jnp.float32):
    """A VMEM scratch allocation for ``scratch_shapes``."""
    return pltpu.VMEM(tuple(shape), dtype)


def smem_scratch(shape, dtype=jnp.int32):
    return pltpu.SMEM(tuple(shape), dtype)


def dma_semaphore(shape=None):
    """A DMA semaphore (optionally an array of them) for ``scratch_shapes``."""
    if shape is None:
        return pltpu.SemaphoreType.DMA
    return pltpu.SemaphoreType.DMA(tuple(shape))


def regular_semaphore(shape=None):
    if shape is None:
        return pltpu.SemaphoreType.REGULAR
    return pltpu.SemaphoreType.REGULAR(tuple(shape))


# ---- DMA + semaphore primitives ----------------------------------------------

def _remote_device_id(rank):
    """(device_id, device_id_type) for a logical rank on the manual axis.

    The LOGICAL spelling is only equivalent to the axis rank when the kernel
    runs under exactly one named (manual) axis — which is how every fused
    kernel here is launched.  With more named axes the old-JAX discharge rule
    itself refuses (NotImplementedError at trace time), so the mismatch is
    loud, never silent peer corruption.

    On JAX without the TPU interpreter class, LOGICAL is used regardless of
    target: any interpreted run there goes through the generic interpreter
    (whose MESH-tuple path is broken), and for a single named axis Mosaic
    accepts LOGICAL too, so it is the one spelling valid on every path.
    Because logical id == axis rank only holds for ONE named axis, that
    branch verifies the trace-time axis env and refuses otherwise — Mosaic
    would compile the multi-axis case and silently DMA to the wrong peer.
    """
    if not _f.HAS_TPU_INTERPRET_PARAMS:
        _check_single_named_axis()
        return rank, pltpu.DeviceIdType.LOGICAL
    return (rank,), pltpu.DeviceIdType.MESH


def _check_single_named_axis():
    # 0.4.x-only branch, so the 0.4.x-internal axis env is a safe probe.  If
    # the probe API itself is missing, fail open only for interpreted runs
    # (the generic interpreter's discharge rule refuses multi-axis LOGICAL on
    # its own); for a Mosaic compile there is no second line of defense
    # against wrong-peer DMAs, so refuse instead.
    try:
        from jax._src import core as _jax_core

        named = [n for n in _jax_core.get_axis_env().axis_sizes if n is not None]
    except (ImportError, AttributeError):
        if _is_emulated():
            return
        raise NotImplementedError(
            "cannot verify the manual-axis count on this JAX (private axis-env "
            "probe missing) and Mosaic would silently accept a wrong logical "
            "device id — add the new probe spelling to repro.backend.lowering"
        ) from None
    if len(named) > 1:
        raise NotImplementedError(
            f"remote DMA by logical rank under {len(named)} named axes "
            f"{tuple(named)}: on this JAX the logical device id equals the "
            "axis rank only for a single manual axis — launch the fused "
            "kernel under shard_map over just the channel axis"
        )


def make_async_copy(src_ref, dst_ref, sem):
    """Local async copy handle (start()/wait())."""
    return pltpu.make_async_copy(src_ref, dst_ref, sem)


def make_async_remote_copy(src_ref, dst_ref, send_sem, recv_sem, rank):
    """Remote async copy handle addressed by logical rank on the manual axis."""
    device_id, device_id_type = _remote_device_id(rank)
    return pltpu.make_async_remote_copy(
        src_ref=src_ref,
        dst_ref=dst_ref,
        send_sem=send_sem,
        recv_sem=recv_sem,
        device_id=device_id,
        device_id_type=device_id_type,
    )


def semaphore_signal(sem, inc: int = 1, *, rank=None):
    """Signal a semaphore, locally or on peer ``rank`` (release semantics)."""
    if rank is None:
        pltpu.semaphore_signal(sem, inc)
        return
    if _is_emulated() and not _f.HAS_REMOTE_SIGNAL_IN_INTERPRET:
        raise NotImplementedError(
            "remote semaphore_signal is not simulated by the generic pallas "
            f"interpreter on jax {'.'.join(map(str, _f.JAX_VERSION))}; "
            "structure the kernel around make_async_remote_copy recv "
            "semaphores (as ag_gemm/gemm_rs do), or run on a JAX with "
            "pltpu.InterpretParams for full emulation"
        )
    device_id, device_id_type = _remote_device_id(rank)
    pltpu.semaphore_signal(
        sem, inc, device_id=device_id, device_id_type=device_id_type
    )


def semaphore_wait(sem, count: int = 1):
    """Block until the semaphore holds ``count`` (acquire semantics)."""
    pltpu.semaphore_wait(sem, count)
