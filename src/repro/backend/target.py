"""Backend target selection: real TPU lowering vs. emulated (interpret) CPU.

Targets:

  "tpu"       lower Pallas kernels to Mosaic; remote DMAs ride the ICI.
  "emulated"  force ``interpret`` execution so every kernel — including the
              fused communication kernels — runs on any host with no TPU,
              using XLA's forced-host-device pool for the mesh axes.

Resolution order: the ``REPRO_BACKEND`` environment variable ("tpu",
"emulated", or "auto"), else "tpu" iff ``jax.default_backend() == "tpu"``.
"""
from __future__ import annotations

import os

import jax

from repro.backend import features as _f

__all__ = ["target", "is_emulated", "resolve_interpret", "default_interpret"]

_ENV = "REPRO_BACKEND"
_VALID = ("auto", "tpu", "emulated")


def target() -> str:
    """The active lowering target: "tpu" or "emulated"."""
    choice = os.environ.get(_ENV, "auto").strip().lower()
    if choice not in _VALID:
        raise ValueError(
            f"{_ENV}={choice!r}: expected one of {_VALID}"
        )
    if choice != "auto":
        return choice
    return "tpu" if jax.default_backend() == "tpu" else "emulated"


def is_emulated() -> bool:
    return target() == "emulated"


def resolve_interpret(interpret=None):
    """Normalize an ``interpret`` request into what pallas_call accepts here.

    ``None`` means "whatever the target needs" (emulated -> interpret).  On
    JAX with the dedicated TPU interpreter, interpreting returns an
    ``InterpretParams`` instance (it simulates inter-device DMAs); on older
    JAX it returns plain ``True`` (the generic interpreter's discharge rules
    cover local and single-axis remote DMAs).
    """
    if interpret is None:
        interpret = is_emulated()
    if isinstance(interpret, bool):
        if not interpret:
            # The emulated target has no Mosaic compiler to fall back to:
            # compiling is not an option, so interpret anyway.
            if is_emulated():
                interpret = True
            else:
                return False
        if _f.INTERPRET_PARAMS_CLS is not None:
            return _f.INTERPRET_PARAMS_CLS()
        return True
    return interpret  # already an InterpretParams-like object


def default_interpret() -> bool:
    """Plain-bool view of the target, for jit-static ``interpret`` args."""
    return is_emulated()
