"""Version-stable mesh construction and shard_map entry.

Drift handled here:

  * ``jax.make_mesh`` gained ``axis_types`` (``jax.sharding.AxisType``) in
    0.6; on 0.4.x referencing ``AxisType`` raises AttributeError — probe
    with ``hasattr`` first instead of relying on exception type.
  * ``jax.shard_map`` became public API in 0.7 with ``check_vma`` and
    ``axis_names`` (partial-auto); before that it lives in
    ``jax.experimental.shard_map`` with ``check_rep`` and the *complement*
    parameter ``auto`` (the set of axes that stay automatic).
"""
from __future__ import annotations

import jax
from jax import lax

from repro.backend import features as _f

__all__ = ["make_mesh", "shard_map", "axis_size"]


def axis_size(name):
    """Size of a named mesh axis from inside a manual region.

    ``lax.axis_size`` appeared after 0.4.x; ``psum(1, axis)`` is the
    version-stable spelling (constant-folded, works inside Pallas kernels too).
    """
    if hasattr(lax, "axis_size"):
        return lax.axis_size(name)
    return lax.psum(1, name)


def make_mesh(shape, axis_names):
    """Mesh constructor pinned to Auto axis types (we use in_shardings/constraints)."""
    if _f.HAS_AXIS_TYPE:
        try:
            return jax.make_mesh(
                shape,
                axis_names,
                axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
            )
        except TypeError:
            pass  # make_mesh predates axis_types
    if _f.HAS_JAX_MAKE_MESH:
        return jax.make_mesh(shape, axis_names)
    from jax.experimental import mesh_utils

    return jax.sharding.Mesh(mesh_utils.create_device_mesh(shape), axis_names)


def shard_map(f, mesh, in_specs, out_specs, check_rep: bool = False,
              axis_names=None):
    """Version-stable shard_map wrapper (check_rep/check_vma naming drift).

    ``axis_names``: when given, a partial-auto shard_map — only those mesh axes
    are manual; the rest stay under the automatic partitioner.
    """
    if _f.HAS_JAX_SHARD_MAP:
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = frozenset(axis_names)
        try:
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=check_rep, **kw,
            )
        except TypeError:
            pass  # transitional releases: fall through to the experimental API
    from jax.experimental.shard_map import shard_map as _sm

    kw = {}
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto and _f.HAS_AXIS_TYPE:
            # pre-0.7 spelling of partial-auto: pass the *auto* complement
            kw["auto"] = auto
        # On 0.4.x partial-auto is broken in XLA:CPU SPMD (axis_index lowers
        # to an unsupported PartitionId instruction), so fall through to a
        # full-manual region instead: specs mention only the manual axes, so
        # the body sees the same shapes, with the other axes replicated —
        # identical results, redundant compute on the unmentioned axes, and
        # the shard_map transpose still psums cotangents over them (DP grads).
        # Caveat: fused remote-DMA kernels cannot run inside this fallback on
        # a multi-axis mesh — all axes become named, and the logical-rank
        # device-id check in lowering.py refuses >1 named axis (loudly, at
        # trace time). The XLA overlap path (what smap callers use) is fine.
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_rep, **kw)
