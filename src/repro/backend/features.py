"""Feature detection for the installed JAX (probed once, at import).

Every probe is a ``hasattr``/signature check, never a version comparison,
except for ``JAX_VERSION`` itself which is exposed for diagnostics and CI
matrices.  The rest of the package keys off these booleans so a new JAX
release that restores or renames an API is picked up without code changes.

This module is the ONLY place in the repository that imports
``jax.experimental.pallas.tpu`` (enforced by tests/test_backend.py); the
``pl``/``pltpu`` handles re-exported here are consumed by the sibling
modules and must not leak outside ``repro.backend``.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.experimental import pallas as pl  # noqa: F401  (re-exported)
from jax.experimental.pallas import tpu as pltpu  # noqa: F401  (re-exported)

__all__ = [
    "JAX_VERSION",
    "HAS_AXIS_TYPE",
    "HAS_JAX_SHARD_MAP",
    "HAS_JAX_MAKE_MESH",
    "COMPILER_PARAMS_CLS",
    "COMPILER_PARAMS_FIELDS",
    "INTERPRET_PARAMS_CLS",
    "HAS_TPU_INTERPRET_PARAMS",
    "HAS_REMOTE_SIGNAL_IN_INTERPRET",
    "MEMORY_SPACE_ANY",
    "describe",
    "pl",
    "pltpu",
]


def _version_tuple(v: str) -> tuple:
    parts = []
    for p in v.split("."):
        digits = "".join(ch for ch in p if ch.isdigit())
        if not digits:
            break
        parts.append(int(digits))
    return tuple(parts)


JAX_VERSION = _version_tuple(jax.__version__)

# ---- mesh / shard_map surface ------------------------------------------------
HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")  # >= 0.6
HAS_JAX_SHARD_MAP = hasattr(jax, "shard_map")  # >= 0.7 public API
HAS_JAX_MAKE_MESH = hasattr(jax, "make_mesh")  # >= 0.4.35


def _probe(names, *modules):
    """First attribute found under any of ``names`` on any module, else a
    loud, actionable error (bare AttributeError at import would take down
    even the non-Pallas paths with no hint where drift belongs)."""
    for mod in modules:
        for name in names:
            found = getattr(mod, name, None)
            if found is not None:
                return found
    raise ImportError(
        f"none of {tuple(names)} found on this JAX ({jax.__version__}) — "
        "add the new spelling to repro.backend.features"
    )


# ---- pallas TPU compiler params (CompilerParams <- TPUCompilerParams rename) --
COMPILER_PARAMS_CLS = _probe(("CompilerParams", "TPUCompilerParams"), pltpu)
COMPILER_PARAMS_FIELDS = frozenset(
    f.name for f in dataclasses.fields(COMPILER_PARAMS_CLS)
)

# ---- TPU interpret mode ------------------------------------------------------
# Newer JAX ships a dedicated TPU interpreter (pltpu.InterpretParams, earlier
# pltpu.TPUInterpretParams) that simulates inter-device DMAs and semaphores.
# Older JAX (0.4.x) instead discharges DMA/semaphore state in the generic
# pallas interpreter when ``interpret=True`` — remote copies work there with a
# scalar LOGICAL device id, but remote semaphore_signal does not.
INTERPRET_PARAMS_CLS = getattr(pltpu, "InterpretParams", None) or getattr(
    pltpu, "TPUInterpretParams", None
)
HAS_TPU_INTERPRET_PARAMS = INTERPRET_PARAMS_CLS is not None
HAS_REMOTE_SIGNAL_IN_INTERPRET = HAS_TPU_INTERPRET_PARAMS

MEMORY_SPACE_ANY = _probe(("ANY",), pl, pltpu)


def describe() -> dict:
    """Snapshot of every probe, for logs / CI / bug reports."""
    return {
        "jax_version": jax.__version__,
        "default_backend": jax.default_backend(),
        "has_axis_type": HAS_AXIS_TYPE,
        "has_jax_shard_map": HAS_JAX_SHARD_MAP,
        "has_jax_make_mesh": HAS_JAX_MAKE_MESH,
        "compiler_params_cls": COMPILER_PARAMS_CLS.__name__,
        "interpret_params_cls": (
            INTERPRET_PARAMS_CLS.__name__ if INTERPRET_PARAMS_CLS else None
        ),
        "has_remote_signal_in_interpret": HAS_REMOTE_SIGNAL_IN_INTERPRET,
    }
