"""Compute-hardware probes for tile-size tuning (MXU shape, VMEM budget).

The CompSpec half of the design space — the (tm, tn, tk) consumer-kernel
tile — is only searchable if the tuner knows what the compute unit actually
looks like: how wide the systolic array is (tiles below it waste MXU
cycles), what the sublane/lane packing multiples are per dtype (misaligned
tiles pad), and how much VMEM a tile's working set may occupy (oversized
tiles spill or refuse to compile).  This module is the single place those
constants live, probed per device kind with environment overrides, so
``repro.tune.candidates`` prunes its tile lattice against the same numbers
the kernels will face.

Probing policy matches the rest of ``repro.backend``: inspect the live
device (``device_kind``), fall back to conservative defaults on unknown or
emulated hosts, never hard-code a version check.  ``REPRO_VMEM_BYTES``
overrides the VMEM budget (tests use it to exercise the pruning path).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

__all__ = [
    "MXU_DIM",
    "LANE_MULTIPLE",
    "device_kind",
    "mxu_dim",
    "vmem_budget_bytes",
    "sublane_multiple",
    "lane_multiple",
]

_ENV_VMEM = "REPRO_VMEM_BYTES"

# the MXU systolic array is 128x128 on every shipped TPU generation; the
# vector lane width (last-dim packing multiple) is likewise 128
MXU_DIM = 128
LANE_MULTIPLE = 128

# VMEM per core by device kind (bytes).  ~16 MiB on v4/v5 parts, 32 MiB on
# v6e; unknown kinds (CPU hosts running the emulated target) get the
# conservative 16 MiB so tiles tuned on an emulated host stay valid on TPU.
_VMEM_BY_KIND = {
    "TPU v4": 16 * 2**20,
    "TPU v5 lite": 16 * 2**20,
    "TPU v5e": 16 * 2**20,
    "TPU v5p": 16 * 2**20,
    "TPU v6e": 32 * 2**20,
    "TPU v6 lite": 32 * 2**20,
}
_DEFAULT_VMEM = 16 * 2**20


def device_kind() -> str:
    """Kind string of the first visible device ("cpu" on emulated hosts)."""
    dev = jax.devices()[0]
    return str(getattr(dev, "device_kind", dev.platform))


def mxu_dim() -> int:
    """Edge length of the MXU systolic array (tiles below it underutilize)."""
    return MXU_DIM


def vmem_budget_bytes() -> int:
    """VMEM available to one core's tile working set (env-overridable)."""
    env = os.environ.get(_ENV_VMEM)
    if env:
        return max(1, int(env))
    return _VMEM_BY_KIND.get(device_kind(), _DEFAULT_VMEM)


def sublane_multiple(dtype) -> int:
    """Second-to-last-dim packing multiple for ``dtype`` (8 sublanes x 32b).

    f32 packs 8 rows per tile register, bf16/f16 16, int8/fp8 32 — the
    standard (8 * 4 / itemsize) rule.
    """
    itemsize = jnp.dtype(dtype).itemsize
    return max(8, (8 * 4) // max(1, itemsize))


def lane_multiple() -> int:
    """Last-dim packing multiple (always the 128-wide vector lane)."""
    return LANE_MULTIPLE
