"""Version-adaptive backend: tile-centric primitives -> the installed JAX.

TileLink's design keeps primitives *tile-centric* and pushes every
platform/toolchain quirk into a backend that lowers them to whatever the
target actually supports.  This package is that backend for the JAX/Pallas
port: the single point where kernels, tile primitives, and the mesh layer
touch version-sensitive JAX API.  Nothing outside ``repro.backend`` may
import ``jax.experimental.pallas.tpu`` (enforced by tests/test_backend.py).

Supported-JAX policy
--------------------
Feature-detected at import (``hasattr`` probes, see ``features.py``), not
version-gated.  Exercised in CI against:

  * jax 0.4.3x  — ``pltpu.TPUCompilerParams``, experimental ``shard_map``
    (``check_rep``/``auto``), no ``AxisType``, no TPU interpreter class
    (plain ``interpret=True`` + discharge rules; remote DMAs need scalar
    LOGICAL device ids, remote semaphore_signal unsupported);
  * jax >= 0.6/0.7 — ``pltpu.CompilerParams``, public ``jax.shard_map``
    (``check_vma``/``axis_names``), ``AxisType`` mesh types,
    ``pltpu.InterpretParams`` TPU interpreter.

Anything in between resolves by probe.  New drift belongs HERE, never in
kernels.

Targets
-------
``target()`` returns "tpu" (Mosaic lowering, ICI remote DMAs) or "emulated"
(forced ``interpret`` execution so the full suite and benchmarks run on any
CPU-only host).  Override with ``REPRO_BACKEND=tpu|emulated|auto``.

Surface
-------
  mesh / manual regions:   make_mesh, shard_map
  kernel launch:           pallas_call, compiler_params, prefetch_grid_spec,
                           pl (stable pallas frontend handle), ANY
  allocation:              vmem_scratch, smem_scratch, dma_semaphore,
                           regular_semaphore
  tile data movement:      make_async_copy, make_async_remote_copy (by
                           logical rank), semaphore_signal, semaphore_wait
  target control:          target, is_emulated, resolve_interpret,
                           default_interpret, describe
  compute-hardware probes: mxu_dim, vmem_budget_bytes, sublane_multiple,
                           lane_multiple (tile-lattice pruning, repro.tune)
"""
from repro.backend.features import describe
from repro.backend.hw import (
    mxu_dim,
    vmem_budget_bytes,
    sublane_multiple,
    lane_multiple,
)
from repro.backend.target import (
    target,
    is_emulated,
    resolve_interpret,
    default_interpret,
)
from repro.backend.mesh import make_mesh, shard_map, axis_size
from repro.backend.lowering import (
    pl,
    ANY,
    compiler_params,
    pallas_call,
    prefetch_grid_spec,
    vmem_scratch,
    smem_scratch,
    dma_semaphore,
    regular_semaphore,
    make_async_copy,
    make_async_remote_copy,
    semaphore_signal,
    semaphore_wait,
)

__all__ = [
    "describe",
    "mxu_dim",
    "vmem_budget_bytes",
    "sublane_multiple",
    "lane_multiple",
    "target",
    "is_emulated",
    "resolve_interpret",
    "default_interpret",
    "make_mesh",
    "shard_map",
    "axis_size",
    "pl",
    "ANY",
    "compiler_params",
    "pallas_call",
    "prefetch_grid_spec",
    "vmem_scratch",
    "smem_scratch",
    "dma_semaphore",
    "regular_semaphore",
    "make_async_copy",
    "make_async_remote_copy",
    "semaphore_signal",
    "semaphore_wait",
]
