"""mamba2-2.7b [ssm] — 64L d=2560 attn-free, ssm_state=128, SSD.
[arXiv:2405.21060]  AG-KV overlap inapplicable (no attention) — the paper's
technique applies to in/out projections; see DESIGN.md §Arch-applicability."""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    pattern=("mamba",),
    ssm=SSMConfig(d_state=128, headdim=64, n_groups=1, d_conv=4, expand=2),
    act="silu",
    tie_embeddings=True,
    sub_quadratic=True,
))
