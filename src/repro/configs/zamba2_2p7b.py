"""zamba2-2.7b [hybrid] — 54L d=2560, Mamba2 mixers + shared attention blocks
(one shared-parameter attention+MLP block every 6 layers), ssm_state=64.
[arXiv:2411.15242]"""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    rope_theta=1e4,
    pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "shared_attn"),
    ssm=SSMConfig(d_state=64, headdim=64, n_groups=1, d_conv=4, expand=2),
    act="gelu",
    sub_quadratic=True,
))
