"""Architecture configuration schema + input-shape registry.

One ``ArchConfig`` per assigned architecture lives in ``repro/configs/<id>.py``.
``SHAPES`` is the assignment's per-arch input-shape set (LM-family: shared).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "Shape", "SHAPES", "get_config"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int  # routed experts (pre-padding)
    top_k: int
    d_expert: int  # expert intermediate size
    num_shared: int = 0  # shared experts (DeepSeek-style)
    first_k_dense: int = 0  # leading layers that use a dense MLP
    dense_d_ff: int = 0  # d_ff of those dense layers
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int  # N
    headdim: int = 64  # P
    n_groups: int = 1  # G (B/C groups)
    d_conv: int = 4
    expand: int = 2  # d_inner = expand * d_model
    chunk: int = 64  # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope_theta_local: float = 1e4  # theta for attn_local layers (gemma3)
    local_window: Optional[int] = None  # sliding-window size for local layers
    pattern: Tuple[str, ...] = ("attn",)  # layer-kind pattern, tiled over depth
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder_layers: int = 0  # >0 -> encoder-decoder
    frontend: Optional[str] = None  # "vision" | "audio" stub frontends
    norm_eps: float = 1e-6
    act: str = "silu"
    tie_embeddings: bool = False
    sub_quadratic: bool = False  # eligible for long_500k decode
    # serving defaults
    enc_len: int = 4096  # stub encoder length for enc-dec decode

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    def layer_kind(self, i: int) -> str:
        if self.moe and i < self.moe.first_k_dense:
            return "attn_dense"  # leading dense-MLP layers (DeepSeek)
        return self.pattern[i % len(self.pattern)]

    def param_count(self) -> int:
        """Approximate parameter count (for 6ND model-FLOPs accounting)."""
        d, n_layers = self.d_model, self.n_layers
        hd = self.hd
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        for i in range(n_layers):
            kind = self.layer_kind(i)
            if kind in ("attn", "attn_local", "attn_dense", "shared_attn"):
                total += attn
            if kind == "mamba" and self.ssm is not None:
                di = self.ssm.expand * d
                h = di // self.ssm.headdim
                total += d * (2 * di + h + 2 * self.ssm.n_groups * self.ssm.d_state)
                total += di * d + self.ssm.d_conv * di
            if self.moe is not None and kind != "mamba":
                if kind == "attn_dense":
                    total += 3 * d * self.moe.dense_d_ff
                else:
                    e = self.moe.num_experts + self.moe.num_shared
                    total += e * 3 * d * self.moe.d_expert + d * self.moe.num_experts
            elif kind in ("attn", "attn_local", "shared_attn") and self.d_ff:
                total += 3 * d * self.d_ff
        if self.encoder_layers:
            total += self.encoder_layers * (attn + 3 * d * self.d_ff + attn)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        e_all = self.moe.num_experts + self.moe.num_shared
        e_act = self.moe.top_k + self.moe.num_shared
        n_moe_layers = sum(
            1 for i in range(self.n_layers)
            if self.layer_kind(i) not in ("attn_dense", "mamba")
        )
        inactive = n_moe_layers * (e_all - e_act) * 3 * d * self.moe.d_expert
        return full - inactive


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


_REGISTRY = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        from repro import configs as _c  # populates registry

        del _c
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs():
    get_config.__wrapped__ = None  # ensure registry import side effect
    if not _REGISTRY:
        from repro import configs as _c

        del _c
    return dict(_REGISTRY)
