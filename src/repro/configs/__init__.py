"""Assigned architecture configs (10) + paper benchmark shapes."""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, Shape, SHAPES, get_config

from repro.configs import (  # noqa: F401 — registration side effects
    granite_moe_3b_a800m,
    deepseek_moe_16b,
    paligemma_3b,
    zamba2_2p7b,
    qwen2_72b,
    smollm_360m,
    starcoder2_7b,
    gemma3_27b,
    mamba2_2p7b,
    seamless_m4t_medium,
)
from repro.configs.base import _REGISTRY as REGISTRY

ARCH_NAMES = sorted(REGISTRY)

__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "Shape", "SHAPES",
           "get_config", "REGISTRY", "ARCH_NAMES"]
