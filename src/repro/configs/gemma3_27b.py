"""gemma3-27b [dense] — 62L d=5376 32H (GQA kv=16) d_ff=21504 vocab 262144;
5:1 local(sliding-1024):global attention, 128k context. [hf:google/gemma-3]
sub_quadratic: local layers keep O(window) KV -> eligible for long_500k."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    head_dim=128,
    rope_theta=1e6,
    rope_theta_local=1e4,
    local_window=1024,
    pattern=("attn_local",) * 5 + ("attn",),
    act="gelu",
    tie_embeddings=True,
    sub_quadratic=True,
))
