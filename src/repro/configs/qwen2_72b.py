"""qwen2-72b [dense] — 80L d=8192 64H (GQA kv=8) d_ff=29568 vocab 152064;
GQA with QKV bias. [arXiv:2407.10671]  (Paper Table 4's MLP-6 shape.)"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    pattern=("attn",),
    act="silu",
))
