"""granite-moe-3b-a800m [moe] — 32L d=1536 24H (GQA kv=8) expert d_ff=512,
vocab 49155, MoE 40 experts top-8. [hf:ibm-granite]"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=0,  # FFN is MoE-only
    vocab_size=49155,
    head_dim=64,
    rope_theta=1e4,
    pattern=("attn",),
    moe=MoEConfig(num_experts=40, top_k=8, d_expert=512),
    act="silu",
))
