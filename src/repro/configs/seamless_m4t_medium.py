"""seamless-m4t-medium [audio] — enc-dec, 12L encoder + 12L decoder, d=1024
16H (kv=16) d_ff=4096 vocab 256206; speech frontend is a STUB (precomputed
frame embeddings). [arXiv:2308.11596]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    rope_theta=1e4,
    pattern=("attn",),
    frontend="audio",
    act="relu",
    enc_len=4096,
))
