"""deepseek-moe-16b [moe] — 28L d=2048 16H (GQA kv=16) expert d_ff=1408,
vocab 102400; 2 shared + 64 routed top-6, fine-grained; first layer dense.
[arXiv:2401.06066]"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab_size=102400,
    head_dim=128,
    rope_theta=1e4,
    pattern=("attn",),
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, num_shared=2,
                  first_k_dense=1, dense_d_ff=10944),
    act="silu",
))
