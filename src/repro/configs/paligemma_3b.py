"""paligemma-3b [vlm] — 18L d=2048 8H (MQA kv=1) d_ff=16384 vocab 257216;
SigLIP frontend is a STUB (precomputed patch embeddings). [arXiv:2407.07726]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab_size=257216,
    head_dim=256,
    rope_theta=1e4,
    pattern=("attn",),
    frontend="vision",
    act="gelu",
    tie_embeddings=True,
))
