"""smollm-360m [dense] — 32L d=960 15H (GQA kv=5) d_ff=2560 vocab 49152;
llama-arch small. [hf:HuggingFaceTB]  Awkward 15q/5kv GQA on TP=16 is realized
via GQALayout padding (16q/8kv with grad-masked zero pads) — see DESIGN.md."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    head_dim=64,
    rope_theta=1e4,
    pattern=("attn",),
    act="silu",
    tie_embeddings=True,
))
