"""Pass 1 — schedule legality for the baked plan tables.

Checks, per channel (raising :class:`PlanVerificationError` on the first
violation, with the failing (kind, order, world, channel, step, rank)):

  * ``per_step_permutation``  — sigma(., step) is a permutation of ranks;
  * ``seed_identity``         — sigma(r, 0) == r (the flow starts local);
  * ``ag_coverage``           — every rank consumes every origin exactly once;
  * ``flow_composition``      — flow_perm(step) delivers sigma(., step + 1):
                                src[dst(j)] at step+1 == src[j] at step, and
                                each dst row is itself a permutation;
  * ``rs_time_reversal``      — rs_seg(r, s) == sigma(r, world - 1 - s);
  * ``rs_home``               — rs_seg(r, world - 1) == r (reduction lands on
                                its home rank);
  * ``rs_composition``        — rs_dst rows compose with rs_seg the same way;
  * ``align_home``            — align_perm routes the ag_rs tile-following
                                reduction to the origin of the tile held last:
                                align(j) == sigma(j, world - 1);
  * ``slot_partition``        — per rank the (origin, channel) gather slots
                                are hit exactly once (no overlap / no gap in
                                the multi-channel block partition).

For a2a flows (expert-parallel dispatch/combine) three more checks run:

  * ``a2a_exchange_composition`` — the direct exchange delivers each rank's
                                *own* tile to exactly the rank that consumes
                                it: src[dst(j)] at step s == j, and each dst
                                row is itself a permutation (full coverage);
  * ``a2a_seed``              — step 0's exchange is the identity (tokens
                                routed to the local expert shard move nowhere);
  * ``a2a_involution``        — for the all2all order on power-of-two worlds
                                the exchange is the XOR involution
                                dst(j) == sigma(j, s) == j ^ s (each step is a
                                disjoint pairwise swap); non-power-of-2 worlds
                                and other orders fall back to the inverse-
                                permutation law dst == sigma(., s)^-1 already
                                proven by ``a2a_exchange_composition``.

For fused multi-op seam plans (``core/plan.SeqPlan``) ``check_seam`` adds:

  * ``seam_composition``      — the producer's fully reduced RS segment lands
                                on its home rank exactly where the consumer
                                seeds its step-0 local tile:
                                rs_seg(r, world - 1) == r == sigma(r, 0), with
                                matching world and channel counts, so the
                                handoff is rank-local (no resharding hop);

and for the a2a pair ``check_a2a_seam`` requires the combine to return along
the *reversed* edges of the dispatch exchange:

  * ``a2a_seam_composition``  — identical src tables on both halves (the
                                combine's return destination sigma(j, s) is
                                the dispatch edge traversed backwards), with
                                matching world and channel counts.

All checks run off the precomputed O(world^2 * channels) tables, so a full
verification is microseconds even at dry-run world sizes.
"""
from __future__ import annotations

from repro.analysis.errors import PlanVerificationError
from repro.analysis.ir import PlanTables

__all__ = ["check_schedule", "check_channel_partition", "check_seam", "check_a2a_seam"]


def check_channel_partition(extent: int, num_channels: int) -> int:
    """Check C block sub-chunks tile ``[0, extent)`` with no overlap or gap.

    Returns the number of assertions evaluated.  ``extent`` is the chunked
    operand extent (columns for matmul flows, tokens for attention/MoE).
    """
    if num_channels < 1 or extent % num_channels:
        raise PlanVerificationError(
            f"{num_channels} channels do not evenly partition extent {extent}",
            check="channel_partition",
        )
    sub = extent // num_channels
    covered = []
    for c in range(num_channels):
        covered.extend(range(c * sub, (c + 1) * sub))
    if covered != list(range(extent)):
        raise PlanVerificationError(
            f"channel blocks overlap or leave a gap over extent {extent}",
            check="channel_partition",
        )
    return num_channels + 1


def _ctx(t: PlanTables, **kw):
    return dict(kind=t.kind, order=t.order, world=t.world, **kw)


def _check_perm_row(t: PlanTables, row, *, check: str, channel: int, step: int) -> None:
    seen = [0] * t.world
    for r, v in enumerate(row):
        if not (0 <= v < t.world) or seen[v]:
            raise PlanVerificationError(
                f"{'duplicate' if 0 <= v < t.world and seen[v] else 'out-of-range'} "
                f"entry {v} — row is not a permutation of ranks",
                check=check,
                rank=r,
                **_ctx(t, channel=channel, step=step),
            )
        seen[v] = 1


def check_schedule(t: PlanTables) -> int:
    """Run every schedule-legality check; returns assertions evaluated."""
    world, checks = t.world, 0

    for c in range(t.num_channels):
        src_c = t.src[c]
        # per-step permutation + seed identity
        for s in range(world):
            _check_perm_row(t, src_c[s], check="per_step_permutation", channel=c, step=s)
            checks += 1
        for r in range(world):
            if src_c[0][r] != r:
                raise PlanVerificationError(
                    f"sigma(r, 0) == {src_c[0][r]}, expected r — the flow must "
                    "start from the local shard",
                    check="seed_identity",
                    rank=r,
                    **_ctx(t, channel=c, step=0),
                )
            # AG coverage: each rank consumes every origin exactly once
            if sorted(src_c[s][r] for s in range(world)) != list(range(world)):
                raise PlanVerificationError(
                    "rank does not consume every origin exactly once over the pass",
                    check="ag_coverage",
                    rank=r,
                    **_ctx(t, channel=c),
                )
            checks += 2

        # flow composition: dst row is a permutation delivering sigma(., s+1)
        if t.flow_dst is None:
            raise PlanVerificationError(
                "flow destination tables could not be derived (source schedule "
                "is not a per-step permutation)",
                check="flow_composition",
                **_ctx(t, channel=c),
            )
        for s in range(world - 1):
            dst_row = t.flow_dst[c][s]
            _check_perm_row(t, dst_row, check="flow_composition", channel=c, step=s)
            for j in range(world):
                d = dst_row[j]
                if src_c[s + 1][d] != src_c[s][j]:
                    raise PlanVerificationError(
                        f"flow_perm sends rank {j}'s held tile (origin "
                        f"{src_c[s][j]}) to rank {d}, which consumes origin "
                        f"{src_c[s + 1][d]} next",
                        check="flow_composition",
                        rank=j,
                        **_ctx(t, channel=c, step=s),
                    )
                checks += 1

        # RS view: time reversal of sigma, ending at the home rank
        seg_c = t.rs_seg[c]
        for s in range(world):
            for r in range(world):
                if seg_c[s][r] != src_c[world - 1 - s][r]:
                    raise PlanVerificationError(
                        f"rs_segment {seg_c[s][r]} is not the time reversal "
                        f"sigma(r, world-1-s) == {src_c[world - 1 - s][r]}",
                        check="rs_time_reversal",
                        rank=r,
                        **_ctx(t, channel=c, step=s),
                    )
                checks += 1
        for r in range(world):
            if seg_c[world - 1][r] != r:
                raise PlanVerificationError(
                    f"final segment {seg_c[world - 1][r]} is not the home rank",
                    check="rs_home",
                    rank=r,
                    **_ctx(t, channel=c, step=world - 1),
                )
            checks += 1
        if t.rs_dst is None:
            raise PlanVerificationError(
                "rs destination tables could not be derived",
                check="rs_composition",
                **_ctx(t, channel=c),
            )
        for s in range(world - 1):
            dst_row = t.rs_dst[c][s]
            _check_perm_row(t, dst_row, check="rs_composition", channel=c, step=s)
            for j in range(world):
                d = dst_row[j]
                if seg_c[s + 1][d] != seg_c[s][j]:
                    raise PlanVerificationError(
                        f"rs_perm sends rank {j}'s partial (segment "
                        f"{seg_c[s][j]}) to rank {d}, which reduces segment "
                        f"{seg_c[s + 1][d]} next",
                        check="rs_composition",
                        rank=j,
                        **_ctx(t, channel=c, step=s),
                    )
                checks += 1

        # a2a flows: the direct pairwise exchange must deliver each rank's
        # own tile to exactly the rank consuming it this step
        if t.flow in ("a2a", "a2a_rs"):
            if t.a2a_dst is None:
                raise PlanVerificationError(
                    "a2a exchange tables could not be derived (source schedule "
                    "is not a per-step permutation)",
                    check="a2a_exchange_composition",
                    **_ctx(t, channel=c),
                )
            xor_involution = t.order == "all2all" and world & (world - 1) == 0
            for s in range(world):
                dst_row = t.a2a_dst[c][s]
                _check_perm_row(
                    t, dst_row, check="a2a_exchange_composition", channel=c, step=s
                )
                for j in range(world):
                    if src_c[s][dst_row[j]] != j:
                        raise PlanVerificationError(
                            f"a2a exchange sends rank {j}'s own tile to rank "
                            f"{dst_row[j]}, which consumes origin "
                            f"{src_c[s][dst_row[j]]} at this step",
                            check="a2a_exchange_composition",
                            rank=j,
                            **_ctx(t, channel=c, step=s),
                        )
                    if s == 0 and dst_row[j] != j:
                        raise PlanVerificationError(
                            f"step-0 a2a exchange moves rank {j}'s tile to "
                            f"{dst_row[j]}; the seed step must be local",
                            check="a2a_seed",
                            rank=j,
                            **_ctx(t, channel=c, step=0),
                        )
                    if xor_involution and dst_row[j] != src_c[s][j]:
                        raise PlanVerificationError(
                            f"all2all exchange is not the XOR involution: rank "
                            f"{j} sends to {dst_row[j]} but receives from "
                            f"{src_c[s][j]}",
                            check="a2a_involution",
                            rank=j,
                            **_ctx(t, channel=c, step=s),
                        )
                    checks += 2 + int(xor_involution)

        # ag_rs final alignment hop: deliver the reduction for the tile held
        # last (origin sigma(j, world-1)) to that origin rank
        for j in range(world):
            if t.align[c][j] != src_c[world - 1][j]:
                raise PlanVerificationError(
                    f"align_perm sends rank {j}'s reduction to "
                    f"{t.align[c][j]}, but the tile it followed originates at "
                    f"{src_c[world - 1][j]}",
                    check="align_home",
                    rank=j,
                    **_ctx(t, channel=c, step=world - 1),
                )
            checks += 1

    # slot partition across channels: per rank, the (origin, channel) gather
    # slots are each hit exactly once — no overlap, no gap
    for r in range(world):
        slots = sorted(
            t.src[c][s][r] * t.num_channels + c
            for c in range(t.num_channels)
            for s in range(world)
        )
        if slots != list(range(world * t.num_channels)):
            raise PlanVerificationError(
                "gather-buffer slots are not a partition: some (origin, "
                "channel) slot is reused or never consumed",
                check="slot_partition",
                rank=r,
                **_ctx(t),
            )
        checks += 1
    return checks


def check_seam(producer: PlanTables, consumer: PlanTables) -> int:
    """Seam-composition legality for a fused RS -> AG pair.

    The fused executor hands each channel's fully reduced RS segment to the
    consumer *in place* — no resharding hop — which is only sound when the
    producer's last-step segment schedule and the consumer's step-0 source
    schedule are both the identity on every rank, over the same world and
    channel split.  Returns the number of assertions evaluated.
    """
    kind = f"{producer.kind}->{consumer.kind}"
    order = f"{producer.order}->{consumer.order}"
    if producer.flow != "rs" or consumer.flow != "ag":
        raise PlanVerificationError(
            f"seam chains flows {(producer.flow, consumer.flow)}; only an rs "
            "producer feeding an ag consumer composes rank-locally",
            check="seam_composition",
            kind=kind,
            order=order,
            world=producer.world,
        )
    if producer.world != consumer.world:
        raise PlanVerificationError(
            f"producer world {producer.world} != consumer world {consumer.world}",
            check="seam_composition",
            kind=kind,
            order=order,
            world=producer.world,
        )
    if producer.num_channels != consumer.num_channels:
        raise PlanVerificationError(
            f"producer has {producer.num_channels} channels but consumer has "
            f"{consumer.num_channels}; the seam handoff is per-channel",
            check="seam_composition",
            kind=kind,
            order=order,
            world=producer.world,
        )
    world, checks = producer.world, 3
    for c in range(producer.num_channels):
        for r in range(world):
            home = producer.rs_seg[c][world - 1][r]
            seed = consumer.src[c][0][r]
            if home != r or seed != r:
                raise PlanVerificationError(
                    f"rank holds producer segment {home} after the RS pass but "
                    f"the consumer seeds origin {seed}; the seam handoff is "
                    "only rank-local when both are the rank itself",
                    check="seam_composition",
                    kind=kind,
                    order=order,
                    world=world,
                    channel=c,
                    rank=r,
                )
            checks += 1
    return checks


def check_a2a_seam(dispatch: PlanTables, combine: PlanTables) -> int:
    """Composition legality for a fused ``a2a_dispatch -> combine_rs`` pair.

    The combine returns each step's expert partials along the *reversed*
    dispatch edge (rank j sends step s's partial to sigma(j, s), the origin of
    the tokens it just processed) — sound only when both halves realize the
    same exchange: identical src tables, world, and channel count.  Returns
    the number of assertions evaluated.
    """
    kind = f"{dispatch.kind}->{combine.kind}"
    order = f"{dispatch.order}->{combine.order}"
    if dispatch.flow != "a2a" or combine.flow != "a2a_rs":
        raise PlanVerificationError(
            f"a2a seam chains flows {(dispatch.flow, combine.flow)}; only an "
            "a2a dispatch feeding an a2a_rs combine reverses edge-for-edge",
            check="a2a_seam_composition",
            kind=kind,
            order=order,
            world=dispatch.world,
        )
    if dispatch.world != combine.world:
        raise PlanVerificationError(
            f"dispatch world {dispatch.world} != combine world {combine.world}",
            check="a2a_seam_composition",
            kind=kind,
            order=order,
            world=dispatch.world,
        )
    if dispatch.num_channels != combine.num_channels:
        raise PlanVerificationError(
            f"dispatch has {dispatch.num_channels} channels but combine has "
            f"{combine.num_channels}; the return edge is per-channel",
            check="a2a_seam_composition",
            kind=kind,
            order=order,
            world=dispatch.world,
        )
    world, checks = dispatch.world, 3
    for c in range(dispatch.num_channels):
        for s in range(world):
            for r in range(world):
                if combine.src[c][s][r] != dispatch.src[c][s][r]:
                    raise PlanVerificationError(
                        f"combine returns step {s}'s partial to "
                        f"{combine.src[c][s][r]} but the dispatch exchange "
                        f"consumed origin {dispatch.src[c][s][r]}; the return "
                        "must traverse the dispatch edge backwards",
                        check="a2a_seam_composition",
                        kind=kind,
                        order=order,
                        world=world,
                        channel=c,
                        step=s,
                        rank=r,
                    )
                checks += 1
    return checks
