"""Plan-IR verifier entry points + the ``python -m repro.analysis.verify`` CLI.

``verify_plan`` runs the schedule-legality pass (always) and the semaphore-
protocol pass (for worlds up to ``REPRO_VERIFY_PROTOCOL_MAX_WORLD``, default
32 — the protocol simulation is O(world^2 * channels) events with world-length
vector clocks, so it is skipped at dry-run mesh sizes where the schedule pass
alone still runs in microseconds).

``build_plan`` calls ``verify_plan`` on every freshly built plan unless
``REPRO_VERIFY=0`` (see ``core/plan.py``); ``check_candidate`` is the cached
boolean form the tuner uses to reject illegal candidates before spending
measurement budget.  ``python -m repro.analysis.verify --all`` exhaustively
verifies the shipped plan space (all kinds x orders x world in {2,3,4,8} x
C in {1,2,4} — world 3 exercises the non-power-of-2 all2all fallback) with
no JAX device — it is the CI ``verify`` job.

This module imports ``repro.core`` lazily (inside functions) so the analysis
package stays importable from ``core/plan.py`` without a cycle.
"""
from __future__ import annotations

import argparse
import functools
import os
from typing import Optional, Sequence, Tuple

from repro.analysis.errors import PlanVerificationError, VerificationReport
from repro.analysis.ir import PlanTables
from repro.analysis.protocol import (
    check_a2a_seam_protocol,
    check_protocol,
    check_seam_protocol,
)
from repro.analysis.schedule import check_a2a_seam, check_schedule, check_seam

__all__ = [
    "check_quant",
    "verify_plan",
    "verify_tables",
    "verify_seq_plan",
    "verify_seq_tables",
    "check_candidate",
    "check_seq_candidate",
    "check_a2a_candidate",
    "verify_space",
    "verify_seq_space",
    "main",
]

# shipped plan space: what `--all` (and the CI verify job) proves well-formed
# (world 3 exercises the non-power-of-2 all2all rotation fallback)
SPACE_WORLDS = (2, 3, 4, 8)
SPACE_CHANNELS = (1, 2, 4)

# fused multi-op pairs selectable from the CLI (--kind) and swept by --all
SEQ_KIND = "seq_rs_ag"
A2A_SEQ_KIND = "seq_a2a_moe"
SEQ_OPS = {
    SEQ_KIND: ("matmul_rs", "ag_matmul"),
    A2A_SEQ_KIND: ("a2a_dispatch", "combine_rs"),
}


def _protocol_max_world() -> int:
    return int(os.environ.get("REPRO_VERIFY_PROTOCOL_MAX_WORLD", "32"))


def check_quant(tables: PlanTables) -> int:
    """Wire-dtype pass: the plan's scale-table spec must cover every encoded
    wire edge of its schedule.

    Evaluates 0 checks when the tables carry no quant snapshot (duck-typed /
    hand-built tables) — then there is nothing the executors would allocate.
    An identity wire legitimately needs 0 slots and still passes through the
    coverage equation (both sides are 0).
    """
    slots = getattr(tables, "scale_slots", None)
    wire = getattr(tables, "wire_dtype", None)
    if slots is None or wire is None:
        return 0
    from repro.core.quant import GRANULARITIES, WIRE_DTYPES, QuantSpec

    checks = 0
    if wire not in WIRE_DTYPES:
        raise PlanVerificationError(
            f"wire dtype {wire!r} is not one of {WIRE_DTYPES}",
            check="quant_wire_dtype",
            kind=tables.kind, order=tables.order, world=tables.world,
        )
    checks += 1
    gran = getattr(tables, "granularity", None)
    if gran not in GRANULARITIES:
        raise PlanVerificationError(
            f"scale granularity {gran!r} is not one of {GRANULARITIES}",
            check="quant_granularity",
            kind=tables.kind, order=tables.order, world=tables.world,
        )
    checks += 1
    steps = len(tables.src[0]) if tables.src else tables.world
    expected = QuantSpec(wire_dtype=wire, granularity=gran).scale_slots(
        tables.flow, tables.world, tables.num_channels, steps
    )
    if int(slots) != int(expected):
        raise PlanVerificationError(
            f"scale table allocates {slots} slot(s) but the {tables.flow!r} "
            f"flow quantizes {expected} wire edge(s) over {steps} step(s)",
            check="quant_scale_slots",
            kind=tables.kind, order=tables.order, world=tables.world,
        )
    checks += 1
    return checks


def verify_tables(
    tables: PlanTables,
    *,
    protocol: Optional[bool] = None,
    requested_channels: Optional[int] = None,
) -> VerificationReport:
    """Verify baked tables; raises PlanVerificationError, returns a report."""
    checks = check_schedule(tables)
    checks += check_quant(tables)
    passes = ["schedule"]
    events = 0
    if protocol is None:
        protocol = tables.world <= _protocol_max_world()
    if protocol:
        pchecks, events = check_protocol(tables)
        checks += pchecks
        passes.append("protocol")
    return VerificationReport(
        kind=tables.kind,
        order=tables.order,
        world=tables.world,
        flow=tables.flow,
        effective_channels=tables.num_channels,
        requested_channels=requested_channels,
        passes=tuple(passes),
        checks=checks,
        events=events,
    )


def verify_plan(
    plan,
    *,
    protocol: Optional[bool] = None,
    requested_channels: Optional[int] = None,
) -> VerificationReport:
    """Statically verify one :class:`~repro.core.plan.TilePlan`."""
    return verify_tables(
        PlanTables.from_plan(plan),
        protocol=protocol,
        requested_channels=requested_channels,
    )


def verify_seq_tables(
    tables: Sequence[PlanTables],
    *,
    protocol: Optional[bool] = None,
    requested_channels: Optional[int] = None,
) -> VerificationReport:
    """Verify a fused seam (producer RS tables -> consumer AG tables).

    Runs the single-op schedule pass on each constituent (failures re-raised
    with the op's ``op_index`` within the sequence), then the seam-composition
    check, then one *combined* protocol pass over the concatenated per-rank
    streams — so a race or deadlock introduced by the handoff itself, not just
    by either half alone, is caught.
    """
    producer, consumer = tables
    is_a2a = producer.flow == "a2a" or consumer.flow == "a2a_rs"
    checks = 0
    for i, t in enumerate(tables):
        try:
            checks += check_schedule(t)
            checks += check_quant(t)
        except PlanVerificationError as e:
            raise e.with_op_index(i) from None
    if is_a2a:
        checks += check_a2a_seam(producer, consumer)
    else:
        checks += check_seam(producer, consumer)
    passes = ["schedule", "seam"]
    events = 0
    if protocol is None:
        protocol = producer.world <= _protocol_max_world()
    if protocol:
        if is_a2a:
            pchecks, events = check_a2a_seam_protocol(producer, consumer)
        else:
            pchecks, events = check_seam_protocol(producer, consumer)
        checks += pchecks
        passes.append("protocol")
    return VerificationReport(
        kind=f"{producer.kind}->{consumer.kind}",
        order=(
            producer.order
            if producer.order == consumer.order
            else f"{producer.order}->{consumer.order}"
        ),
        world=producer.world,
        flow=f"{producer.flow}->{consumer.flow}",
        effective_channels=producer.num_channels,
        requested_channels=requested_channels,
        passes=tuple(passes),
        checks=checks,
        events=events,
    )


def verify_seq_plan(
    seq,
    *,
    protocol: Optional[bool] = None,
    requested_channels: Optional[int] = None,
) -> VerificationReport:
    """Statically verify one :class:`~repro.core.plan.SeqPlan`."""
    return verify_seq_tables(
        [PlanTables.from_plan(op) for op in seq.ops],
        protocol=protocol,
        requested_channels=requested_channels,
    )


@functools.lru_cache(maxsize=4096)
def check_candidate(kind: str, order: str, world: int, num_channels: int) -> Optional[str]:
    """Cheap cached legality probe for the tuner: None if legal, else the
    structured diagnosis message (same one the executor would raise)."""
    from repro.core.channels import BlockChannel, CommSpec
    from repro.core.plan import build_plan

    channel = BlockChannel(axis="model", comm=CommSpec(order=order), num_channels=num_channels)
    try:
        plan = build_plan(kind, channel, world, num_channels)
        verify_plan(plan)
    except PlanVerificationError as e:
        return str(e)
    return None


@functools.lru_cache(maxsize=4096)
def check_seq_candidate(order: str, world: int, num_channels: int) -> Optional[str]:
    """Cached legality probe for a fused ``matmul_rs -> ag_matmul`` seam."""
    from repro.core.channels import BlockChannel, CommSpec
    from repro.core.plan import build_seq_plan

    ch = BlockChannel(axis="model", comm=CommSpec(order=order), num_channels=num_channels)
    try:
        seq = build_seq_plan(("matmul_rs", "ag_matmul"), (ch, ch), world, num_channels)
        verify_seq_plan(seq)
    except PlanVerificationError as e:
        return str(e)
    return None


@functools.lru_cache(maxsize=4096)
def check_a2a_candidate(order: str, world: int, num_channels: int) -> Optional[str]:
    """Cached legality probe for a fused ``a2a_dispatch -> combine_rs`` pair."""
    from repro.core.channels import BlockChannel, CommSpec
    from repro.core.plan import build_seq_plan

    ch = BlockChannel(axis="model", comm=CommSpec(order=order), num_channels=num_channels)
    try:
        seq = build_seq_plan(("a2a_dispatch", "combine_rs"), (ch, ch), world, num_channels)
        verify_seq_plan(seq)
    except PlanVerificationError as e:
        return str(e)
    return None


def verify_space(
    *,
    kinds: Optional[Sequence[str]] = None,
    orders: Optional[Sequence[str]] = None,
    worlds: Sequence[int] = SPACE_WORLDS,
    channels: Sequence[int] = SPACE_CHANNELS,
    protocol: Optional[bool] = None,
):
    """Yield a VerificationReport per point of the shipped plan space."""
    from repro.core.channels import ORDERS, BlockChannel, CommSpec
    from repro.core.plan import FLOW_OF_KIND, build_plan

    for kind in kinds if kinds is not None else sorted(FLOW_OF_KIND):
        for order in orders if orders is not None else ORDERS:
            for world in worlds:
                for nch in channels:
                    ch = BlockChannel(axis="model", comm=CommSpec(order=order), num_channels=nch)
                    plan = build_plan(kind, ch, world, nch)
                    yield verify_plan(plan, protocol=protocol, requested_channels=nch)


def verify_seq_space(
    *,
    kinds: Tuple[str, str] = ("matmul_rs", "ag_matmul"),
    orders: Optional[Sequence[str]] = None,
    worlds: Sequence[int] = SPACE_WORLDS,
    channels: Sequence[int] = SPACE_CHANNELS,
    protocol: Optional[bool] = None,
):
    """Yield a VerificationReport per fused 2-op pair of ``kinds``.

    Covers the RS->AG layer seam and the a2a dispatch/combine pair.  One
    shared order per pair (mixed-order seams are legal — the composition
    invariant only involves the home/seed identities — but the shipped space
    is what the ``compile_overlap`` list form emits: matching channels on
    both halves).
    """
    from repro.core.channels import ORDERS, BlockChannel, CommSpec
    from repro.core.plan import build_seq_plan

    for order in orders if orders is not None else ORDERS:
        for world in worlds:
            for nch in channels:
                ch = BlockChannel(axis="model", comm=CommSpec(order=order), num_channels=nch)
                seq = build_seq_plan(tuple(kinds), (ch, ch), world, nch)
                yield verify_seq_plan(seq, protocol=protocol, requested_channels=nch)


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.verify",
        description="Statically verify TilePlan schedules + semaphore protocols.",
    )
    p.add_argument("--all", action="store_true", help="verify the full shipped plan space")
    p.add_argument("--kind", action="append", help="workload kind(s) to verify")
    p.add_argument("--order", action="append", help="tile order(s) to verify")
    p.add_argument("--world", type=int, action="append", help="world size(s)")
    p.add_argument("--channels", type=int, action="append", help="channel count(s)")
    p.add_argument("--quiet", action="store_true", help="only print failures + the summary line")
    args = p.parse_args(argv)
    if not (args.all or args.kind or args.order or args.world or args.channels):
        p.error("nothing to verify: pass --all or narrow with --kind/--order/--world/--channels")

    from repro.core.channels import ORDERS
    from repro.core.plan import FLOW_OF_KIND

    # "seq_rs_ag" selects the fused RS->AG seam space and "seq_a2a_moe" the
    # fused dispatch/combine pair; any single-op kind narrows to single-op
    # plans only.  Default (--all / no --kind) verifies everything.
    kinds = args.kind or sorted(FLOW_OF_KIND) + sorted(SEQ_OPS)
    ok = failed = 0
    for kind in kinds:
        for order in args.order or ORDERS:
            try:
                space = (
                    verify_seq_space(
                        kinds=SEQ_OPS[kind],
                        orders=[order],
                        worlds=args.world or SPACE_WORLDS,
                        channels=args.channels or SPACE_CHANNELS,
                    )
                    if kind in SEQ_OPS
                    else verify_space(
                        kinds=[kind],
                        orders=[order],
                        worlds=args.world or SPACE_WORLDS,
                        channels=args.channels or SPACE_CHANNELS,
                    )
                )
                for report in space:
                    ok += 1
                    if not args.quiet:
                        print(f"ok   {report.summary()}")
            except PlanVerificationError as e:
                failed += 1
                print(f"FAIL {e}")
    status = "verified" if not failed else "FAILED"
    print(f"{status}: {ok} plan(s) ok, {failed} failure(s)")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
