"""Table-level view of a plan — the IR the static passes operate on.

:class:`PlanTables` snapshots the exact nested int tuples a
:class:`~repro.core.plan.TilePlan` bakes into the executors (``src_tables`` /
``flow_dst_tables`` / ``rs_seg_tables`` / ``rs_dst_tables`` / ``align_perm``),
so the verifier checks what ships, not a re-derivation.  It is duck-typed on
the plan object (no ``repro.core`` import) to keep the analysis layer free of
circular imports — ``core/plan.py`` imports ``analysis.errors``.

The mutation test-suite pokes these tables via ``dataclasses.replace`` to
seed schedule bugs the verifier must flag.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

Table = Tuple[Tuple[Tuple[int, ...], ...], ...]  # [channel][step][rank]

__all__ = ["PlanTables", "Table"]


@dataclasses.dataclass(frozen=True)
class PlanTables:
    """Baked schedule tables for one plan, indexed ``[channel][step][rank]``.

    ``flow_dst`` / ``rs_dst`` may be ``None`` when the plan could not derive
    them (a source schedule that is not a per-step permutation) — the schedule
    pass then reports the root cause from ``src`` instead of crashing during
    table construction.
    """

    kind: str
    order: str
    flow: str  # "ag" | "rs" | "ag_rs" | "a2a" | "a2a_rs"
    world: int
    num_channels: int
    src: Table  # AG origin rank consumed per (c, step, rank)
    rs_seg: Table  # RS segment reduced per (c, step, rank)
    flow_dst: Optional[Table]  # AG push destination (last row identity, unused)
    rs_dst: Optional[Table]  # RS push destination (last row identity, unused)
    align: Tuple[Tuple[int, ...], ...]  # [channel][rank] ag_rs final-hop dst
    a2a_dst: Optional[Table] = None  # a2a direct-exchange destination (step 0 identity)
    # quant snapshot (wire-edge dtype split).  All None on duck-typed plan
    # objects without a QuantSpec — the quant pass then evaluates 0 checks,
    # so the mutation suite's hand-built tables are unaffected.
    accum_dtype: Optional[str] = None  # reduction dtype
    wire_dtype: Optional[str] = None  # dtype travelling the permutes
    granularity: Optional[str] = None  # scale granularity (per_tile/per_channel)
    scale_slots: Optional[int] = None  # scale-table coverage the plan allocates

    @classmethod
    def from_plan(cls, plan) -> "PlanTables":
        """Snapshot the tables a TilePlan-compatible object emits."""
        try:
            flow_dst = plan.flow_dst_tables()
            rs_dst = plan.rs_dst_tables()
        except ValueError:
            # not a per-step permutation; the schedule pass reports precisely
            flow_dst = rs_dst = None
        a2a_dst = None
        if plan.flow in ("a2a", "a2a_rs") and hasattr(plan, "a2a_dst_tables"):
            try:
                a2a_dst = plan.a2a_dst_tables()
            except Exception:
                a2a_dst = None  # schedule pass reports the root cause from src
        accum_dtype = getattr(plan, "accum_dtype", None)
        quant = getattr(plan, "quant", None)
        wire_dtype = granularity = scale_slots = None
        if quant is not None and accum_dtype is not None:
            wire_dtype = quant.resolve_wire(accum_dtype)
            granularity = quant.granularity
            scale_slots = plan.quant_table_spec()
        return cls(
            kind=plan.kind,
            order=plan.channels[0].order,
            flow=plan.flow,
            world=plan.world,
            num_channels=plan.num_channels,
            src=plan.src_tables(),
            rs_seg=plan.rs_seg_tables(),
            flow_dst=flow_dst,
            rs_dst=rs_dst,
            align=tuple(tuple(d for _, d in ch.align_perm()) for ch in plan.channels),
            a2a_dst=a2a_dst,
            accum_dtype=accum_dtype,
            wire_dtype=wire_dtype,
            granularity=granularity,
            scale_slots=scale_slots,
        )

    # ---- mutation helpers (test suite) --------------------------------------
    def poke(self, table: str, channel: int, step: int, rank: int, value: int) -> "PlanTables":
        """Return a copy with one entry of ``table`` replaced by ``value``."""
        rows = [[list(r) for r in ch] for ch in getattr(self, table)]
        rows[channel][step][rank] = value
        frozen = tuple(tuple(tuple(r) for r in ch) for ch in rows)
        return dataclasses.replace(self, **{table: frozen})

    def poke_align(self, channel: int, rank: int, value: int) -> "PlanTables":
        rows = [list(ch) for ch in self.align]
        rows[channel][rank] = value
        return dataclasses.replace(self, align=tuple(tuple(ch) for ch in rows))
