"""Static analysis of the tile-centric IR — no device required.

Three passes over every plan the repo can emit (see ``ISSUE``/README):

  1. ``analysis.schedule`` — schedule legality of the baked tables;
  2. ``analysis.protocol`` — semaphore-protocol model checking (signal/wait
     counts, deadlock freedom, RAW/WAR races at double-buffer depth);
  3. ``analysis.lint``     — AST layering rules for the repo itself.

``verify_plan`` is called on every ``build_plan`` miss (``REPRO_VERIFY=0``
opts out); ``check_candidate`` gates tuner candidates;
``python -m repro.analysis.verify --all`` proves the shipped space.

Layering: this package must stay importable from ``repro.core.plan`` —
submodules import ``repro.core`` only lazily, inside functions.
"""
from repro.analysis.errors import PlanVerificationError, VerificationReport
from repro.analysis.ir import PlanTables
from repro.analysis.verify import (
    check_a2a_candidate,
    check_candidate,
    check_quant,
    check_seq_candidate,
    verify_plan,
    verify_seq_plan,
    verify_seq_space,
    verify_seq_tables,
    verify_space,
    verify_tables,
)

__all__ = [
    "PlanVerificationError",
    "VerificationReport",
    "PlanTables",
    "check_a2a_candidate",
    "check_candidate",
    "check_quant",
    "check_seq_candidate",
    "verify_plan",
    "verify_seq_plan",
    "verify_seq_space",
    "verify_seq_tables",
    "verify_space",
    "verify_tables",
]
