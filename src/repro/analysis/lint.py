"""Pass 3 — AST lint rules for the repo's layering invariants.

Enforces, over ``src/repro``, the invariants the changelog states informally
(run as a pytest in ``tests/test_analysis.py`` and as a CI step via
``python -m repro.analysis.lint``):

  * ``ppermute-site``   — ``lax.ppermute`` may appear only in
                          ``core/overlap.py`` (the single generic schedule
                          executor); every other layer goes through plans;
  * ``semaphore-site``  — semaphore / remote-DMA primitives
                          (``semaphore_signal``, ``semaphore_wait``,
                          ``dma_semaphore``, ``make_async_copy``,
                          ``make_async_remote_copy``) may appear only under
                          ``kernels/``, ``backend/`` and the paper-primitive
                          wrappers in ``core/primitives.py``;
  * ``raw-pallas-call`` — no raw ``pl.pallas_call`` outside ``backend/``;
                          kernels must launch through ``backend.pallas_call``
                          so the emulated/Mosaic target switch stays in one
                          place.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
from pathlib import Path
from typing import List, Optional, Sequence

__all__ = ["Violation", "lint_source", "lint_file", "lint_tree", "main"]

_SEM_PRIMITIVES = frozenset(
    {
        "semaphore_signal",
        "semaphore_wait",
        "dma_semaphore",
        "make_async_copy",
        "make_async_remote_copy",
        "get_barrier_semaphore",
    }
)

# rule -> relative paths (or dir prefixes ending in "/") allowed to match
_ALLOWED = {
    "ppermute-site": ("core/overlap.py",),
    "semaphore-site": ("kernels/", "backend/", "core/primitives.py"),
    "raw-pallas-call": ("backend/",),
}


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str  # relative to the repro package root
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _allowed(rule: str, relpath: str) -> bool:
    return any(
        relpath == entry or (entry.endswith("/") and relpath.startswith(entry))
        for entry in _ALLOWED[rule]
    )


def lint_source(source: str, relpath: str) -> List[Violation]:
    """Lint one module's source; ``relpath`` is relative to ``src/repro``."""
    violations: List[Violation] = []
    tree = ast.parse(source, filename=relpath)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Attribute):
            continue
        base = node.value.id if isinstance(node.value, ast.Name) else None
        if node.attr == "ppermute" and not _allowed("ppermute-site", relpath):
            violations.append(
                Violation(
                    relpath,
                    node.lineno,
                    "ppermute-site",
                    f"{base or '?'}.ppermute outside core/overlap.py — route "
                    "collectives through the plan executor",
                )
            )
        elif node.attr in _SEM_PRIMITIVES and not _allowed("semaphore-site", relpath):
            violations.append(
                Violation(
                    relpath,
                    node.lineno,
                    "semaphore-site",
                    f"{base or '?'}.{node.attr} outside kernels/, backend/ or "
                    "core/primitives.py",
                )
            )
        elif (
            node.attr == "pallas_call"
            and base != "backend"
            and not _allowed("raw-pallas-call", relpath)
        ):
            violations.append(
                Violation(
                    relpath,
                    node.lineno,
                    "raw-pallas-call",
                    f"raw {base or '?'}.pallas_call outside backend/ — use "
                    "backend.pallas_call",
                )
            )
    return violations


def lint_file(path: Path, root: Path) -> List[Violation]:
    relpath = path.relative_to(root).as_posix()
    return lint_source(path.read_text(), relpath)


def lint_tree(root: Optional[Path] = None) -> List[Violation]:
    """Lint every module under ``src/repro`` (the default root)."""
    root = root or Path(__file__).resolve().parents[1]
    violations: List[Violation] = []
    for path in sorted(root.rglob("*.py")):
        violations.extend(lint_file(path, root))
    return violations


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Layering lint: ppermute/semaphore/pallas_call call-sites.",
    )
    p.add_argument("root", nargs="?", default=None, help="package root (default: src/repro)")
    args = p.parse_args(argv)
    violations = lint_tree(Path(args.root) if args.root else None)
    for v in violations:
        print(v)
    print(f"{len(violations)} violation(s)")
    return 1 if violations else 0


if __name__ == "__main__":
    raise SystemExit(main())
