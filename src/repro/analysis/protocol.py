"""Pass 2 — semaphore-protocol verification of the fused-kernel schedules.

Reconstructs, from a plan's baked int32 tables, the per-(rank, step, channel)
abstract instruction streams the fused Pallas kernels execute
(``kernels/ag_gemm.py`` for "ag" flows, ``kernels/gemm_rs.py`` for "rs") —
local buffer reads/writes, remote-DMA starts, and semaphore waits — then
model-checks them:

  * ``sem_count``   — every semaphore slot has matched signal/wait totals;
  * ``deadlock``    — a happens-before simulation (vector clocks, counting
                      semaphores) runs every rank to completion; a stuck
                      state is reported with the blocked rank + slot.  A
                      completed simulation certifies the signal/wait graph is
                      cycle-free (the constructed happens-before relation is
                      a partial order by construction);
  * RAW/WAR/WAW     — every pair of conflicting accesses to a buffer slot
                      must be ordered by happens-before *through a resolved
                      semaphore wait*: ``read_before_signal`` (a recv-buffer
                      slot read without an ordering signal), ``overwritten_
                      before_wait`` (a slot overwritten while an outstanding
                      DMA may still be reading it — double-buffer depth
                      violations), ``double_write`` (two unordered writers).

Counting-semaphore soundness: a wait resolves a DMA's completion (and gains
its happens-before edge) only when *every* signal that could satisfy it is
accounted for — the n-th wait on a slot resolves outstanding signals only if
exactly n have started.  With more starts than consumed credits the credits
are interchangeable, no completion is learned, and any dependent access is
flagged.  This is precisely the rule that rejects sharing one send semaphore
across channels (each channel's ``wait_send`` could consume the other
channel's completion credit while its own push is still reading the
accumulator columns — see ``tests/test_analysis.py``).
"""
from __future__ import annotations

import dataclasses
from collections import Counter, defaultdict
from typing import Dict, List, Optional, Tuple

from repro.analysis.errors import PlanVerificationError
from repro.analysis.ir import PlanTables

__all__ = [
    "build_streams",
    "build_seam_streams",
    "build_a2a_seam_streams",
    "check_streams",
    "check_protocol",
    "check_seam_protocol",
    "check_a2a_seam_protocol",
    "DmaStart",
    "Wait",
    "LocalRead",
    "LocalWrite",
]


# ---- abstract ops (locations and sems are (name, index), local to a rank) ---
@dataclasses.dataclass(frozen=True)
class LocalWrite:
    loc: Tuple[str, int]


@dataclasses.dataclass(frozen=True)
class LocalRead:
    loc: Tuple[str, int]


@dataclasses.dataclass(frozen=True)
class DmaStart:
    """Async remote copy: reads ``src`` locally until the send semaphore is
    signaled; writes ``dst`` at ``dst_rank`` until the recv semaphore is."""

    src: Tuple[str, int]
    dst_rank: int
    dst: Tuple[str, int]
    send_sem: Tuple[str, int]
    recv_sem: Tuple[str, int]


@dataclasses.dataclass(frozen=True)
class Wait:
    sem: Tuple[str, int]


def build_streams(t: PlanTables, *, shared_rs_send_sem: bool = False) -> Dict[int, list]:
    """Abstract per-rank instruction streams implied by the plan tables.

    "ag" / "ag_rs" flows model ``kernels/ag_gemm.py`` (the ag_rs double-ring's
    tile-following reduction is XLA-only, so its semaphore realization is the
    forward tile flow); "rs" models ``kernels/gemm_rs.py``.

    ``shared_rs_send_sem=True`` reproduces the pre-fix gemm_rs protocol that
    shared one send semaphore across channels — kept so the test suite can
    demonstrate the WAR race the verifier flags on it.
    """
    if t.flow in ("ag", "ag_rs"):
        return _ag_streams(t)
    if t.flow == "rs":
        return _rs_streams(t, shared_send_sem=shared_rs_send_sem)
    if t.flow == "a2a":
        return _a2a_streams(t)
    if t.flow == "a2a_rs":
        return _combine_streams(t)
    raise ValueError(f"unknown flow {t.flow!r}")


def _ag_streams(t: PlanTables) -> Dict[int, list]:
    world, nch = t.world, t.num_channels
    streams = {}
    for r in range(world):
        ops: list = []
        for s in range(world):
            for c in range(nch):
                slot = t.src[c][s][r] * nch + c
                if s == 0:
                    # stage channel c of the own shard into its gather slot
                    ops.append(LocalWrite(("gather", r * nch + c)))
                # consumer_tile_wait's load: gather slot -> VMEM staging
                ops.append(LocalRead(("gather", slot)))
                ops.append(LocalWrite(("x_vmem", 0)))
                if s < world - 1:
                    # tile_push_data: forward the held tile to the next consumer
                    d = t.flow_dst[c][s][r]
                    ops.append(
                        DmaStart(
                            src=("x_vmem", 0),
                            dst_rank=d,
                            dst=("gather", slot),
                            send_sem=("send", 0),
                            recv_sem=("recv", s * nch + c),
                        )
                    )
                ops.append(LocalRead(("x_vmem", 0)))  # MXU consumes the tile
                if s < world - 1:
                    ops.append(Wait(("send", 0)))  # x_vmem drained
                    ops.append(Wait(("recv", s * nch + c)))  # next tile arrived
        streams[r] = ops
    return streams


def _rs_streams(t: PlanTables, *, shared_send_sem: bool = False) -> Dict[int, list]:
    world, nch = t.world, t.num_channels
    streams = {}
    for r in range(world):
        ops: list = []
        for s in range(world):
            for c in range(nch):
                send = ("send", 0 if shared_send_sem else c)
                if s > 0:
                    # consumer_tile_wait (acquire): stage s-1 partial arrived
                    ops.append(Wait(("recv", (s - 1) * nch + c)))
                    ops.append(LocalRead(("rbuf", (s - 1) * nch + c)))
                    # release: our stage s-1 push drained before acc reuse
                    ops.append(Wait(send))
                ops.append(LocalWrite(("acc", c)))  # stage GEMM (+ add prev)
                if s < world - 1:
                    d = t.rs_dst[c][s][r]
                    ops.append(
                        DmaStart(
                            src=("acc", c),
                            dst_rank=d,
                            dst=("rbuf", s * nch + c),
                            send_sem=send,
                            recv_sem=("recv", s * nch + c),
                        )
                    )
                else:
                    ops.append(LocalRead(("acc", c)))  # final store
        streams[r] = ops
    return streams


def _a2a_streams(t: PlanTables) -> Dict[int, list]:
    """Dispatch half of the expert-parallel a2a (direct pairwise exchange).

    Each rank stages its own token tile once, pushes it directly to step
    s+1's consumer while reading step s's landed tile — nothing is forwarded,
    so the send buffer is written once and every landed slot has exactly one
    writer and one reader.
    """
    world, nch = t.world, t.num_channels
    streams = {}
    for r in range(world):
        ops: list = []
        for s in range(world):
            for c in range(nch):
                if s == 0:
                    ops.append(LocalWrite(("x", c)))  # stage own token tile
                if s < world - 1:
                    # issue step s+1's exchange while step s's tile is consumed
                    d = t.a2a_dst[c][s + 1][r]
                    ops.append(
                        DmaStart(
                            src=("x", c),
                            dst_rank=d,
                            dst=("land", (s + 1) * nch + c),
                            send_sem=("dsend", s * nch + c),
                            recv_sem=("drecv", (s + 1) * nch + c),
                        )
                    )
                if s == 0:
                    ops.append(LocalRead(("x", c)))  # local tokens, no hop
                else:
                    ops.append(Wait(("drecv", s * nch + c)))
                    ops.append(LocalRead(("land", s * nch + c)))
        for s in range(world - 1):  # drain: own tile no longer being read
            for c in range(nch):
                ops.append(Wait(("dsend", s * nch + c)))
        streams[r] = ops
    return streams


def _combine_streams(t: PlanTables) -> Dict[int, list]:
    """Combine half: per-step expert partials return along the reversed edge.

    At step s rank r holds the output for tokens of origin sigma(r, s); it
    returns that partial straight home while the home rank accumulates — the
    accumulator never travels (unlike ag_rs, where the reduction follows the
    tile flow and needs a final alignment hop).
    """
    world, nch = t.world, t.num_channels
    streams = {}
    for r in range(world):
        ops: list = []
        for s in range(world):
            for c in range(nch):
                if s == 0:
                    ops.append(LocalWrite(("acc", c)))  # own partial, no hop
                    continue
                if s >= 2:  # part buffer reuse: previous return drained
                    ops.append(Wait(("csend", (s - 1) * nch + c)))
                ops.append(LocalWrite(("part", c)))  # stage step s's partial
                ops.append(
                    DmaStart(
                        src=("part", c),
                        dst_rank=t.src[c][s][r],
                        dst=("ret", s * nch + c),
                        send_sem=("csend", s * nch + c),
                        recv_sem=("crecv", s * nch + c),
                    )
                )
                ops.append(Wait(("crecv", s * nch + c)))
                ops.append(LocalRead(("ret", s * nch + c)))
                ops.append(LocalWrite(("acc", c)))  # home accumulate
        for c in range(nch):
            if world > 1:  # drain the last return before the final store
                ops.append(Wait(("csend", (world - 1) * nch + c)))
            ops.append(LocalRead(("acc", c)))  # final store
        streams[r] = ops
    return streams


def build_a2a_seam_streams(dispatch: PlanTables, combine: PlanTables) -> Dict[int, list]:
    """Abstract per-rank streams of the fused dispatch -> GEMM -> combine pipe.

    One interleaved pipeline per (rank, step, channel): issue step s+1's
    dispatch exchange, run the grouped expert GEMM on step s's landed tile,
    and return the resulting partial along the reversed edge while the home
    rank accumulates.  The GEMM is made explicit as the read of the landed
    tile feeding the write of the ``part`` staging buffer, so the race pass
    proves the compute is ordered between the two exchanges.
    """
    world, nch = dispatch.world, dispatch.num_channels
    streams = {}
    for r in range(world):
        ops: list = []
        for s in range(world):
            for c in range(nch):
                if s == 0:
                    ops.append(LocalWrite(("x", c)))  # stage own token tile
                if s < world - 1:
                    d = dispatch.a2a_dst[c][s + 1][r]
                    ops.append(
                        DmaStart(
                            src=("x", c),
                            dst_rank=d,
                            dst=("land", (s + 1) * nch + c),
                            send_sem=("dsend", s * nch + c),
                            recv_sem=("drecv", (s + 1) * nch + c),
                        )
                    )
                if s == 0:
                    # local tokens: GEMM reads the own tile, accumulates home
                    ops.append(LocalRead(("x", c)))
                    ops.append(LocalWrite(("acc", c)))
                    continue
                ops.append(Wait(("drecv", s * nch + c)))
                ops.append(LocalRead(("land", s * nch + c)))  # grouped GEMM in
                if s >= 2:  # part buffer reuse: previous return drained
                    ops.append(Wait(("csend", (s - 1) * nch + c)))
                ops.append(LocalWrite(("part", c)))  # grouped GEMM out
                ops.append(
                    DmaStart(
                        src=("part", c),
                        dst_rank=combine.src[c][s][r],
                        dst=("ret", s * nch + c),
                        send_sem=("csend", s * nch + c),
                        recv_sem=("crecv", s * nch + c),
                    )
                )
                ops.append(Wait(("crecv", s * nch + c)))
                ops.append(LocalRead(("ret", s * nch + c)))
                ops.append(LocalWrite(("acc", c)))  # home accumulate
        for c in range(nch):  # drain: dispatch sends + the last return
            for s in range(world - 1):
                ops.append(Wait(("dsend", s * nch + c)))
            if world > 1:
                ops.append(Wait(("csend", (world - 1) * nch + c)))
            ops.append(LocalRead(("acc", c)))  # final store
        streams[r] = ops
    return streams


def _namespace(ops: list, prefix: str) -> list:
    """Prefix every location and semaphore name — per-op resources of a seam."""

    def loc(pair):
        return (prefix + pair[0], pair[1])

    out = []
    for op in ops:
        if isinstance(op, DmaStart):
            out.append(
                dataclasses.replace(
                    op,
                    src=loc(op.src),
                    dst=loc(op.dst),
                    send_sem=loc(op.send_sem),
                    recv_sem=loc(op.recv_sem),
                )
            )
        elif isinstance(op, Wait):
            out.append(Wait(loc(op.sem)))
        elif isinstance(op, LocalRead):
            out.append(LocalRead(loc(op.loc)))
        else:
            out.append(LocalWrite(loc(op.loc)))
    return out


def build_seam_streams(producer: PlanTables, consumer: PlanTables) -> Dict[int, list]:
    """Abstract per-rank streams of a fused RS -> AG seam.

    Per rank: the producer's full rs stream, then the consumer's ag stream,
    with every resource namespaced per op (each op owns its semaphore set and
    buffers).  The seam handoff is made explicit: staging channel c of the
    consumer's own shard *reads the producer's fully reduced accumulator*
    (``op0.acc[c]``) instead of an independent input — so the race pass proves
    the ag gather staging is ordered after the rs reduction completes, through
    the same vector-clock machinery that checks single-op plans.
    """
    rs = _rs_streams(producer)
    ag = _ag_streams(consumer)
    nch = producer.num_channels
    streams = {}
    for r in sorted(rs):
        ops = _namespace(rs[r], "op0.")
        for op in _namespace(ag[r], "op1."):
            if (
                isinstance(op, LocalWrite)
                and op.loc[0] == "op1.gather"
                and op.loc[1] // nch == r
            ):
                # seam handoff: the "own shard" the consumer stages IS the
                # producer's home segment for this channel
                ops.append(LocalRead(("op0.acc", op.loc[1] % nch)))
            ops.append(op)
        streams[r] = ops
    return streams


# ---- happens-before model ---------------------------------------------------
@dataclasses.dataclass
class _Dma:
    idx: int
    rank: int
    op: DmaStart
    start: Optional[Tuple[int, ...]] = None
    send_done: Optional[Tuple[int, ...]] = None
    recv_done: Optional[Tuple[int, ...]] = None


@dataclasses.dataclass
class _Access:
    """One access to a global location: a half-open interval [start, done]."""

    is_write: bool
    rank: int
    descr: str
    _start: Optional[Tuple[int, ...]] = None
    _dma: Optional[_Dma] = None
    _dma_field: str = ""

    def start(self):
        return self._dma.start if self._dma is not None else self._start

    def done(self):
        return getattr(self._dma, self._dma_field) if self._dma is not None else self._start


def _dominates(a, b) -> bool:
    return all(x <= y for x, y in zip(a, b))


def _err(message, *, check, t: PlanTables, **kw):
    raise PlanVerificationError(
        message, check=check, kind=t.kind, order=t.order, world=t.world, **kw
    )


def check_streams(streams: Dict[int, list], t: PlanTables) -> Tuple[int, int]:
    """Model-check abstract streams; returns (assertions, events simulated)."""
    world = t.world
    checks = 0

    # -- matched signal/wait totals per semaphore slot ------------------------
    signals: Counter = Counter()
    waits: Counter = Counter()
    for r, ops in streams.items():
        for op in ops:
            if isinstance(op, DmaStart):
                signals[(r,) + op.send_sem] += 1
                signals[(op.dst_rank,) + op.recv_sem] += 1
            elif isinstance(op, Wait):
                waits[(r,) + op.sem] += 1
    for slot in sorted(set(signals) | set(waits)):
        if signals[slot] != waits[slot]:
            _err(
                f"semaphore slot {slot[1]}[{slot[2]}] gets {signals[slot]} "
                f"signal(s) but {waits[slot]} wait(s)",
                check="sem_count",
                t=t,
                rank=slot[0],
            )
        checks += 1

    # -- happens-before simulation (vector clocks, counting semaphores) -------
    clocks = {r: [0] * world for r in streams}
    pc = {r: 0 for r in streams}
    slot_started: Dict[tuple, List[Tuple[_Dma, str]]] = defaultdict(list)
    slot_consumed: Counter = Counter()
    slot_wait_events: Dict[tuple, List[Tuple[tuple, bool]]] = defaultdict(list)
    accesses: Dict[tuple, List[_Access]] = defaultdict(list)
    dmas: List[_Dma] = []
    events = 0

    def _tick(r, joins=()):
        clk = clocks[r]
        for j in joins:
            for i in range(world):
                clk[i] = max(clk[i], j[i])
        clk[r] += 1
        return tuple(clk)

    progress = True
    while progress:
        progress = False
        for r in sorted(streams):
            ops = streams[r]
            while pc[r] < len(ops):
                op = ops[pc[r]]
                if isinstance(op, Wait):
                    slot = (r,) + op.sem
                    if len(slot_started[slot]) <= slot_consumed[slot]:
                        break  # blocked: no unconsumed signal can fire yet
                    n = slot_consumed[slot]
                    slot_consumed[slot] += 1
                    started = slot_started[slot]
                    resolved = []
                    if len(started) == n + 1:
                        # every signal that could satisfy this wait is
                        # accounted for: all of them fired before it returned
                        resolved = [
                            (d, f)
                            for d, f in started
                            if getattr(d, f + "_done") is None
                        ]
                    ev = _tick(r, joins=[d.start for d, _ in resolved])
                    for d, f in resolved:
                        setattr(d, f + "_done", ev)
                    slot_wait_events[slot].append((ev, bool(resolved)))
                elif isinstance(op, DmaStart):
                    d = _Dma(idx=len(dmas), rank=r, op=op)
                    d.start = _tick(r)
                    dmas.append(d)
                    slot_started[(r,) + op.send_sem].append((d, "send"))
                    slot_started[(op.dst_rank,) + op.recv_sem].append((d, "recv"))
                    accesses[(r,) + op.src].append(
                        _Access(False, r, f"dma read by rank {r}", _dma=d, _dma_field="send_done")
                    )
                    accesses[(op.dst_rank,) + op.dst].append(
                        _Access(True, r, f"dma write from rank {r}", _dma=d, _dma_field="recv_done")
                    )
                else:
                    ev = _tick(r)
                    accesses[(r,) + op.loc].append(
                        _Access(isinstance(op, LocalWrite), r, "local access", _start=ev)
                    )
                pc[r] += 1
                events += 1
                progress = True
    blocked = [r for r in streams if pc[r] < len(streams[r])]
    if blocked:
        r = blocked[0]
        op = streams[r][pc[r]]
        _err(
            f"no rank can advance; rank {r} blocked on semaphore "
            f"{op.sem if isinstance(op, Wait) else op} "
            f"(stuck ranks: {blocked})",
            check="deadlock",
            t=t,
            rank=r,
        )
    checks += 1

    # -- post-check: no wait resolved a signal it could not uniquely claim ----
    for slot, wait_events in slot_wait_events.items():
        started = slot_started[slot]
        for idx, (ev, did_resolve) in enumerate(wait_events):
            if not did_resolve:
                continue
            candidates = sum(
                1 for d, _f in started if not (_dominates(ev, d.start) and ev != d.start)
            )
            if candidates > idx + 1:
                _err(
                    f"semaphore slot {slot[1]}[{slot[2]}] is over-subscribed: "
                    f"wait #{idx + 1} could be satisfied by {candidates} signals",
                    check="ambiguous_wait",
                    t=t,
                    rank=slot[0],
                )
            checks += 1

    # -- data races: every conflicting pair must be HB-ordered ----------------
    def _ordered(a: _Access, b: _Access) -> bool:
        return a.done() is not None and _dominates(a.done(), b.start())

    for gloc in sorted(accesses):
        accs = accesses[gloc]
        loc_name = f"{gloc[1]}[{gloc[2]}] at rank {gloc[0]}"
        for i, a in enumerate(accs):
            if not a.is_write:
                if not any(w.is_write and _ordered(w, a) for w in accs):
                    _err(
                        f"{loc_name} is read ({a.descr}) with no signal "
                        "ordering it after any write",
                        check="read_before_signal",
                        t=t,
                        rank=gloc[0],
                    )
                checks += 1
            for b in accs[i + 1 :]:
                if not (a.is_write or b.is_write):
                    continue
                if _ordered(a, b) or _ordered(b, a):
                    checks += 1
                    continue
                if a.is_write and b.is_write:
                    check = "double_write"
                    msg = f"{loc_name} has two unordered writers ({a.descr} / {b.descr})"
                else:
                    rd, wr = (a, b) if not a.is_write else (b, a)
                    if rd._dma is not None:
                        check = "overwritten_before_wait"
                        msg = (
                            f"{loc_name} is overwritten ({wr.descr}) while an "
                            f"outstanding DMA ({rd.descr}) may still be reading it"
                        )
                    else:
                        check = "read_before_signal"
                        msg = (
                            f"{loc_name} read ({rd.descr}) races with an "
                            f"unordered write ({wr.descr})"
                        )
                _err(msg, check=check, t=t, rank=gloc[0])
    return checks, events


def check_protocol(t: PlanTables) -> Tuple[int, int]:
    """Build the flow's streams from the tables and model-check them."""
    return check_streams(build_streams(t), t)


def check_seam_protocol(producer: PlanTables, consumer: PlanTables) -> Tuple[int, int]:
    """Model-check the combined producer+consumer streams of a fused seam."""
    ctx = dataclasses.replace(
        producer,
        kind=f"{producer.kind}->{consumer.kind}",
        order=f"{producer.order}->{consumer.order}",
    )
    return check_streams(build_seam_streams(producer, consumer), ctx)


def check_a2a_seam_protocol(dispatch: PlanTables, combine: PlanTables) -> Tuple[int, int]:
    """Model-check the fused dispatch -> GEMM -> combine event graph."""
    ctx = dataclasses.replace(
        dispatch,
        kind=f"{dispatch.kind}->{combine.kind}",
        order=f"{dispatch.order}->{combine.order}",
    )
    return check_streams(build_a2a_seam_streams(dispatch, combine), ctx)
