"""Structured diagnostics for the plan-IR static verifier.

This module is the bottom of the analysis layering and must stay import-free
of ``repro.core``: ``core/plan.py`` imports :class:`PlanVerificationError` so
the executor (``ChannelSchedule.flow_perm``) and the tuner's candidate filter
raise the *same* structured diagnosis instead of a bare ``ValueError``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["PlanVerificationError", "VerificationReport"]


class PlanVerificationError(ValueError):
    """A plan (or its baked schedule tables) violates a static invariant.

    Subclasses ``ValueError`` so pre-existing callers that caught the old bare
    errors keep working; carries the failing coordinate so the tuner, the
    executor and the CLI all report the same diagnosis.
    """

    def __init__(
        self,
        message: str,
        *,
        check: str,
        kind: Optional[str] = None,
        order: Optional[str] = None,
        world: Optional[int] = None,
        step: Optional[int] = None,
        rank: Optional[int] = None,
        channel: Optional[int] = None,
        op_index: Optional[int] = None,
    ):
        self.check = check
        self.kind = kind
        self.order = order
        self.world = world
        self.step = step
        self.rank = rank
        self.channel = channel
        # position of the failing op inside a multi-op SeqPlan (None for
        # single-op plans) — lets a seam failure name which half broke
        self.op_index = op_index
        self.raw_message = message
        where = ", ".join(
            f"{name}={val!r}"
            for name, val in (
                ("kind", kind),
                ("order", order),
                ("world", world),
                ("channel", channel),
                ("step", step),
                ("rank", rank),
                ("op_index", op_index),
            )
            if val is not None
        )
        super().__init__(f"[{check}] {message}" + (f" ({where})" if where else ""))

    def with_op_index(self, op_index: int) -> "PlanVerificationError":
        """Re-raise helper: same diagnosis, tagged with its sequence position."""
        return PlanVerificationError(
            self.raw_message,
            check=self.check,
            kind=self.kind,
            order=self.order,
            world=self.world,
            step=self.step,
            rank=self.rank,
            channel=self.channel,
            op_index=op_index,
        )


@dataclasses.dataclass(frozen=True)
class VerificationReport:
    """What the verifier proved about one plan.

    ``effective_channels`` is the channel count the verified tables actually
    use — when ``mapping.effective_channels`` clamped a request to the largest
    divisor of the extent, ``requested_channels`` records the original ask so
    tune-cache records and verifier output cannot silently disagree.
    """

    kind: str
    order: str
    world: int
    flow: str
    effective_channels: int
    requested_channels: Optional[int] = None
    passes: Tuple[str, ...] = ()
    checks: int = 0  # individual assertions evaluated
    events: int = 0  # protocol events simulated (0 if the pass did not run)

    @property
    def clamped(self) -> bool:
        return (
            self.requested_channels is not None
            and self.requested_channels != self.effective_channels
        )

    def summary(self) -> str:
        ch = str(self.effective_channels)
        if self.clamped:
            ch += f" (requested {self.requested_channels})"
        return (
            f"{self.kind:<13} {self.order:<10} world={self.world:<3} C={ch:<18} "
            f"passes={'+'.join(self.passes)} checks={self.checks} events={self.events}"
        )
