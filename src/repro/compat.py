"""Legacy compatibility surface — thin re-export of ``repro.backend``.

Historically this module held the JAX version shims; they now live in the
``repro.backend`` package (single point of version adaptation).  Kept so
existing imports (``from repro.compat import shard_map, make_mesh``) keep
working; new code should import ``repro.backend`` directly.
"""
from __future__ import annotations

import jax

from repro.backend import make_mesh, shard_map  # noqa: F401

__all__ = ["shard_map", "make_mesh", "tree_map", "tree_leaves",
           "tree_flatten", "tree_unflatten"]

tree_map = jax.tree_util.tree_map
tree_leaves = jax.tree_util.tree_leaves
tree_flatten = jax.tree_util.tree_flatten
tree_unflatten = jax.tree_util.tree_unflatten
