"""Compatibility shims for JAX API drift (0.6 -> 0.8).

Centralizes every version-sensitive import so the rest of the codebase
targets a single stable surface.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "make_mesh", "tree_map", "tree_leaves", "tree_flatten", "tree_unflatten"]


def shard_map(f, mesh, in_specs, out_specs, check_rep: bool = False,
              axis_names=None):
    """Version-stable shard_map wrapper (check_rep/check_vma naming drift).

    ``axis_names``: when given, a partial-auto shard_map — only those mesh axes
    are manual; the rest stay under the automatic partitioner.
    """
    try:
        # jax >= 0.7 public API
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = frozenset(axis_names)
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_rep, **kw,
        )
    except (AttributeError, TypeError):
        from jax.experimental.shard_map import shard_map as _sm

        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_rep)


def make_mesh(shape, axis_names):
    """Mesh constructor pinned to Auto axis types (we use in_shardings/constraints)."""
    try:
        return jax.make_mesh(
            shape, axis_names, axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names)
        )
    except TypeError:
        return jax.make_mesh(shape, axis_names)


tree_map = jax.tree_util.tree_map
tree_leaves = jax.tree_util.tree_leaves
tree_flatten = jax.tree_util.tree_flatten
tree_unflatten = jax.tree_util.tree_unflatten
