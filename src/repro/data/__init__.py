from repro.data.pipeline import SyntheticLM, MemmapTokens, make_pipeline

__all__ = ["SyntheticLM", "MemmapTokens", "make_pipeline"]
