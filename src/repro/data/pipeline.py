"""Deterministic, elastically-resumable data pipelines.

Both pipelines index samples by a pure function of (cursor, host shard), so:
  * resume from checkpoint = restore the integer cursor (exactly-once);
  * elastic remesh = recompute host shards from the same cursor — no sample is
    duplicated or dropped when the host set changes (the cursor is global).

``SyntheticLM`` generates a learnable in-memory corpus (token t+1 depends on
token t via a fixed random bigram table) so loss-decrease tests are meaningful.
``MemmapTokens`` streams a flat token file (np.memmap) — the production path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

__all__ = ["SyntheticLM", "MemmapTokens", "make_pipeline"]


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    cursor: int = 0  # global step cursor (checkpointed)
    n_hosts: int = 1
    host_id: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab_size, 4096)
        self._v = v
        # sparse bigram transition table -> predictable structure
        self._table = rng.integers(0, v, size=(v, 4), dtype=np.int32)

    def _sample(self, idx: int) -> np.ndarray:
        rng = np.random.default_rng(hash((self.seed, idx)) % (2 ** 63))
        toks = np.empty(self.seq_len + 1, np.int32)
        toks[0] = rng.integers(0, self._v)
        choices = rng.integers(0, 4, size=self.seq_len)
        for t in range(self.seq_len):
            toks[t + 1] = self._table[toks[t], choices[t]]
        return toks

    def host_batch(self) -> Dict[str, np.ndarray]:
        """This host's shard of the next global batch; advances the cursor."""
        per_host = self.global_batch // self.n_hosts
        base = self.cursor * self.global_batch + self.host_id * per_host
        seqs = np.stack([self._sample(base + i) for i in range(per_host)])
        self.cursor += 1
        return {"inputs": seqs[:, :-1], "labels": seqs[:, 1:]}

    def state(self) -> dict:
        return {"cursor": self.cursor, "seed": self.seed}

    def restore(self, state: dict):
        self.cursor = int(state["cursor"])


@dataclasses.dataclass
class MemmapTokens:
    """Flat uint16/uint32 token file, deterministic strided sampling."""
    path: str
    seq_len: int
    global_batch: int
    dtype: str = "uint16"
    cursor: int = 0
    n_hosts: int = 1
    host_id: int = 0

    def __post_init__(self):
        self._mm = np.memmap(self.path, dtype=self.dtype, mode="r")
        self._n_seqs = (len(self._mm) - 1) // self.seq_len

    def host_batch(self) -> Dict[str, np.ndarray]:
        per_host = self.global_batch // self.n_hosts
        base = self.cursor * self.global_batch + self.host_id * per_host
        out_i = np.empty((per_host, self.seq_len), np.int32)
        out_l = np.empty((per_host, self.seq_len), np.int32)
        for i in range(per_host):
            s = ((base + i) % self._n_seqs) * self.seq_len
            chunk = np.asarray(self._mm[s: s + self.seq_len + 1], np.int32)
            out_i[i] = chunk[:-1]
            out_l[i] = chunk[1:]
        self.cursor += 1
        return {"inputs": out_i, "labels": out_l}

    def state(self) -> dict:
        return {"cursor": self.cursor, "path": self.path}

    def restore(self, state: dict):
        self.cursor = int(state["cursor"])


def make_pipeline(kind: str, **kw):
    if kind == "synthetic":
        return SyntheticLM(**kw)
    if kind == "memmap":
        return MemmapTokens(**kw)
    raise ValueError(kind)
