"""Request-level admission control for the continuous-batching engine.

A :class:`Request` is the public unit of work; the :class:`Scheduler` seats
queued requests into a fixed pool of batch slots FIFO as slots free up, and
tracks per-request host state (prompt cursor, generated tokens, cache length)
between ``engine.step()`` calls.  All device state lives in
``serving.cache.SlotPool`` — the scheduler is pure host bookkeeping.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

import numpy as np

__all__ = ["Request", "RequestState", "Scheduler"]


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request: prompt tokens, budget, sampling knobs.

    ``max_new_tokens`` is exact: the engine emits exactly that many tokens
    unless ``eos_id`` is sampled first (the eos token is included in the
    output).  ``top_k == 0`` disables truncation; ``temperature <= 0`` is
    greedy.  ``seed`` gives per-request reproducible sampling independent of
    which other requests share the batch.
    """

    tokens: Sequence[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    top_k: int = 0
    eos_id: Optional[int] = None
    seed: int = 0


@dataclasses.dataclass
class RequestState:
    rid: int
    request: Request
    prompt: np.ndarray  # int32 [len]
    pos: int = 0  # prompt tokens already fed through the model
    cache_len: int = 0  # tokens whose KV/state is resident in the slot
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False

    @property
    def remaining(self) -> int:
        return self.request.max_new_tokens - len(self.generated)

    @property
    def pending(self) -> Optional[int]:
        """Last sampled token whose KV is not yet in the cache."""
        if self.pos < len(self.prompt) or not self.generated:
            return None
        return self.generated[-1]


class Scheduler:
    """FIFO admission over a fixed slot pool."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.queue: Deque[int] = deque()
        self.states: Dict[int, RequestState] = {}
        self.slots: List[Optional[int]] = [None] * n_slots
        self._next_rid = 0

    def submit(self, req: Request) -> int:
        rid = self._next_rid
        self._next_rid += 1
        prompt = np.asarray(req.tokens, np.int32).reshape(-1)
        self.states[rid] = RequestState(rid, req, prompt)
        self.queue.append(rid)
        return rid

    def admit(self) -> List[int]:
        """Seat queued requests into free slots; returns the slots seated."""
        seated = []
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                rid = self.queue.popleft()
                self.states[rid].slot = i
                self.slots[i] = rid
                seated.append(i)
        return seated

    def release(self, slot: int) -> None:
        rid = self.slots[slot]
        if rid is not None:
            self.states[rid].slot = None
        self.slots[slot] = None

    def active(self):
        """(slot, state) pairs currently seated, slot order."""
        for i, rid in enumerate(self.slots):
            if rid is not None:
                yield i, self.states[rid]

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)
