"""Batched serving engine: prefill-into-cache + jit'd decode loop.

Continuous-batching-lite: requests are padded into a fixed batch; prefill fills
the KV/SSM caches in one forward pass (TileLink-overlapped projections), then a
single jit'd ``decode_step`` advances all sequences one token per call.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm

__all__ = ["ServeEngine"]


@dataclasses.dataclass
class ServeEngine:
    cfg: object
    pc: object
    params: object
    max_len: int = 512
    temperature: float = 0.0  # greedy by default

    def __post_init__(self):
        cfg, pc = self.cfg, self.pc
        self._prefill = jax.jit(
            lambda p, t: lm.prefill(p, cfg, pc, t, max_len=self.max_len))
        self._decode = jax.jit(
            lambda p, c, t, n: lm.decode_step(p, c, cfg, pc, t, n))

    def _sample(self, logits, key):
        if self.temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits[:, -1].astype(jnp.float32) / self.temperature
        ).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32,
                 seed: int = 0) -> np.ndarray:
        """prompts: [B, S0] int32 (already padded). Returns [B, S0+new]."""
        b, s0 = prompts.shape
        assert s0 + max_new_tokens <= self.max_len
        logits, caches = self._prefill(self.params, jnp.asarray(prompts))
        key = jax.random.PRNGKey(seed)
        tok = self._sample(logits, key)
        out = [prompts, np.asarray(tok)[:, None]]
        for i in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            logits, caches = self._decode(self.params, caches, tok[:, None],
                                          s0 + i)
            tok = self._sample(logits, sub)
            out.append(np.asarray(tok)[:, None])
        return np.concatenate(out, axis=1)
