"""Continuous-batching serving engine: a request-level API over one jit'd step.

``submit(Request) -> handle`` queues work; the scheduler seats requests into
a fixed slot pool (`serving.cache.SlotPool`) as slots free up.  ``step()``
advances every admitted sequence one iteration:

  * chunked prefill and decode interleave in the SAME forward — one
    ``lm.decode_step`` call where prefilling slots carry up to
    ``prefill_chunk`` prompt tokens and decoding slots carry their one
    pending token, masked per slot by length + validity;
  * then a ``lax.while_loop`` decode body samples ON DEVICE (greedy /
    temperature / top-k, per-slot knobs) for up to ``decode_block`` tokens,
    writing into a device token buffer — no per-token host round-trip;
  * the host syncs exactly once per step (``jax.device_get`` of the token
    buffer), asserted by ``stats["host_syncs"] == stats["steps"]``.

``poll(handle)`` reads a request's progress, ``step()``'s return value is
the streaming surface ({handle: new tokens}), and ``drain()`` runs steps to
completion.  ``generate(prompts, max_new_tokens)`` keeps the legacy
padded-batch convenience surface on top.

Sampling is reproducible per request: each slot's key is
``fold_in(PRNGKey(request.seed), n_sampled)``, so results don't depend on
which other requests share the batch or on step boundaries.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.serving.cache import SlotPool
from repro.serving.scheduler import Request, Scheduler

__all__ = ["ServeEngine", "Request"]

_TOPK_MAX = 64  # static width of the top-k threshold lattice (clamped to V)


def _sample(logits, temp, topk, keys):
    """Per-slot on-device sampling. logits [S, V] f32; temp/topk/keys [S...]."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    kmax = min(logits.shape[-1], _TOPK_MAX)
    vals = jax.lax.top_k(logits, kmax)[0]  # [S, kmax] sorted desc
    kidx = jnp.clip(topk - 1, 0, kmax - 1)
    thresh = jnp.take_along_axis(vals, kidx[:, None], axis=-1)
    masked = jnp.where((topk > 0)[:, None] & (logits < thresh), -jnp.inf, logits)
    scaled = masked / jnp.maximum(temp, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled)
    return jnp.where(temp > 0, sampled.astype(jnp.int32), greedy)


@dataclasses.dataclass
class ServeEngine:
    """Request-level continuous-batching engine over ``lm.decode_step``."""

    cfg: object
    pc: object
    params: object
    max_len: int = 512
    temperature: float = 0.0  # default for the generate() convenience path
    n_slots: int = 8
    prefill_chunk: int = 16
    decode_block: int = 32
    cache_dtype: object = None

    def __post_init__(self):
        cfg, pc = self.cfg, self.pc
        if self.cache_dtype is None:
            self.cache_dtype = self.params["embed"].dtype
        # ring-buffer (sliding window) layers cap the prefill chunk: a chunk
        # wider than the ring would overwrite rows its own queries still need
        rings = [min(self.max_len, d.window)
                 for d in _all_layer_defs(cfg) if d.window is not None]
        self.prefill_chunk = max(1, min([self.prefill_chunk] + rings))
        self.scheduler = Scheduler(self.n_slots)
        self.pool = SlotPool(cfg, pc, self.n_slots, self.max_len,
                             self.cache_dtype)
        self.stats = {"steps": 0, "host_syncs": 0, "step_traces": 0,
                      "resets": 0}
        donate = () if jax.default_backend() == "cpu" else (1,)
        self._step_fn = jax.jit(self._build_step(), donate_argnums=donate)
        self.decode_channels = self._warm_decode_channels() if pc.tune else {}

    # ------------------------------------------------------------------ jit'd
    def _build_step(self):
        cfg, pc = self.cfg, self.pc
        dmax = self.decode_block

        def step_fn(params, caches, lens, tokens, valid, active, budget,
                    eos, temp, topk, seeds, n_sampled, n_decode):
            self.stats["step_traces"] += 1
            n = tokens.shape[0]
            # mixed forward: prefill chunks + pending decode tokens together
            logits, caches = lm.decode_step(params, caches, cfg, pc, tokens,
                                            lens, q_valid=valid)
            lens = lens + valid
            idx = jnp.clip(valid - 1, 0, tokens.shape[1] - 1)
            last = jnp.take_along_axis(
                logits, idx[:, None, None], axis=1)[:, 0].astype(jnp.float32)
            keys = jax.vmap(jax.random.PRNGKey)(seeds)
            sub = jax.vmap(jax.random.fold_in)(keys, n_sampled)
            tok0 = _sample(last, temp, topk, sub)
            alive = active & (budget > 0)
            n_sampled = n_sampled + alive.astype(jnp.int32)
            buf = jnp.full((n, dmax), -1, jnp.int32)
            buf = buf.at[:, 0].set(jnp.where(alive, tok0, -1))
            emitted = alive.astype(jnp.int32)
            alive = alive & (tok0 != eos) & (budget > 1)

            def cond(st):
                return (st[0] < n_decode) & jnp.any(st[4])

            def body(st):
                t, caches_, lens_, tok, alive_, buf_, em_, ns_ = st
                lg, caches_ = lm.decode_step(
                    params, caches_, cfg, pc, tok[:, None], lens_,
                    q_valid=alive_.astype(jnp.int32))
                lens_ = lens_ + alive_.astype(jnp.int32)
                sub_ = jax.vmap(jax.random.fold_in)(keys, ns_)
                nt = _sample(lg[:, 0].astype(jnp.float32), temp, topk, sub_)
                ns_ = ns_ + alive_.astype(jnp.int32)
                buf_ = buf_.at[:, t].set(jnp.where(alive_, nt, -1),
                                         mode="drop")
                em_ = em_ + alive_.astype(jnp.int32)
                alive_ = alive_ & (nt != eos) & (em_ < budget)
                return (t + 1, caches_, lens_, nt, alive_, buf_, em_, ns_)

            st = (jnp.int32(1), caches, lens, tok0, alive, buf, emitted,
                  n_sampled)
            st = jax.lax.while_loop(cond, body, st)
            return st[1], st[5], st[6]

        return step_fn

    # ------------------------------------------------------------------ host
    def submit(self, req: Request) -> int:
        """Queue a request; returns a handle for poll()/drain()."""
        n_prompt = int(np.asarray(req.tokens).reshape(-1).shape[0])
        if n_prompt == 0:
            raise ValueError("empty prompt")
        if n_prompt + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({n_prompt}) + max_new_tokens ({req.max_new_tokens}) "
                f"exceeds the engine max_len ({self.max_len})")
        return self.scheduler.submit(req)

    def _admit(self) -> None:
        for slot in self.scheduler.admit():
            self.pool.reset(slot)
            self.stats["resets"] += 1

    def _fetch(self, tree):
        self.stats["host_syncs"] += 1
        return jax.device_get(tree)

    def step(self) -> Dict[int, List[int]]:
        """Advance every admitted sequence one iteration.

        Returns {handle: tokens emitted this step} — the streaming surface.
        Exactly one host sync regardless of how many tokens were decoded.
        """
        self._admit()
        sch = self.scheduler
        if not any(r is not None for r in sch.slots):
            return {}
        n, c = self.n_slots, self.prefill_chunk
        tokens = np.zeros((n, c), np.int32)
        valid = np.zeros((n,), np.int32)
        active = np.zeros((n,), bool)
        budget = np.zeros((n,), np.int32)
        eos = np.full((n,), -1, np.int32)
        temp = np.zeros((n,), np.float32)
        topk = np.zeros((n,), np.int32)
        seeds = np.zeros((n,), np.int32)
        nsamp = np.zeros((n,), np.int32)
        lens = np.zeros((n,), np.int32)
        for i, st in sch.active():
            req = st.request
            lens[i] = st.cache_len
            budget[i] = st.remaining
            eos[i] = -1 if req.eos_id is None else req.eos_id
            temp[i] = req.temperature
            topk[i] = req.top_k
            seeds[i] = req.seed
            nsamp[i] = len(st.generated)
            if st.pos < len(st.prompt):
                take = min(c, len(st.prompt) - st.pos)
                tokens[i, :take] = st.prompt[st.pos:st.pos + take]
                valid[i] = take
                st.pos += take
                active[i] = st.pos == len(st.prompt)
            else:
                tokens[i, 0] = st.pending
                valid[i] = 1
                active[i] = True
        n_decode = int(min(self.decode_block,
                           max([0] + [int(budget[i]) for i, _ in sch.active()
                                      if active[i]])))

        out = self._step_fn(self.params, self.pool.caches, jnp.asarray(lens),
                            jnp.asarray(tokens), jnp.asarray(valid),
                            jnp.asarray(active), jnp.asarray(budget),
                            jnp.asarray(eos), jnp.asarray(temp),
                            jnp.asarray(topk), jnp.asarray(seeds),
                            jnp.asarray(nsamp), jnp.int32(n_decode))
        self.pool.caches = out[0]
        buf, emitted = self._fetch(out[1:])
        self.stats["steps"] += 1

        results: Dict[int, List[int]] = {}
        finished = []
        for i, st in sch.active():
            e = int(emitted[i])
            st.cache_len += int(valid[i]) + max(0, e - 1)
            if e:
                toks = buf[i, :e].tolist()
                st.generated.extend(toks)
                results[st.rid] = toks
                hit_eos = (st.request.eos_id is not None
                           and toks[-1] == st.request.eos_id)
                if hit_eos or st.remaining <= 0:
                    st.done = True
                    finished.append(i)
        for i in finished:
            sch.release(i)
        return results

    def poll(self, handle: int) -> Dict[str, object]:
        """Progress of one request: done flag, tokens so far, queue state."""
        st = self.scheduler.states[handle]
        return {"done": st.done, "tokens": list(st.generated),
                "queued": st.slot is None and not st.done}

    def drain(self, handles=None, max_steps: int = 100_000):
        """Run step() until the given (default: all) requests finish."""
        if handles is None:
            handles = list(self.scheduler.states)
        for _ in range(max_steps):
            if all(self.scheduler.states[h].done for h in handles):
                break
            if not self.scheduler.has_work:
                break
            self.step()
        return {h: np.asarray(self.scheduler.states[h].generated, np.int32)
                for h in handles}

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32,
                 seed: int = 0) -> np.ndarray:
        """Legacy convenience surface: prompts [B, S0] (already padded, pads
        attend as real tokens exactly like the old fixed-batch engine);
        returns [B, S0 + max_new_tokens] with exactly ``max_new_tokens`` new
        tokens per row."""
        prompts = np.asarray(prompts, np.int32)
        _, s0 = prompts.shape
        if s0 + max_new_tokens > self.max_len:
            raise ValueError("prompt + max_new_tokens exceeds max_len")
        handles = [
            self.submit(Request(tokens=row, max_new_tokens=max_new_tokens,
                                temperature=self.temperature, seed=seed + i))
            for i, row in enumerate(prompts)
        ]
        outs = self.drain(handles)
        gen = np.stack([outs[h] for h in handles])
        return np.concatenate([prompts, gen], axis=1)

    # ------------------------------------------------------- decode tuning
    def _warm_decode_channels(self):
        """Resolve decode-shape joint winners for this engine's TP GEMMs.

        Decode GEMMs (M == n_slots rows, 1 token) live in a different corner
        of the joint space than prefill shapes; ``signature(..., decode=True)``
        keys them separately so the cache holds both winners side by side.
        """
        from repro import tune
        from repro.nn.attention import _lay

        cfg, pc = self.cfg, self.pc
        lay = _lay(cfg, pc.tp)
        hd, d = cfg.hd, cfg.d_model
        s = self.n_slots
        gemms = {
            "qkv": ("ag_matmul",
                    ((s, 1, d), (d, (lay.h_loc + 2 * lay.kv_loc) * hd))),
            "attn_out": ("matmul_rs",
                         ((s, 1, lay.h_loc * hd), (lay.h_loc * hd, d))),
        }
        if cfg.d_ff:
            f_loc = max(1, cfg.d_ff // pc.tp)
            gemms["ffn_gu"] = ("ag_matmul", ((s, 1, d), (d, 2 * f_loc)))
            gemms["ffn_down"] = ("matmul_rs", ((s, 1, f_loc), (f_loc, d)))
        return {
            name: tune.resolve_channel(
                kind, sig=tune.signature(kind, shapes, decode=True),
                mesh=pc.mesh, axis=pc.axis, ranker=pc.tune_ranker,
                space=tune.JOINT_SPACE)
            for name, (kind, shapes) in gemms.items()
        }


def _all_layer_defs(cfg):
    prefix, unit, n_units, suffix = lm.layer_plan(cfg)
    return list(prefix) + (list(unit) if n_units else []) + list(suffix)
