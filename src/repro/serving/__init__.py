from repro.serving.cache import SlotPool
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import Request, Scheduler

__all__ = ["ServeEngine", "Request", "Scheduler", "SlotPool"]
