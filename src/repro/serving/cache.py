"""Slot-pool KV/SSM cache management for continuous batching.

A fixed pool of ``n_slots`` batch rows over ``lm.init_caches``: each admitted
request owns one row, its per-slot length masks every attention read, and
evicting a finished sequence is just re-seating the slot.  ``reset(slot)``
zeroes the row's cache/state — mandatory for the recurrent mamba SSM/conv
state (a stale recurrence would silently poison the next occupant; attention
rows are already excluded by the length masks, so zeroing them is hygiene).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lm

__all__ = ["SlotPool"]


def _zero_slot(caches, slot):
    """Zero one slot's rows across the whole cache tree.

    Prefix/suffix layer caches carry the slot on axis 0; scan (stacked unit)
    caches carry ``n_units`` first and the slot on axis 1.
    """

    def zero(axis):
        def f(leaf):
            idx = (slice(None),) * axis + (slot,)
            return leaf.at[idx].set(jnp.zeros((), leaf.dtype))

        return f

    out = {
        "prefix": [jax.tree_util.tree_map(zero(0), c) for c in caches["prefix"]],
        "suffix": [jax.tree_util.tree_map(zero(0), c) for c in caches["suffix"]],
    }
    if "scan" in caches:
        out["scan"] = [jax.tree_util.tree_map(zero(1), c) for c in caches["scan"]]
    return out


class SlotPool:
    """Device-resident cache pool; the engine threads ``caches`` through its
    jit'd step and writes the result back here."""

    def __init__(self, cfg, pc, n_slots: int, max_len: int, dtype=jnp.bfloat16):
        self.n_slots = n_slots
        self.max_len = max_len
        self.caches = lm.init_caches(cfg, pc, n_slots, max_len, dtype)
        # donation keeps the pool at one cache's footprint on real devices;
        # CPU has no donation support and would only log noise
        donate = () if jax.default_backend() == "cpu" else (0,)
        self._reset = jax.jit(_zero_slot, donate_argnums=donate)

    def reset(self, slot: int) -> None:
        """Evict whatever occupied ``slot``: zero its cache/state rows.

        Device-side only — enqueues one small jit'd update, no host sync.
        """
        self.caches = self._reset(self.caches, jnp.int32(slot))
