"""Async, atomic, mesh-agnostic checkpointing.

Layout: <dir>/step_<N>/{manifest.json, arrays.npz}.  Writes go to a tmp dir
renamed into place (atomic on POSIX) from a background thread (training is
never blocked on I/O).  Arrays are saved logically (full, host-gathered), so a
checkpoint restores onto *any* mesh/chip count — elastic scaling across
restarts.  Retention keeps the newest K checkpoints.

At true 1000-node scale the arrays.npz payload would be per-host sharded
(OCDBT-style); the manager's interface (save/restore/latest/wait) is what the
runtime depends on and is unchanged by that swap.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


class CheckpointManager:

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---- save -----------------------------------------------------------------
    def save(self, step: int, params, opt_state, extra: Optional[Dict[str, Any]] = None):
        """Snapshot (device->host copy happens synchronously; I/O is async)."""
        tree = {"params": params, "opt": opt_state}
        flat, treedef = _flatten(tree)
        host = [np.asarray(x) for x in flat]  # sync: consistent snapshot
        meta = {
            "step": int(step),
            "extra": extra or {},
            "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
            if hasattr(jax.tree_util.tree_structure(tree), "serialize_using_proto")
            else None,
        }

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}_{os.getpid()}")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{f"a{i}": a for i, a in enumerate(host)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._retain()

        self.wait()
        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---- restore ----------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, mesh=None, spec_tree=None):
        """Restore into the structure of ``like``; optionally re-place on a
        (possibly different) mesh — elastic restarts."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat_like, treedef = _flatten(like)
        flat = [data[f"a{i}"] for i in range(len(flat_like))]
        flat = [np.asarray(a, dtype=like_leaf.dtype)
                for a, like_leaf in zip(flat, flat_like)]
        tree = treedef.unflatten(flat)
        if mesh is not None and spec_tree is not None:
            from repro.parallel.sharding import place

            tree = place(tree, mesh, spec_tree)
        return tree, meta
