"""ParallelContext — distribution configuration threaded through the model.

Carries the mesh, axis roles, the overlap mode, and the ``BlockChannel``
describing the communication/computation design point:

  mode="overlap"   TileLink tile plans run by the generic schedule executor
                   (compile_overlap -> core/plan -> core/overlap.run_plan)
  mode="baseline"  operator-centric AG/RS collectives — the non-overlap baseline
  (both run inside partial-auto shard_map, manual over the TP axis only;
   FSDP/DP axes stay under XLA's automatic partitioner)

Every per-shard collective op lowers through ``compile_overlap`` with
``pc.channel``, so the whole ``CommSpec x CompSpec x QuantSpec`` space (tile
order, channel count, accum dtype, wire encoding) is selected once here and
honored by every layer (`nn/attention.py`, `nn/ffn.py`, `nn/moe.py`,
`nn/mamba.py`).  ``quant=`` pins a :class:`QuantSpec` on every op (wire
dtype split from the accum dtype), or ``quant="auto"`` opens the int8 wire
axis to the tuner.

With ``tune=True`` the design point is not fixed: each op resolves the best
``BlockChannel`` for its own operand shapes through the ``repro.tune``
autotuner over the JOINT space — the comm half (order, C, accum dtype, and
under ``quant="auto"`` the wire dtype) and
the compute half (the (tm, tn, tk) consumer tile) together (persistent
per-mesh cache; trace-safe cost-model ranking, or measured winners wherever
the cache was pre-warmed with ``repro.tune.autotune(..., ranker="measure")``).
Non-tuned fields of ``pc.channel`` (comm resource/mode) are inherited by
every winner.

Layers call ``pc.ag_matmul`` / ``pc.matmul_rs`` / ``pc.psum`` on *per-shard*
values while inside a manual region entered via ``pc.smap``.  With
``fuse_seams=True`` the model stack additionally fuses each layer's
down-projection RS into the next consumer's AG over ONE shared ring pass
(``pc.matmul_rs_ag`` -> ``compile_overlap`` seq form), eliminating the exposed
collective at the inter-op seam.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.channels import BlockChannel
from repro.core.compiler import compile_overlap
from repro.core.quant import QuantSpec

__all__ = ["ParallelContext", "manual_only"]


def manual_only(spec: P, manual_axes: Tuple[str, ...]) -> P:
    """Strip a full PartitionSpec down to its manual-axis entries.

    P(('pod','data'), 'model') with manual=('model',) -> P(None, 'model').
    Used to derive shard_map in_specs from the global sharding table.
    """
    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in manual_axes)
            return kept[0] if len(kept) == 1 else (kept if kept else None)
        return entry if entry in manual_axes else None

    return P(*(keep(e) for e in spec))


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    mesh: Any  # jax Mesh
    axis: str = "model"  # TP / SP / EP axis
    dp_axes: Tuple[str, ...] = ("pod", "data")
    mode: str = "overlap"  # "overlap" | "baseline"
    channel: BlockChannel = None
    seq_shard: bool = True  # sequence-parallel residual stream
    attn_p_bf16: bool = False  # cast softmax P to bf16 before P@V
                                            # (halves attention HBM traffic)
    moe_decode_stream: bool = False  # stream local experts once over all
                                            # tokens in decode (bytes-optimal)
    tune: bool = False  # autotune each op's BlockChannel
                                            # per (kind, shape) via repro.tune
    tune_ranker: Optional[str] = None  # "measure" | "model" | "auto"/None
    fuse_seams: bool = False  # fuse layer RS->AG seams into one ring
                                            # pass (compile_overlap seq form)
    ep_axis: Optional[str] = None  # expert-parallel opt-in: mesh axis the
                                            # MoE dispatch/combine a2a runs
                                            # over (usually == axis)
    quant: Any = None  # wire-dtype policy: None (inherit channel),
                                            # a QuantSpec (pin every op's wire
                                            # encoding), or "auto"/True (open
                                            # the flow axis under tune=True)

    def __post_init__(self):
        if self.quant is True:
            object.__setattr__(self, "quant", "auto")
        if not (self.quant is None or self.quant == "auto"
                or isinstance(self.quant, QuantSpec)):
            raise ValueError(
                f"quant must be None, a QuantSpec, or 'auto'/True; "
                f"got {self.quant!r}")
        if self.ep_axis is not None and self.ep_axis not in dict(self.mesh.shape):
            raise ValueError(
                f"ep_axis {self.ep_axis!r} is not a mesh axis "
                f"(mesh axes: {tuple(dict(self.mesh.shape))})")
        if self.channel is None:
            object.__setattr__(self, "channel", BlockChannel(axis=self.axis))
        elif self.channel.axis != self.axis:
            # ops lower through compile_overlap(channel), which binds the
            # collective axis from the channel — a mismatch would run every
            # permute over a different axis than the manual region
            raise ValueError(
                f"BlockChannel.axis {self.channel.axis!r} != "
                f"ParallelContext.axis {self.axis!r}")
        if isinstance(self.quant, QuantSpec) and self.channel.quant != self.quant:
            # bake the pinned spec into the channel once: every op (tuned or
            # not) inherits the wire encoding from pc.channel from here on
            object.__setattr__(
                self, "channel", self.channel.with_(quant=self.quant))

    # ---- static topology -----------------------------------------------------
    @property
    def tp(self) -> int:
        return self.mesh.shape[self.axis]

    @property
    def dp(self) -> int:
        n = 1
        for a in self.dp_axes:
            if a in self.mesh.shape:
                n *= self.mesh.shape[a]
        return n

    def dp_spec(self):
        present = tuple(a for a in self.dp_axes if a in self.mesh.shape)
        return present if len(present) > 1 else (present[0] if present else None)

    # ---- ZeRO-3 use-time gather -------------------------------------------------
    def use_gather(self, tree, spec_tree):
        """Constrain parameters to drop DP/FSDP-axis sharding at use time.

        Storage keeps params sharded over (dp x model); this constraint makes
        XLA all-gather each layer's weights over the dp axes right before use
        (ZeRO-3), instead of contraction-partitioning the matmuls over dp
        (which would all-reduce activations — far more bytes).  The transpose
        of the gather reduce-scatters the gradients back to dp shards.
        """
        def one(a, s):
            return jax.lax.with_sharding_constraint(
                a, jax.sharding.NamedSharding(
                    self.mesh, manual_only(s, (self.axis,))))

        return jax.tree_util.tree_map(
            one, tree, spec_tree, is_leaf=lambda v: isinstance(v, P))

    # ---- manual-region entry ---------------------------------------------------
    def smap(self, fn: Callable, in_specs, out_specs) -> Callable:
        """Partial-auto shard_map, manual over the TP axis only."""
        return compat.shard_map(
            fn, self.mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False, axis_names={self.axis},
        )

    def manual(self, spec: P) -> P:
        return manual_only(spec, (self.axis,))

    # ---- per-shard collective ops (call inside smap) ---------------------------
    # every op lowers kind -> plan -> executor through the frontend; the plan
    # cache makes repeated layer calls reuse one schedule per design point
    def _tune_space(self):
        """The JOINT space, widened with the int8 wire axis under quant='auto'."""
        from repro.tune import JOINT_SPACE

        if self.quant == "auto":
            return dataclasses.replace(JOINT_SPACE, flows=(None, "int8"))
        return JOINT_SPACE

    def _op(self, kind: str, shapes: Tuple = ()) -> Callable:
        channel = self.channel
        if self.tune and self.mode == "overlap" and shapes:
            from repro.tune import resolve_channel

            # host-side: tuning-cache lookup / cost-model ranking (trace-safe);
            # the JOINT space searches both halves — comm (order, C, wire
            # dtype under quant="auto") and compute ((tm, tn, tk) consumer
            # tile) — per op shape
            channel = resolve_channel(
                kind, shapes=shapes, mesh=self.mesh, axis=self.axis,
                base=self.channel, ranker=self.tune_ranker,
                space=self._tune_space())
        return compile_overlap(kind, channel, backend="xla",
                               overlapped=(self.mode == "overlap"))

    def ag_matmul(self, x, w, **kw):
        return self._op("ag_matmul", (jnp.shape(x), jnp.shape(w)))(x, w, **kw)

    def matmul_rs_ag(self, x, w1, w2, *, residual=None, glue=None, **kw):
        """Fused layer seam: matmul_rs(x, w1) -> ag_matmul(glue(residual + .), w2).

        One shared ring pass; each RS segment lands on its home rank and feeds
        the consumer's per-tile compute directly (no exposed collective at the
        seam).  With ``tune=True`` the seam-aware tuner prices fused vs.
        unfused per shape; a schedule-incompatible seam degrades loudly to the
        unfused pair via one SeamFallbackWarning.  Returns ``(y, out)`` where
        ``y = residual + rs_out`` (pre-glue, for the residual stream) and
        ``out`` is the consumer's AG-matmul output.
        """
        ops = ["matmul_rs", "ag_matmul"]
        if self.tune and self.mode == "overlap":
            fn = compile_overlap(
                ops, channel="auto", axis=self.axis, mesh=self.mesh,
                tune_ranker=self.tune_ranker, tune_base=self.channel,
                tune_space=self._tune_space())
        else:
            fn = compile_overlap(
                ops, channel=self.channel,
                overlapped=(self.mode == "overlap"))
        return fn(x, w1, w2, residual=residual, glue=glue, **kw)

    def matmul_rs(self, x, w, **kw):
        return self._op("matmul_rs", (jnp.shape(x), jnp.shape(w)))(x, w, **kw)

    def ring_attention(self, q, k, v, **kw):
        return self._op("ag_attention",
                        (jnp.shape(q), jnp.shape(k), jnp.shape(v)))(q, k, v, **kw)

    def ag_moe(self, x, ids, wts, w_gu, w_down, **kw):
        return self._op(
            "ag_moe", (jnp.shape(x), jnp.shape(ids), jnp.shape(wts),
                       jnp.shape(w_gu), jnp.shape(w_down)),
        )(x, ids, wts, w_gu, w_down, **kw)

    def a2a_moe(self, x, ids, wts, w_gu, w_down, **kw):
        """Expert-parallel MoE: overlapped dispatch/combine all-to-all.

        Lowers the ``["a2a_dispatch", "combine_rs"]`` pair through
        ``compile_overlap`` over ``ep_axis``: each step's direct pairwise
        exchange lands a peer's token tile + routing tables, the local
        experts' grouped GEMM runs while the next exchange is in flight, and
        the weighted partial returns home along the reversed edge.  Requires
        ``ParallelContext(ep_axis=...)`` — expert parallelism is opt-in.
        ``mode="baseline"`` (or an unfused tuner verdict under ``tune=True``)
        runs ``a2a_moe_baseline`` with identical capacity/drop semantics.
        """
        if self.ep_axis is None:
            raise ValueError(
                "a2a_moe requires ParallelContext(ep_axis=...); expert "
                "parallelism is opt-in (use ag_moe for the TP MoE path)")
        ch = self.channel if self.ep_axis == self.axis else self.channel.with_(
            axis=self.ep_axis)
        ops = ["a2a_dispatch", "combine_rs"]
        if self.tune and self.mode == "overlap":
            # the a2a MoE kinds are not QUANT_WIRE_KINDS, so the widened
            # space's flow axis is inert here (int32 routing tables dilute
            # the win); _tune_space keeps the call sites uniform regardless
            fn = compile_overlap(
                ops, channel="auto", axis=self.ep_axis, mesh=self.mesh,
                tune_ranker=self.tune_ranker, tune_base=ch,
                tune_space=self._tune_space())
        else:
            fn = compile_overlap(
                ops, channel=ch, overlapped=(self.mode == "overlap"))
        return fn(x, ids, wts, w_gu, w_down, **kw)

    def psum(self, x):
        return lax.psum(x, self.axis)

    def axis_index(self):
        return lax.axis_index(self.axis)

    def all_gather_seq(self, x, dim: int):
        return lax.all_gather(x, self.axis, axis=dim, tiled=True)
