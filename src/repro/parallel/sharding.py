"""Sharding utilities: placing pytrees, named shardings, spec manipulation."""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["named", "place", "shardings_of", "is_spec"]


def is_spec(v) -> bool:
    return isinstance(v, P)


def named(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def shardings_of(mesh, spec_tree):
    """Map a pytree of PartitionSpec to NamedSharding."""
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), spec_tree,
                                  is_leaf=is_spec)


def place(tree, mesh, spec_tree):
    """device_put a pytree according to a matching spec pytree."""
    return jax.tree_util.tree_map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
        tree, spec_tree, is_leaf=lambda v: is_spec(v) or v is None,
    )
