from repro.parallel.context import ParallelContext
from repro.parallel import sharding

__all__ = ["ParallelContext", "sharding"]
