"""Decoder-only LM covering dense / MoE / SSM / hybrid / VLM architectures.

Depth is organized as repeated *pattern units* (cfg.pattern), scanned with
stacked parameters for O(1) HLO size at any depth; non-pattern layers
(DeepSeek's leading dense layers, depth remainders) are unrolled.  Each layer
kind wraps its body in a partial-auto shard_map (manual over the TP axis) —
see nn/* for the per-kind bodies.

Layer kinds: "attn" (global attention + FFN), "attn_local" (sliding window),
"attn_dense" (attention + dense MLP in an otherwise-MoE model), "mamba"
(SSD mixer, no FFN), "shared_attn" (attention + FFN with parameters shared
across all occurrences — Zamba2).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import attention, ffn, moe, mamba
from repro.nn.layers import emb_init
from repro.parallel.context import ParallelContext

__all__ = ["init", "specs", "forward", "init_caches", "cache_specs",
           "decode_step", "grad_masks", "sync_grads", "layer_plan", "LayerDef"]

# per-shard spec of a fused seam's gathered qkv projection ([B, S, cols_loc],
# column-sharded over the TP axis) as it crosses between layer smap regions
_SEAM_QKV_SPEC = P(None, None, "model")


@dataclasses.dataclass(frozen=True)
class LayerDef:
    kind: str  # attn | attn_local | attn_dense | mamba | shared_attn
    ffn_kind: Optional[str]  # mlp | moe | None
    window: Optional[int]
    theta: float
    shared: bool = False  # parameters shared across occurrences (zamba2)

    # ---- params ---------------------------------------------------------------
    def init(self, key, cfg, pc, dtype):
        ks = jax.random.split(key, 2)
        p = {}
        if self.kind == "mamba":
            p["mixer"] = mamba.init(ks[0], cfg, pc.tp, dtype)
        elif not self.shared:
            p["mixer"] = attention.init(ks[0], cfg, pc.tp, dtype)
        if self.ffn_kind == "mlp":
            d_ff = cfg.moe.dense_d_ff if self.kind == "attn_dense" and cfg.moe else cfg.d_ff
            p["ffn"] = ffn.init(ks[1], cfg, pc.tp, dtype, d_ff=d_ff)
        elif self.ffn_kind == "moe":
            p["ffn"] = moe.init(ks[1], cfg, pc.tp, dtype)
        return p

    def specs(self, cfg, pc):
        dp = pc.dp_spec()
        s = {}
        if self.kind == "mamba":
            s["mixer"] = mamba.specs(cfg, pc.tp, dp)
        elif not self.shared:
            s["mixer"] = attention.specs(cfg, pc.tp, dp)
        if self.ffn_kind == "mlp":
            s["ffn"] = ffn.specs(cfg, pc.tp, dp)
        elif self.ffn_kind == "moe":
            s["ffn"] = moe.specs(cfg, pc.tp, dp)
        return s

    def grad_masks(self, cfg, pc):
        m = jax.tree_util.tree_map(lambda _: None, self.specs(cfg, pc))
        if self.kind != "mamba" and not self.shared:
            am = attention.grad_masks(cfg, pc.tp)
            if am is not None:
                m["mixer"] = am
        return m

    # ---- seq (train / prefill) --------------------------------------------------
    def apply_seq(self, params, x, pc, cfg, shared_params=None):
        """x: [B, s_loc, D] (seq-sharded). Returns (x, aux_loss)."""
        mixer_params = shared_params if self.shared else params["mixer"]
        aux = jnp.zeros((), jnp.float32)

        if self.kind == "mamba":
            full = mamba.specs(cfg, pc.tp, pc.dp_spec())
            sp = {k: pc.manual(v) for k, v in full.items()}
            x = pc.smap(
                lambda p_, x_: mamba.apply_seq(p_, x_, pc, cfg),
                in_specs=(sp, P(None, "model", None)),
                out_specs=P(None, "model", None),
            )(pc.use_gather(mixer_params, full), x)
        else:
            full = attention.specs(cfg, pc.tp, pc.dp_spec())
            sp = {k: pc.manual(v) for k, v in full.items()}
            x = pc.smap(
                lambda p_, x_: attention.apply_seq(
                    p_, x_, pc, cfg, causal=True, window=self.window,
                    rope_theta=self.theta),
                in_specs=(sp, P(None, "model", None)),
                out_specs=P(None, "model", None),
            )(pc.use_gather(mixer_params, full), x)

        if self.ffn_kind == "mlp":
            full = ffn.specs(cfg, pc.tp, pc.dp_spec())
            sp = {k: pc.manual(v) for k, v in full.items()}
            x = pc.smap(
                lambda p_, x_: ffn.apply_seq(p_, x_, pc, cfg),
                in_specs=(sp, P(None, "model", None)),
                out_specs=P(None, "model", None),
            )(pc.use_gather(params["ffn"], full), x)
        elif self.ffn_kind == "moe":
            full = moe.specs(cfg, pc.tp, pc.dp_spec())
            sp = jax.tree_util.tree_map(
                pc.manual, full, is_leaf=lambda v: isinstance(v, P))
            x, aux = pc.smap(
                lambda p_, x_: moe.apply_seq(p_, x_, pc, cfg),
                in_specs=(sp, P(None, "model", None)),
                out_specs=(P(None, "model", None), P()),
            )(pc.use_gather(params["ffn"], full), x)
        return x, aux

    # ---- fused RS->AG seams (pc.fuse_seams) -----------------------------------
    def seam_eligible(self) -> bool:
        """Layer can join a fused RS->AG seam chain: attention + dense MLP.

        Mamba has no RS epilogue feeding an AG consumer; MoE's gather is the
        ag_moe flow, not a plain ag_matmul — both break the chain.
        """
        return self.kind != "mamba" and self.ffn_kind == "mlp"

    def apply_seq_fused(self, params, x, pc, cfg, shared_params=None,
                        qkv=None, next_mixer=None):
        """Seam-fused layer body: ONE smap region for attention + MLP.

        The attention output-proj RS feeds the MLP gate/up AG over one shared
        ring pass (intra-layer seam); with ``next_mixer`` (the next layer's
        attention params) the MLP down-proj RS additionally produces the NEXT
        layer's qkv projection (inter-layer seam), returned as ``next_qkv``
        so the caller threads it into the next ``apply_seq_fused``.  ``qkv``
        is this layer's projection from the previous layer's seam.
        Returns (x, aux_loss, next_qkv).
        """
        mixer_params = shared_params if self.shared else params["mixer"]
        afull = attention.specs(cfg, pc.tp, pc.dp_spec())
        asp = {k: pc.manual(v) for k, v in afull.items()}
        ffull = ffn.specs(cfg, pc.tp, pc.dp_spec())
        fsp = {k: pc.manual(v) for k, v in ffull.items()}
        aux = jnp.zeros((), jnp.float32)

        args = [pc.use_gather(mixer_params, afull),
                pc.use_gather(params["ffn"], ffull), x]
        in_specs = [asp, fsp, P(None, "model", None)]
        if qkv is not None:
            args.append(qkv)
            in_specs.append(_SEAM_QKV_SPEC)
        if next_mixer is not None:
            args.append(pc.use_gather(next_mixer, afull))
            in_specs.append(asp)

        def body(mp_, fp_, x_, *rest):
            it = iter(rest)
            qkv_ = next(it) if qkv is not None else None
            np_ = next(it) if next_mixer is not None else None
            y, gu = attention.apply_seq(
                mp_, x_, pc, cfg, causal=True, window=self.window,
                rope_theta=self.theta, qkv=qkv_,
                next_proj=ffn.seam_proj(fp_, cfg))
            if np_ is None:
                return ffn.apply_seq(fp_, y, pc, cfg, gu=gu)
            return ffn.apply_seq(fp_, y, pc, cfg, gu=gu,
                                 next_proj=attention.seam_proj(np_, cfg))

        if next_mixer is not None:
            x, nqkv = pc.smap(
                body, in_specs=tuple(in_specs),
                out_specs=(P(None, "model", None), _SEAM_QKV_SPEC))(*args)
            return x, aux, nqkv
        x = pc.smap(body, in_specs=tuple(in_specs),
                    out_specs=P(None, "model", None))(*args)
        return x, aux, None

    # ---- prefill (fills decode caches while computing logits) -----------------
    def apply_prefill(self, params, x, pc, cfg, max_len, shared_params=None):
        """Like apply_seq, but also returns this layer's decode cache with the
        sequence dimension padded to ``max_len``."""
        mixer_params = shared_params if self.shared else params["mixer"]
        aux = jnp.zeros((), jnp.float32)

        if self.kind == "mamba":
            full = mamba.specs(cfg, pc.tp, pc.dp_spec())
            sp = {k: pc.manual(v) for k, v in full.items()}
            cs = {k: pc.manual(v) for k, v in mamba.cache_specs(pc.dp_spec()).items()}
            x, cache = pc.smap(
                lambda p_, x_: mamba.apply_seq(p_, x_, pc, cfg, return_state=True),
                in_specs=(sp, P(None, "model", None)),
                out_specs=(P(None, "model", None), cs),
            )(pc.use_gather(mixer_params, full), x)
        else:
            full = attention.specs(cfg, pc.tp, pc.dp_spec())
            sp = {k: pc.manual(v) for k, v in full.items()}
            cs = {k: pc.manual(v) for k, v in
                  attention.cache_specs(pc.dp_spec()).items()}

            def fn(p_, x_):
                y, kv = attention.apply_seq(
                    p_, x_, pc, cfg, causal=True, window=self.window,
                    rope_theta=self.theta, return_kv=True)
                s_len = kv["k"].shape[2]
                if self.window is not None and self.window < max_len:
                    # ring-buffer layout: slot p % window holds position p
                    w = self.window
                    if s_len >= w:
                        kv = {n: jnp.roll(a[:, :, s_len - w:], s_len % w, axis=2)
                              for n, a in kv.items()}
                    else:
                        kv = {n: jnp.pad(a, ((0, 0), (0, 0), (0, w - s_len), (0, 0)))
                              for n, a in kv.items()}
                else:
                    pad = max_len - s_len
                    kv = {n: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)))
                          for n, a in kv.items()}
                return y, kv

            x, cache = pc.smap(
                fn, in_specs=(sp, P(None, "model", None)),
                out_specs=(P(None, "model", None), cs),
            )(pc.use_gather(mixer_params, full), x)

        if self.ffn_kind == "mlp":
            full = ffn.specs(cfg, pc.tp, pc.dp_spec())
            sp = {k: pc.manual(v) for k, v in full.items()}
            x = pc.smap(
                lambda p_, x_: ffn.apply_seq(p_, x_, pc, cfg),
                in_specs=(sp, P(None, "model", None)),
                out_specs=P(None, "model", None),
            )(pc.use_gather(params["ffn"], full), x)
        elif self.ffn_kind == "moe":
            full = moe.specs(cfg, pc.tp, pc.dp_spec())
            sp = jax.tree_util.tree_map(
                pc.manual, full, is_leaf=lambda v: isinstance(v, P))
            x, aux = pc.smap(
                lambda p_, x_: moe.apply_seq(p_, x_, pc, cfg),
                in_specs=(sp, P(None, "model", None)),
                out_specs=(P(None, "model", None), P()),
            )(pc.use_gather(params["ffn"], full), x)
        return x, aux, cache

    # ---- decode -----------------------------------------------------------------
    def init_cache(self, cfg, pc, batch, max_len, dtype):
        if self.kind == "mamba":
            return mamba.init_cache(cfg, pc.tp, batch, dtype)
        return attention.init_cache(cfg, pc.tp, batch, max_len, dtype,
                                    window=self.window)

    def cache_specs(self, pc):
        dp = pc.dp_spec()
        if self.kind == "mamba":
            return mamba.cache_specs(dp)
        return attention.cache_specs(dp)

    def apply_decode(self, params, x, cache, cache_len, pc, cfg,
                     shared_params=None, q_valid=None):
        mixer_params = shared_params if self.shared else params["mixer"]
        b, c = x.shape[0], x.shape[1]
        lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
        nv = (jnp.full((b,), c, jnp.int32) if q_valid is None
              else jnp.asarray(q_valid, jnp.int32))
        if self.kind == "mamba":
            full = mamba.specs(cfg, pc.tp, pc.dp_spec())
            sp = {k: pc.manual(v) for k, v in full.items()}
            cs = {k: pc.manual(v) for k, v in mamba.cache_specs(pc.dp_spec()).items()}
            x, cache = pc.smap(
                lambda p_, x_, c_, n_: mamba.apply_decode_chunk(
                    p_, x_, c_, pc, cfg, q_valid=n_),
                in_specs=(sp, P(None, None, None), cs, P(None)),
                out_specs=(P(None, None, None), cs),
            )(pc.use_gather(mixer_params, full), x, cache, nv)
        else:
            full = attention.specs(cfg, pc.tp, pc.dp_spec())
            sp = {k: pc.manual(v) for k, v in full.items()}
            cs = {k: pc.manual(v) for k, v in
                  attention.cache_specs(pc.dp_spec()).items()}
            x, cache = pc.smap(
                lambda p_, x_, c_, l_, n_: attention.apply_decode(
                    p_, x_, c_, l_, pc, cfg, window=self.window,
                    rope_theta=self.theta, q_valid=n_),
                in_specs=(sp, P(None, None, None), cs, P(None), P(None)),
                out_specs=(P(None, None, None), cs),
            )(pc.use_gather(mixer_params, full), x, cache, lens, nv)

        if self.ffn_kind == "mlp":
            full = ffn.specs(cfg, pc.tp, pc.dp_spec())
            sp = {k: pc.manual(v) for k, v in full.items()}
            x = pc.smap(
                lambda p_, x_: ffn.apply_decode(p_, x_, pc, cfg),
                in_specs=(sp, P(None, None, None)),
                out_specs=P(None, None, None),
            )(pc.use_gather(params["ffn"], full), x)
        elif self.ffn_kind == "moe":
            full = moe.specs(cfg, pc.tp, pc.dp_spec())
            sp = jax.tree_util.tree_map(
                pc.manual, full, is_leaf=lambda v: isinstance(v, P))
            x = pc.smap(
                lambda p_, x_: moe.apply_decode(p_, x_, pc, cfg),
                in_specs=(sp, P(None, None, None)),
                out_specs=P(None, None, None),
            )(pc.use_gather(params["ffn"], full), x)
        return x, cache


def _layer_def(cfg, kind: str) -> LayerDef:
    theta_local = getattr(cfg, "rope_theta_local", 1e4)
    if kind == "mamba":
        return LayerDef("mamba", None, None, 0.0)
    if kind == "shared_attn":
        return LayerDef("shared_attn", "mlp", None, cfg.rope_theta, shared=True)
    window = cfg.local_window if kind == "attn_local" else None
    theta = theta_local if kind == "attn_local" else cfg.rope_theta
    if kind == "attn_dense":
        return LayerDef("attn_dense", "mlp", None, cfg.rope_theta)
    ffn_kind = None
    if cfg.moe is not None:
        ffn_kind = "moe"
    elif cfg.d_ff:
        ffn_kind = "mlp"
    return LayerDef(kind, ffn_kind, window, theta)


def layer_plan(cfg) -> Tuple[List[LayerDef], List[LayerDef], int, List[LayerDef]]:
    """(prefix_defs, unit_defs, n_units, suffix_defs)."""
    period = len(cfg.pattern)
    k0 = cfg.moe.first_k_dense if cfg.moe else 0
    prefix = [_layer_def(cfg, cfg.layer_kind(i)) for i in range(k0)]
    remaining = cfg.n_layers - k0
    n_units = remaining // period
    unit = [_layer_def(cfg, cfg.pattern[j]) for j in range(period)]
    n_suffix = remaining - n_units * period
    suffix = [_layer_def(cfg, cfg.pattern[j]) for j in range(n_suffix)]
    return prefix, unit, n_units, suffix


def _uses_shared(cfg) -> bool:
    return any(k == "shared_attn" for k in cfg.pattern)


def _gathered_head(params, cfg, pc):
    """LM head with ZeRO use-time gather of the dp-sharded dim."""
    from jax.sharding import PartitionSpec as _P

    if cfg.tie_embeddings:
        emb = jax.lax.with_sharding_constraint(
            params["embed"],
            jax.sharding.NamedSharding(pc.mesh, _P("model", None)))
        return emb.T
    return jax.lax.with_sharding_constraint(
        params["lm_head"],
        jax.sharding.NamedSharding(pc.mesh, _P(None, "model")))


# -----------------------------------------------------------------------------
# init / specs
# -----------------------------------------------------------------------------

def padded_vocab(cfg, pc) -> int:
    """Vocab rows padded to the TP degree (uneven vocabs e.g. 49155)."""
    v, tp = cfg.vocab_size, pc.tp
    return -(-v // tp) * tp


def init(key, cfg, pc: ParallelContext, dtype=jnp.bfloat16):
    prefix, unit, n_units, suffix = layer_plan(cfg)
    v_pad = padded_vocab(cfg, pc)
    ks = iter(jax.random.split(key, 8 + len(prefix) + len(suffix)))
    params: Dict[str, Any] = {
        "embed": emb_init(next(ks), (v_pad, cfg.d_model), dtype),
        "final_ln": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = emb_init(next(ks), (cfg.d_model, v_pad), dtype)
    if _uses_shared(cfg):
        params["shared_attn"] = attention.init(next(ks), cfg, pc.tp, dtype)

    params["prefix"] = [d.init(next(ks), cfg, pc, dtype) for d in prefix]
    params["suffix"] = [d.init(next(ks), cfg, pc, dtype) for d in suffix]

    if n_units:
        unit_key = next(ks)

        def one_unit(k):
            kk = jax.random.split(k, len(unit))
            return [d.init(kk[i], cfg, pc, dtype) for i, d in enumerate(unit)]

        params["scan"] = jax.vmap(one_unit)(jax.random.split(unit_key, n_units))
    return params


def specs(cfg, pc: ParallelContext):
    prefix, unit, n_units, suffix = layer_plan(cfg)
    dp = pc.dp_spec()
    s: Dict[str, Any] = {
        "embed": P("model", dp),
        "final_ln": P(None),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = P(dp, "model")
    if _uses_shared(cfg):
        s["shared_attn"] = attention.specs(cfg, pc.tp, dp)
    s["prefix"] = [d.specs(cfg, pc) for d in prefix]
    s["suffix"] = [d.specs(cfg, pc) for d in suffix]
    if n_units:
        def stack_spec(spec):
            # scanned params have a leading layer axis (unsharded)
            return P(*((None,) + tuple(spec)))

        s["scan"] = [
            jax.tree_util.tree_map(stack_spec, d.specs(cfg, pc),
                                   is_leaf=lambda v: isinstance(v, P))
            for d in unit
        ]
    return s


def sync_grads(grads, cfg, pc: ParallelContext):
    """Average the expanded kv-weight replica gradients (GQA with kv < tp).

    kv weights are stored with ``rep`` identical copies (nn/layers.GQALayout);
    their per-copy gradients differ (different q-head groups), so they are
    group-averaged here to keep the copies identical — Megatron-style GQA
    replication semantics.  No-op when rep == 1.  Works on any pytree whose
    attention param dicts contain a "wkv" leaf (stacked or not).
    """
    from repro.nn.layers import gqa_layout, sync_kv_grad

    if not cfg.n_heads:
        return grads
    lay = gqa_layout(cfg.n_heads, cfg.n_kv_heads, pc.tp)
    if lay.rep == 1:
        return grads

    def walk(node):
        if isinstance(node, dict):
            if "wkv" in node:
                node = dict(node)
                node["wkv"] = sync_kv_grad(node["wkv"], lay, axis=-1)
                if "bkv" in node:
                    node["bkv"] = sync_kv_grad(node["bkv"], lay, axis=-1)
                return node
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        if isinstance(node, tuple):
            return tuple(walk(v) for v in node)
        return node

    return walk(grads)


def grad_masks(cfg, pc: ParallelContext):
    """Pytree of 0/1 masks (or None) matching params, for padded-head params."""
    prefix, unit, n_units, suffix = layer_plan(cfg)
    m: Dict[str, Any] = {"embed": None, "final_ln": None}
    if not cfg.tie_embeddings:
        m["lm_head"] = None
    if _uses_shared(cfg):
        am = attention.grad_masks(cfg, pc.tp)
        m["shared_attn"] = am if am is not None else jax.tree_util.tree_map(
            lambda _: None, attention.specs(cfg, pc.tp, pc.dp_spec()))
    m["prefix"] = [d.grad_masks(cfg, pc) for d in prefix]
    m["suffix"] = [d.grad_masks(cfg, pc) for d in suffix]
    if n_units:
        m["scan"] = [d.grad_masks(cfg, pc) for d in unit]  # broadcast over layer axis
    return m


# -----------------------------------------------------------------------------
# forward (train / prefill)
# -----------------------------------------------------------------------------

def _seam_chain(defs, plist, x, pc, cfg, shared, aux_total):
    """Run a python-level list of layers, fusing RS->AG seams between
    consecutive eligible layers (attention + dense MLP); an ineligible layer
    (mamba, MoE) breaks the chain and runs the unfused body.  Chains live
    within one python-level segment only — a lax.scan carry boundary cannot
    carry a half-open seam, so prefix / each scan unit / suffix chain
    independently.
    """
    qkv = None
    n = len(defs)
    for i, (d, p) in enumerate(zip(defs, plist)):
        if not d.seam_eligible():
            x, aux = d.apply_seq(p, x, pc, cfg, shared_params=shared)
            aux_total = aux_total + aux
            continue
        next_mixer = None
        if i + 1 < n and defs[i + 1].seam_eligible():
            nd, np_ = defs[i + 1], plist[i + 1]
            next_mixer = shared if nd.shared else np_["mixer"]
        x, aux, qkv = d.apply_seq_fused(p, x, pc, cfg, shared_params=shared,
                                        qkv=qkv, next_mixer=next_mixer)
        aux_total = aux_total + aux
    return x, aux_total


def embed_tokens(params, cfg, tokens, embeds=None):
    """tokens: [B, S] int32 (or None); embeds: [B, S0, D] stub-frontend prefix."""
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(params["embed"].dtype))
    if tokens is not None:
        parts.append(jnp.take(params["embed"], tokens, axis=0))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if cfg.family in ("vlm",) or cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def forward(params, cfg, pc: ParallelContext, tokens, embeds=None,
            remat_policy: str = "none", unroll: bool = False):
    """Returns (logits [B, S, V], aux_loss scalar).

    ``unroll`` replaces the layer scan with a python loop — used by the
    dry-run cost analysis (XLA counts while bodies once) and for small-depth
    debugging; numerically identical.

    With ``pc.fuse_seams`` consecutive attention+MLP layers chain their
    RS->AG seams into shared ring passes (see :func:`_seam_chain`); chains
    reset at lax.scan carry boundaries.
    """
    from repro.nn.layers import rms_norm

    prefix, unit, n_units, suffix = layer_plan(cfg)
    x = embed_tokens(params, cfg, tokens, embeds)
    x = jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(pc.mesh, P(pc.dp_spec(), "model", None)))

    shared = params.get("shared_attn")
    aux_total = jnp.zeros((), jnp.float32)

    if pc.fuse_seams:
        x, aux_total = _seam_chain(prefix, params["prefix"], x, pc, cfg,
                                   shared, aux_total)
    else:
        for d, p in zip(prefix, params["prefix"]):
            x, aux = d.apply_seq(p, x, pc, cfg, shared_params=shared)
            aux_total = aux_total + aux

    if n_units:
        def unit_body(carry, unit_params):
            h, aux_acc = carry
            if pc.fuse_seams:
                plist = [unit_params[i] for i in range(len(unit))]
                h, aux_acc = _seam_chain(unit, plist, h, pc, cfg,
                                         shared, aux_acc)
            else:
                for i, d in enumerate(unit):
                    h, aux = d.apply_seq(unit_params[i], h, pc, cfg,
                                         shared_params=shared)
                    aux_acc = aux_acc + aux
            return (h, aux_acc), None

        body = unit_body
        if remat_policy != "none":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if remat_policy == "dots" else None)
            body = jax.checkpoint(unit_body, policy=policy)

        if unroll:
            for u in range(n_units):
                up = jax.tree_util.tree_map(lambda a: a[u], params["scan"])
                (x, aux_total), _ = body((x, aux_total), up)
        else:
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["scan"])

    if pc.fuse_seams:
        x, aux_total = _seam_chain(suffix, params["suffix"], x, pc, cfg,
                                   shared, aux_total)
    else:
        for d, p in zip(suffix, params["suffix"]):
            x, aux = d.apply_seq(p, x, pc, cfg, shared_params=shared)
            aux_total = aux_total + aux

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = _gathered_head(params, cfg, pc)
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    logits = jax.lax.with_sharding_constraint(
        logits, jax.sharding.NamedSharding(pc.mesh, P(pc.dp_spec(), None, "model")))
    return logits[..., : cfg.vocab_size], aux_total


def prefill(params, cfg, pc: ParallelContext, tokens, embeds=None, *,
            max_len: int, unroll: bool = False):
    """Forward pass that also fills decode caches (serve-path prefill).

    Returns (logits [B, S, V], caches) — decode continues at position S.
    """
    from repro.nn.layers import rms_norm

    prefix, unit, n_units, suffix = layer_plan(cfg)
    x = embed_tokens(params, cfg, tokens, embeds)
    x = jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(pc.mesh, P(pc.dp_spec(), "model", None)))
    shared = params.get("shared_attn")

    pre_caches = []
    for d, p in zip(prefix, params["prefix"]):
        x, _, c = d.apply_prefill(p, x, pc, cfg, max_len, shared_params=shared)
        pre_caches.append(c)

    scan_caches = None
    if n_units:
        def unit_body(h, unit_params):
            caches = []
            for i, d in enumerate(unit):
                h, _, c = d.apply_prefill(unit_params[i], h, pc, cfg, max_len,
                                          shared_params=shared)
                caches.append(c)
            return h, caches

        if unroll:
            collected = []
            for u in range(n_units):
                up = jax.tree_util.tree_map(lambda a: a[u], params["scan"])
                x, cs_u = unit_body(x, up)
                collected.append(cs_u)
            scan_caches = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *collected)
        else:
            x, scan_caches = jax.lax.scan(unit_body, x, params["scan"])

    suf_caches = []
    for d, p in zip(suffix, params["suffix"]):
        x, _, c = d.apply_prefill(p, x, pc, cfg, max_len, shared_params=shared)
        suf_caches.append(c)

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = _gathered_head(params, cfg, pc)
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return logits[..., : cfg.vocab_size], {"prefix": pre_caches,
                                           "scan": scan_caches,
                                           "suffix": suf_caches}


# -----------------------------------------------------------------------------
# decode
# -----------------------------------------------------------------------------

def init_caches(cfg, pc, batch, max_len, dtype=jnp.bfloat16):
    prefix, unit, n_units, suffix = layer_plan(cfg)
    caches = {
        "prefix": [d.init_cache(cfg, pc, batch, max_len, dtype) for d in prefix],
        "suffix": [d.init_cache(cfg, pc, batch, max_len, dtype) for d in suffix],
    }
    if n_units:
        caches["scan"] = [
            jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (n_units,) + a.shape).copy(),
                d.init_cache(cfg, pc, batch, max_len, dtype))
            for d in unit
        ]
    return caches


def cache_specs(cfg, pc):
    prefix, unit, n_units, suffix = layer_plan(cfg)
    cs = {
        "prefix": [d.cache_specs(pc) for d in prefix],
        "suffix": [d.cache_specs(pc) for d in suffix],
    }
    if n_units:
        cs["scan"] = [
            jax.tree_util.tree_map(lambda sp: P(*((None,) + tuple(sp))),
                                   d.cache_specs(pc),
                                   is_leaf=lambda v: isinstance(v, P))
            for d in unit
        ]
    return cs


def decode_step(params, caches, cfg, pc: ParallelContext, tokens, cache_len,
                unroll: bool = False, q_valid=None):
    """One decode step advancing every slot by up to C tokens.

    tokens: [B, C] int32 (C == 1 is plain decode; C > 1 a prefill chunk);
    cache_len: traced scalar or per-slot [B] vector; ``q_valid`` (optional
    [B] int) marks how many of the C rows are real per slot — rows past it
    leave cache/state untouched and their logits are garbage.

    Returns (logits [B, C, V], new_caches).
    """
    from repro.nn.layers import rms_norm

    prefix, unit, n_units, suffix = layer_plan(cfg)
    x = embed_tokens(params, cfg, tokens)
    shared = params.get("shared_attn")

    new_prefix = []
    for d, p, c in zip(prefix, params["prefix"], caches["prefix"]):
        x, c = d.apply_decode(p, x, c, cache_len, pc, cfg,
                              shared_params=shared, q_valid=q_valid)
        new_prefix.append(c)

    new_scan = caches.get("scan")
    if n_units:
        def unit_body(h, xs):
            unit_params, unit_caches = xs
            new_caches = []
            for i, d in enumerate(unit):
                h, c = d.apply_decode(unit_params[i], h, unit_caches[i],
                                      cache_len, pc, cfg, shared_params=shared,
                                      q_valid=q_valid)
                new_caches.append(c)
            return h, new_caches

        if unroll:
            collected = []
            for u in range(n_units):
                up = jax.tree_util.tree_map(lambda a: a[u], params["scan"])
                uc = jax.tree_util.tree_map(lambda a: a[u], caches["scan"])
                x, cs_u = unit_body(x, (up, uc))
                collected.append(cs_u)
            new_scan = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                              *collected)
        else:
            x, new_scan = jax.lax.scan(unit_body, x,
                                       (params["scan"], caches["scan"]))

    new_suffix = []
    for d, p, c in zip(suffix, params["suffix"], caches["suffix"]):
        x, c = d.apply_decode(p, x, c, cache_len, pc, cfg,
                              shared_params=shared, q_valid=q_valid)
        new_suffix.append(c)

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = _gathered_head(params, cfg, pc)
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return logits[..., : cfg.vocab_size], {"prefix": new_prefix,
                                           "scan": new_scan,
                                           "suffix": new_suffix}
