"""Stub modality frontends (assignment rule: [vlm]/[audio] backbones only).

``input_specs()`` for these archs provides *precomputed* patch/frame embeddings;
these helpers generate matching synthetic embeddings for smoke tests and
examples, and define the prefix lengths used by the shape registry.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["vision_prefix_len", "audio_frames_len", "stub_patch_embeddings",
           "stub_frame_embeddings"]

VISION_PATCHES = 256  # SigLIP 16x16 grid stub
AUDIO_FRAME_STRIDE = 8  # speech frames per text token (stub ratio)


def vision_prefix_len(seq_len: int) -> int:
    """Image patches occupy a fixed prefix of the sequence."""
    return min(VISION_PATCHES, seq_len // 2)


def audio_frames_len(seq_len: int) -> int:
    return min(4096, max(64, seq_len // AUDIO_FRAME_STRIDE))


def stub_patch_embeddings(key, batch: int, seq_len: int, d_model: int,
                          dtype=jnp.bfloat16):
    n = vision_prefix_len(seq_len)
    return (jax.random.normal(key, (batch, n, d_model)) * 0.02).astype(dtype)


def stub_frame_embeddings(key, batch: int, enc_len: int, d_model: int,
                          dtype=jnp.bfloat16):
    return (jax.random.normal(key, (batch, enc_len, d_model)) * 0.02).astype(dtype)
