from repro.models import lm, encdec, frontends

__all__ = ["lm", "encdec", "frontends"]
