"""Encoder-decoder LM (seamless-m4t backbone).

Encoder: non-causal attention + FFN over stub frame embeddings (scanned).
Decoder: causal self-attention + cross-attention + FFN (scanned).
The paper's technique covers every projection (AG+GEMM / GEMM+RS) on both
stacks and the cross-attention KV gather.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import attention, ffn
from repro.nn.layers import emb_init, rms_norm
from repro.parallel.context import ParallelContext

__all__ = ["init", "specs", "forward", "init_caches", "cache_specs",
           "decode_step", "encode", "grad_masks", "sync_grads"]


def _enc_layer_init(key, cfg, pc, dtype):
    k1, k2 = jax.random.split(key)
    return {"attn": attention.init(k1, cfg, pc.tp, dtype),
            "ffn": ffn.init(k2, cfg, pc.tp, dtype)}


def _dec_layer_init(key, cfg, pc, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"attn": attention.init(k1, cfg, pc.tp, dtype),
            "cross": attention.init(k2, cfg, pc.tp, dtype),
            "ffn": ffn.init(k3, cfg, pc.tp, dtype)}


def _enc_layer_specs(cfg, pc):
    dp = pc.dp_spec()
    return {"attn": attention.specs(cfg, pc.tp, dp),
            "ffn": ffn.specs(cfg, pc.tp, dp)}


def _dec_layer_specs(cfg, pc):
    dp = pc.dp_spec()
    return {"attn": attention.specs(cfg, pc.tp, dp),
            "cross": attention.specs(cfg, pc.tp, dp),
            "ffn": ffn.specs(cfg, pc.tp, dp)}


def init(key, cfg, pc: ParallelContext, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    n_enc, n_dec = cfg.encoder_layers, cfg.n_layers

    def stack(k, n, f):
        return jax.vmap(lambda kk: f(kk, cfg, pc, dtype))(jax.random.split(k, n))

    from repro.models.lm import padded_vocab

    v_pad = padded_vocab(cfg, pc)
    return {
        "embed": emb_init(ks[0], (v_pad, cfg.d_model), dtype),
        "enc_scan": stack(ks[1], n_enc, _enc_layer_init),
        "enc_ln": jnp.zeros((cfg.d_model,), dtype),
        "dec_scan": stack(ks[2], n_dec, _dec_layer_init),
        "final_ln": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": emb_init(ks[3], (cfg.d_model, v_pad), dtype),
    }


def _stackP(tree):
    return jax.tree_util.tree_map(lambda sp: P(*((None,) + tuple(sp))), tree,
                                  is_leaf=lambda v: isinstance(v, P))


def specs(cfg, pc: ParallelContext):
    dp = pc.dp_spec()
    return {
        "embed": P("model", dp),
        "enc_scan": _stackP(_enc_layer_specs(cfg, pc)),
        "enc_ln": P(None),
        "dec_scan": _stackP(_dec_layer_specs(cfg, pc)),
        "final_ln": P(None),
        "lm_head": P(dp, "model"),
    }


def sync_grads(grads, cfg, pc: ParallelContext):
    """Average the expanded kv-weight replica gradients (GQA with kv < tp).

    kv weights are stored with ``rep`` identical copies (nn/layers.GQALayout);
    their per-copy gradients differ (different q-head groups), so they are
    group-averaged here to keep the copies identical — Megatron-style GQA
    replication semantics.  No-op when rep == 1.  Works on any pytree whose
    attention param dicts contain a "wkv" leaf (stacked or not).
    """
    from repro.nn.layers import gqa_layout, sync_kv_grad

    if not cfg.n_heads:
        return grads
    lay = gqa_layout(cfg.n_heads, cfg.n_kv_heads, pc.tp)
    if lay.rep == 1:
        return grads

    def walk(node):
        if isinstance(node, dict):
            if "wkv" in node:
                node = dict(node)
                node["wkv"] = sync_kv_grad(node["wkv"], lay, axis=-1)
                if "bkv" in node:
                    node["bkv"] = sync_kv_grad(node["bkv"], lay, axis=-1)
                return node
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        if isinstance(node, tuple):
            return tuple(walk(v) for v in node)
        return node

    return walk(grads)


def grad_masks(cfg, pc: ParallelContext):
    return jax.tree_util.tree_map(lambda _: None, specs(cfg, pc),
                                  is_leaf=lambda v: isinstance(v, P))


def _smap_attn(pc, cfg, p, x, *, causal, fn=attention.apply_seq, extra=()):
    full = attention.specs(cfg, pc.tp, pc.dp_spec())
    sp = {k: pc.manual(v) for k, v in full.items()}
    xs = P(None, "model", None)
    p = pc.use_gather(p, full)
    if extra:
        return pc.smap(
            lambda p_, x_, e_: attention.apply_cross_seq(p_, x_, e_, pc, cfg),
            in_specs=(sp, xs, xs), out_specs=xs)(p, x, *extra)
    return pc.smap(
        lambda p_, x_: attention.apply_seq(p_, x_, pc, cfg, causal=causal),
        in_specs=(sp, xs), out_specs=xs)(p, x)


def _smap_ffn(pc, cfg, p, x):
    full = ffn.specs(cfg, pc.tp, pc.dp_spec())
    sp = {k: pc.manual(v) for k, v in full.items()}
    xs = P(None, "model", None)
    return pc.smap(lambda p_, x_: ffn.apply_seq(p_, x_, pc, cfg),
                   in_specs=(sp, xs), out_specs=xs)(pc.use_gather(p, full), x)


def encode(params, cfg, pc, enc_embeds, remat_policy="none", unroll=False):
    """enc_embeds: [B, S_enc, D] stub frame embeddings -> [B, S_enc, D]."""
    x = enc_embeds
    x = jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(pc.mesh, P(pc.dp_spec(), "model", None)))

    def body(h, lp):
        h = _smap_attn(pc, cfg, lp["attn"], h, causal=False)
        h = _smap_ffn(pc, cfg, lp["ffn"], h)
        return h, None

    b = jax.checkpoint(body) if remat_policy != "none" else body
    if unroll:
        for u in range(cfg.encoder_layers):
            x, _ = b(x, jax.tree_util.tree_map(lambda a: a[u], params["enc_scan"]))
    else:
        x, _ = jax.lax.scan(b, x, params["enc_scan"])
    return rms_norm(x, params["enc_ln"], cfg.norm_eps)


def forward(params, cfg, pc: ParallelContext, tokens, embeds=None,
            remat_policy: str = "none", unroll: bool = False):
    """tokens: decoder input ids [B, S_dec]; embeds: encoder frames [B,S_enc,D].

    Returns (logits, aux=0)."""
    enc = encode(params, cfg, pc, embeds, remat_policy, unroll=unroll)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(pc.mesh, P(pc.dp_spec(), "model", None)))

    def body(h, lp):
        h = _smap_attn(pc, cfg, lp["attn"], h, causal=True)
        h = _smap_attn(pc, cfg, lp["cross"], h, causal=False, extra=(enc,))
        h = _smap_ffn(pc, cfg, lp["ffn"], h)
        return h, None

    b = jax.checkpoint(body) if remat_policy != "none" else body
    if unroll:
        for u in range(cfg.n_layers):
            x, _ = b(x, jax.tree_util.tree_map(lambda a: a[u], params["dec_scan"]))
    else:
        x, _ = jax.lax.scan(b, x, params["dec_scan"])
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = jax.lax.with_sharding_constraint(
        params["lm_head"], jax.sharding.NamedSharding(pc.mesh, P(None, "model")))
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return logits[..., : cfg.vocab_size], jnp.zeros((), jnp.float32)


# ---- decode -----------------------------------------------------------------

def init_caches(cfg, pc, batch, max_len, dtype=jnp.bfloat16):
    n_dec = cfg.n_layers
    self_c = attention.init_cache(cfg, pc.tp, batch, max_len, dtype)
    lay = attention.gqa_layout(cfg.n_heads, cfg.n_kv_heads, pc.tp)
    cross_c = {
        "k": jnp.zeros((batch, pc.tp * lay.kv_loc, cfg.enc_len, cfg.hd), dtype),
        "v": jnp.zeros((batch, pc.tp * lay.kv_loc, cfg.enc_len, cfg.hd), dtype),
    }
    def stack(c):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_dec,) + a.shape).copy(), c)
    return {"self": stack(self_c), "cross": stack(cross_c)}


def cache_specs(cfg, pc):
    dp = pc.dp_spec()
    sp = _stackP(attention.cache_specs(dp))
    return {"self": sp, "cross": sp}


def build_cross_caches(params, cfg, pc, enc):
    """Precompute per-layer cross K/V from the encoder output."""
    sp = {k: pc.manual(v) for k, v in
          attention.specs(cfg, pc.tp, pc.dp_spec()).items()}
    xs = P(None, "model", None)
    cs = {k: pc.manual(v) for k, v in attention.cache_specs(pc.dp_spec()).items()}

    full = attention.specs(cfg, pc.tp, pc.dp_spec())

    def per_layer(lp):
        return pc.smap(
            lambda p_, e_: attention.build_cross_cache(p_, e_, pc, cfg),
            in_specs=(sp, xs), out_specs=cs)(pc.use_gather(lp["cross"], full), enc)

    return jax.lax.map(per_layer, params["dec_scan"])


def decode_step(params, caches, cfg, pc: ParallelContext, tokens, cache_len,
                unroll: bool = False):
    """One decoder step with precomputed cross caches."""
    x = jnp.take(params["embed"], tokens, axis=0)
    dp = pc.dp_spec()
    asp = {k: pc.manual(v) for k, v in
           attention.specs(cfg, pc.tp, dp).items()}
    csp = {k: pc.manual(v) for k, v in attention.cache_specs(dp).items()}
    xr = P(None, None, None)

    afull = attention.specs(cfg, pc.tp, dp)
    ffull = ffn.specs(cfg, pc.tp, dp)

    def body(h, xs_):
        lp, self_c, cross_c = xs_
        lp = {"attn": pc.use_gather(lp["attn"], afull),
              "cross": pc.use_gather(lp["cross"], afull),
              "ffn": pc.use_gather(lp["ffn"], ffull)}
        h, self_c = pc.smap(
            lambda p_, x_, c_, n_: attention.apply_decode(p_, x_, c_, n_, pc, cfg),
            in_specs=(asp, xr, csp, P()), out_specs=(xr, csp),
        )(lp["attn"], h, self_c, cache_len)
        h = pc.smap(
            lambda p_, x_, c_: attention.apply_cross_decode(p_, x_, c_, pc, cfg),
            in_specs=(asp, xr, csp), out_specs=xr,
        )(lp["cross"], h, cross_c)
        fsp = {k: pc.manual(v) for k, v in ffn.specs(cfg, pc.tp, dp).items()}
        h = pc.smap(lambda p_, x_: ffn.apply_decode(p_, x_, pc, cfg),
                    in_specs=(fsp, xr), out_specs=xr)(lp["ffn"], h)
        return h, self_c

    if unroll:
        import jax.numpy as _jnp
        collected = []
        for u in range(cfg.n_layers):
            def sl(t, _u=u):
                return jax.tree_util.tree_map(lambda a: a[_u], t)
            x, sc = body(x, (sl(params["dec_scan"]), sl(caches["self"]),
                             sl(caches["cross"])))
            collected.append(sc)
        new_self = jax.tree_util.tree_map(lambda *xs: _jnp.stack(xs), *collected)
    else:
        x, new_self = jax.lax.scan(
            body, x, (params["dec_scan"], caches["self"], caches["cross"]))
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    head = jax.lax.with_sharding_constraint(
        params["lm_head"], jax.sharding.NamedSharding(pc.mesh, P(None, "model")))
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return logits[..., : cfg.vocab_size], {"self": new_self,
                                           "cross": caches["cross"]}
