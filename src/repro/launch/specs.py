"""Abstract input/param/cache specs per (arch × shape) — no device allocation.

Everything here returns ShapeDtypeStruct pytrees (via jax.eval_shape) plus the
matching PartitionSpec trees, so the dry-run can ``jit(...).lower(...)`` the
production step functions for any mesh without touching memory.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, Shape
from repro.models import lm, encdec, frontends
from repro.parallel.context import ParallelContext
from repro.training.optimizer import init_opt_state

__all__ = ["model_module", "abstract_params", "input_specs", "batch_pspec",
           "cell_is_applicable"]

SDS = jax.ShapeDtypeStruct


def model_module(cfg: ArchConfig):
    return encdec if cfg.encoder_layers else lm


def cell_is_applicable(cfg: ArchConfig, shape: Shape) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skip: pure full-attention arch — long_500k requires "
                       "sub-quadratic attention (DESIGN.md §Arch-applicability)")
    return True, ""


def abstract_params(cfg: ArchConfig, pc: ParallelContext, dtype=jnp.bfloat16):
    """(param ShapeDtypeStructs, param specs) without allocation."""
    mod = model_module(cfg)
    shapes = jax.eval_shape(
        lambda k: mod.init(k, cfg, pc, dtype), jax.random.PRNGKey(0))
    return shapes, mod.specs(cfg, pc)


def abstract_opt_state(param_shapes, param_specs):
    opt = jax.eval_shape(init_opt_state, param_shapes)
    specs = {
        "mu": param_specs,
        "nu": jax.tree_util.tree_map(lambda s: s, param_specs,
                                     is_leaf=lambda v: isinstance(v, P)),
        "step": P(),
    }
    return opt, specs


def batch_pspec(batch: int, pc: ParallelContext) -> Any:
    """Shard the batch over DP axes only when divisible (long_500k has B=1)."""
    dp = pc.dp_spec()
    n = pc.dp
    return dp if (dp is not None and batch % n == 0 and batch >= n) else None


def input_specs(cfg: ArchConfig, shape: Shape, pc: ParallelContext,
                dtype=jnp.bfloat16):
    """Returns (inputs SDS-tree, inputs specs-tree) for the cell's step fn.

    train:   {"inputs","labels"[, "embeds"]}
    prefill: {"tokens"[, "embeds"]}
    decode:  {"tokens", "caches", "cache_len"}
    """
    b, s = shape.global_batch, shape.seq_len
    bspec = batch_pspec(b, pc)
    i32 = jnp.int32

    if shape.kind in ("train", "prefill"):
        tree: Dict[str, Any] = {}
        specs: Dict[str, Any] = {}
        n_text = s
        if cfg.frontend == "vision":
            n_img = frontends.vision_prefix_len(s)
            n_text = s - n_img
            tree["embeds"] = SDS((b, n_img, cfg.d_model), dtype)
            specs["embeds"] = P(bspec, None, None)
        elif cfg.frontend == "audio":
            n_enc = min(cfg.enc_len, frontends.audio_frames_len(s) * 8)
            tree["embeds"] = SDS((b, n_enc, cfg.d_model), dtype)
            specs["embeds"] = P(bspec, None, None)
        key = "inputs" if shape.kind == "train" else "tokens"
        tree[key] = SDS((b, n_text), i32)
        specs[key] = P(bspec, None)
        if shape.kind == "train":
            tree["labels"] = SDS((b, s), i32)
            specs["labels"] = P(bspec, None)
        return tree, specs

    # decode: one new token + caches of length seq_len
    mod = model_module(cfg)
    caches = jax.eval_shape(
        lambda: mod.init_caches(cfg, pc, b, s, dtype))
    cspecs = mod.cache_specs(cfg, pc)
    # batch dim of caches may not shard when b < dp: drop DP axes, keep model
    if bspec is None:
        from repro.parallel.context import manual_only

        cspecs = jax.tree_util.tree_map(
            lambda sp: manual_only(sp, ("model",)), cspecs,
            is_leaf=lambda v: isinstance(v, P))
    tree = {"tokens": SDS((b, 1), i32), "caches": caches,
            "cache_len": SDS((), i32)}
    specs = {"tokens": P(bspec, None), "caches": cspecs, "cache_len": P()}
    return tree, specs
