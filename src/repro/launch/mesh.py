"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax device
state.  Single pod: (data=16, model=16) = 256 chips.  Multi-pod: 2 pods = 512
chips with a leading "pod" axis (pure-DP replica axis by default; the runtime
can regroup it as a PP axis for deeper jobs).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh

__all__ = ["make_production_mesh", "make_dev_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) == n:
        return make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for {shape} mesh, have {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (dryrun.py does this)")
    # more devices than needed (e.g. 512 placeholders, single-pod 256 mesh)
    import numpy as np
    from jax.sharding import Mesh

    arr = np.asarray(devices[:n]).reshape(shape)
    return Mesh(arr, axes)


def make_dev_mesh(n_model: int = None, n_data: int = None):
    """Small mesh over whatever devices exist (tests / examples / benchmarks)."""
    n = len(jax.devices())
    n_model = n_model or (2 if n >= 2 else 1)
    n_data = n_data or max(1, n // n_model)
    return make_mesh((1, n_data, n_model), ("pod", "data", "model"))
