"""Roofline-term derivation from compiled dry-run artifacts (TPU v5e model).

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

``compiled.cost_analysis()`` on the post-SPMD module reports *per-device*
flops/bytes (the module IS the per-device program); the assignment's
"HLO_FLOPs / (chips × peak)" is therefore applied with HLO_FLOPs per device.

collective_bytes is parsed from the optimized HLO text: for each collective op
we take its output payload and weight it by the ring traffic factor for its
replica-group size g (all-gather & reduce-scatter move (g-1)/g of the payload
per link hop; all-reduce = RS+AG = 2(g-1)/g; collective-permute & all-to-all
move the payload once).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

__all__ = ["HW", "parse_collective_bytes", "roofline_terms", "model_flops"]

HW = {
    "peak_flops": 197e12,  # bf16 TFLOP/s per chip (v5e)
    "hbm_bw": 819e9,  # B/s per chip
    "link_bw": 50e9,  # B/s per ICI link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?((?:[a-z0-9]+)\[[0-9,]*\][^)]*?)(?:\))?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{[0-9]+,[0-9]+\},?)+)\}")
_PAIR_RE = re.compile(r"\{([0-9]+),([0-9]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """Per-device collective bytes (ring-weighted) from post-SPMD HLO text.

    Returns (total_bytes, per-kind breakdown).  ``-start`` counted, ``-done``
    skipped.  collective-permutes are accounted **per link direction**: ICI
    links are full-duplex, so a bidirectional ring that splits its payload
    across the +1 and -1 directions loads each link with half the bytes — the
    busiest direction is what gates time.  Direction is classified from
    ``source_target_pairs`` (dst-src sign for the majority of pairs).
    """
    per_kind: Dict[str, float] = defaultdict(float)
    permute_dirs: Dict[int, float] = defaultdict(float)
    for line in hlo_text.splitlines():
        line = line.strip()
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        payload = _shape_bytes(shape_str)
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len([x for x in gm.group(1).split(",") if x.strip()])
        if kind == "all-gather":
            w = (g - 1) / g if g > 1 else 0.0
        elif kind == "reduce-scatter":
            w = (g - 1) if g > 1 else 0.0  # payload is post-scatter (1/g size)
        elif kind == "all-reduce":
            w = 2 * (g - 1) / g if g > 1 else 0.0
        elif kind == "collective-permute":
            pm = _PAIRS_RE.search(line)
            direction = 1
            if pm:
                votes = 0
                pairs = _PAIR_RE.findall(pm.group(1))
                for a, b in pairs[: min(8, len(pairs))]:
                    votes += 1 if int(b) > int(a) else -1
                direction = 1 if votes >= 0 else -1
            permute_dirs[direction] += payload
            per_kind[kind] += payload
            continue
        else:  # all-to-all
            w = (g - 1) / g if g > 1 else 0.0
        per_kind[kind] += payload * w
    # busiest permute direction gates time; other kinds assumed same-direction
    permute_link = max(permute_dirs.values()) if permute_dirs else 0.0
    non_permute = sum(v for k, v in per_kind.items()
                      if k != "collective-permute")
    return non_permute + permute_link, dict(per_kind)


def roofline_terms(cost: dict, collective_bytes: float) -> Dict[str, float]:
    """Three roofline terms (seconds) from per-device cost analysis."""
    flops = float(cost.get("flops", 0.0) or 0.0)
    byts = float(cost.get("bytes accessed", 0.0) or 0.0)
    return {
        "compute_s": flops / HW["peak_flops"],
        "memory_s": byts / HW["hbm_bw"],
        "collective_s": collective_bytes / HW["link_bw"],
        "flops": flops,
        "bytes": byts,
        "collective_bytes": collective_bytes,
    }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); D = tokens processed.

    train counts fwd+bwd (6ND); prefill counts 2ND; decode counts 2ND per
    generated token (D = batch tokens for the one step)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n_active * tokens


def dominant(terms: Dict[str, float]) -> str:
    keys = ("compute_s", "memory_s", "collective_s")
    return max(keys, key=lambda k: terms[k])
