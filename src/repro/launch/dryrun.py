import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# XLA:CPU's AllReducePromotion pass crashes cloning bf16 grad all-reduces
# (CPU-only numerics pass; irrelevant to the TPU target this dry-run models).
os.environ["XLA_FLAGS"] += " --xla_disable_hlo_passes=all-reduce-promotion"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes, print memory/cost analysis, and derive roofline terms.

The two lines above MUST stay first: jax locks the device count on first init.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k            # one cell
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all --out results/dryrun                   # all cells (subprocess each)
"""
import argparse
import json
import subprocess
import sys
import time

__all__ = ["run_cell", "main"]


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             mode: str = "overlap", remat: str = "dots", verbose: bool = True,
             extrapolate: bool = True, flow_dtype: str = "float32",
             order: str = "ring", channels: int = 1, attn_bf16: bool = False,
             moe_stream: bool = False):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, SHAPES
    from repro.launch.mesh import make_production_mesh
    from repro.launch import specs as S
    from repro.launch import roofline as R
    from repro.parallel.context import ParallelContext
    from repro.training.optimizer import AdamWConfig

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = S.cell_is_applicable(cfg, shape)
    result = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
              "mode": mode}
    if not ok:
        result.update(status="skipped", reason=why)
        if verbose:
            print(json.dumps(result))
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    from repro.core.channels import BlockChannel, CommSpec, CompSpec

    pc = ParallelContext(
        mesh=mesh, mode=mode, dp_axes=dp_axes, attn_p_bf16=attn_bf16,
        moe_decode_stream=moe_stream,
        channel=BlockChannel(axis="model", num_channels=channels,
                             comm=CommSpec(order=order),
                             comp=CompSpec(accum_dtype=flow_dtype)))
    result["variant"] = {"flow_dtype": flow_dtype, "order": order,
                         "channels": channels, "attn_bf16": attn_bf16,
                         "remat": remat, "moe_stream": moe_stream}

    def lower_for(cfg_, unroll):
        """Lower the cell's step function for a config variant."""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.training.steps import softmax_xent
        from repro.training.optimizer import apply_update

        mod = S.model_module(cfg_)
        params, pspecs = S.abstract_params(cfg_, pc)
        inputs, ispecs = S.input_specs(cfg_, shape, pc)
        def sh(tree):
            return jax.tree_util.tree_map(
                lambda sp_: NamedSharding(mesh, sp_), tree,
                is_leaf=lambda v: isinstance(v, P))

        if shape.kind == "train":
            opt, ospecs = S.abstract_opt_state(params, pspecs)

            def train_step(p, o, batch):
                def loss_fn(pp):
                    logits, aux = mod.forward(
                        pp, cfg_, pc, batch["inputs"],
                        embeds=batch.get("embeds"), remat_policy=remat,
                        unroll=unroll)
                    return softmax_xent(logits, batch["labels"]) + 0.01 * aux

                loss, grads = jax.value_and_grad(loss_fn)(p)
                p2, o2, m = apply_update(p, grads, o, AdamWConfig())
                return p2, o2, {"loss": loss, **m}

            jitted = jax.jit(
                train_step,
                in_shardings=(sh(pspecs), sh(ospecs), sh(ispecs)),
                out_shardings=(sh(pspecs), sh(ospecs), None),
                donate_argnums=(0, 1))
            return jitted.lower(params, opt, inputs)

        if shape.kind == "prefill":
            if cfg_.encoder_layers:
                def prefill_step(p, batch):
                    return mod.forward(p, cfg_, pc, batch["tokens"],
                                       embeds=batch.get("embeds"),
                                       unroll=unroll)
            else:
                def prefill_step(p, batch):
                    return mod.prefill(p, cfg_, pc, batch["tokens"],
                                       embeds=batch.get("embeds"),
                                       max_len=shape.seq_len, unroll=unroll)

            jitted = jax.jit(prefill_step,
                             in_shardings=(sh(pspecs), sh(ispecs)))
            return jitted.lower(params, inputs)

        def serve_step(p, batch):
            return mod.decode_step(p, batch["caches"], cfg_, pc,
                                   batch["tokens"], batch["cache_len"],
                                   unroll=unroll)

        jitted = jax.jit(serve_step,
                         in_shardings=(sh(pspecs), sh(ispecs)),
                         donate_argnums=(1,))
        return jitted.lower(params, inputs)

    def reduced_cfg(u):
        """Config variant with u scan units (prefix/suffix preserved)."""
        import dataclasses as dc
        from repro.models.lm import layer_plan
        if cfg.encoder_layers:
            return dc.replace(cfg, encoder_layers=u, n_layers=u)
        _, unit, _, suffix = layer_plan(cfg)
        k0 = cfg.moe.first_k_dense if cfg.moe else 0
        return dc.replace(cfg, n_layers=k0 + u * len(unit) + len(suffix))

    def analyze(compiled):
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else (cost or {})
        cb, ck = R.parse_collective_bytes(compiled.as_text())
        return {"flops": float(cost.get("flops", 0) or 0),
                "bytes": float(cost.get("bytes accessed", 0) or 0),
                "coll": cb, "kinds": ck}

    # 1) full-depth scanned compile -> memory analysis (true buffer liveness)
    t0 = time.time()
    lowered = lower_for(cfg, unroll=False)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()

    # 2) two unrolled reduced-depth compiles -> per-unit cost extrapolation
    #    (XLA cost analysis counts while bodies once, so scanned costs are
    #     depth-independent; unrolled variants expose the real per-unit cost)
    from repro.models.lm import layer_plan
    if cfg.encoder_layers:
        n_units = cfg.n_layers
    else:
        _, _, n_units, _ = layer_plan(cfg)
    if not extrapolate:
        # multi-pod pass is compile-success + memory proof; roofline terms are
        # reported from the single-pod table (assignment §ROOFLINE)
        result.update(
            status="ok", n_chips=512 if multi_pod else 256,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory={k: getattr(mem, k, None) for k in
                    ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes")} if mem is not None else None,
            extrapolated=False,
        )
        if verbose:
            print(json.dumps(result, default=str))
        return result

    u1, u2 = 1, 2
    c1 = analyze(lower_for(reduced_cfg(u1), unroll=True).compile())
    c2 = analyze(lower_for(reduced_cfg(u2), unroll=True).compile())

    def extrap(k):
        per_unit = c2[k] - c1[k]
        return c1[k] + (n_units - u1) * per_unit

    flops = extrap("flops")
    byts = extrap("bytes")
    coll = extrap("coll")
    kinds = {k: c1["kinds"].get(k, 0.0)
             + (n_units - u1) * (c2["kinds"].get(k, 0.0) - c1["kinds"].get(k, 0.0))
             for k in set(c1["kinds"]) | set(c2["kinds"])}

    terms = R.roofline_terms({"flops": flops, "bytes accessed": byts}, coll)
    n_chips = 512 if multi_pod else 256
    mf = R.model_flops(cfg, shape)
    useful = mf / max(flops * n_chips, 1.0)

    result.update(
        status="ok",
        n_chips=n_chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory={k: getattr(mem, k, None) for k in
                ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes")} if mem is not None else None,
        cost={"flops": flops, "bytes_accessed": byts,
              "per_unit_flops": c2["flops"] - c1["flops"], "n_units": n_units},
        collective_bytes=coll,
        collective_kinds=kinds,
        roofline={k: terms[k] for k in ("compute_s", "memory_s", "collective_s")},
        dominant=R.dominant(terms),
        model_flops=mf,
        useful_flops_ratio=round(useful, 4),
    )
    if verbose:
        print(json.dumps(result, default=str))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="overlap",
                    choices=["overlap", "baseline"])
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--flow-dtype", default="float32")
    ap.add_argument("--order", default="ring")
    ap.add_argument("--channels", type=int, default=1)
    ap.add_argument("--attn-bf16", action="store_true")
    ap.add_argument("--moe-stream", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    if not args.all:
        res = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                       mode=args.mode, remat=args.remat,
                       extrapolate=not args.multi_pod,
                       flow_dtype=args.flow_dtype, order=args.order,
                       channels=args.channels, attn_bf16=args.attn_bf16,
                       moe_stream=args.moe_stream)
        sys.exit(0 if res["status"] in ("ok", "skipped") else 1)

    # --all: one subprocess per cell (isolates compile memory; parallelizable)
    import itertools
    from repro.configs import ARCH_NAMES
    from repro.configs.base import SHAPES

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape, mp in itertools.product(
            ARCH_NAMES, SHAPES, (False, True)):
        tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}__{args.mode}"
        out_file = os.path.join(args.out, tag + ".json")
        if os.path.exists(out_file):
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mode", args.mode, "--remat", args.remat]
        if mp:
            cmd.append("--multi-pod")
        t0 = time.time()
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=3600)
        line = proc.stdout.strip().splitlines()[-1] if proc.stdout.strip() else ""
        try:
            res = json.loads(line)
        except json.JSONDecodeError:
            res = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "status": "error", "stderr": proc.stderr[-2000:]}
        with open(out_file, "w") as f:
            json.dump(res, f, indent=1)
        print(f"{tag}: {res['status']} ({time.time()-t0:.0f}s)")
        if res["status"] == "error":
            failures.append(tag)
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("all cells ok")


if __name__ == "__main__":
    main()
