"""Production training driver.

Wires together: config -> mesh (elastic) -> model init/shard -> data pipeline
-> jit'd train step (TileLink overlap on by default) -> async checkpointing ->
watchdog/straggler monitoring -> resilient restart loop.

Example (CPU dev run):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \\
      --steps 50 --batch 8 --seq 256 --reduce --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import model_module
from repro.parallel.context import ParallelContext
from repro.parallel.sharding import place
from repro.runtime import StepWatchdog, ElasticMesh
from repro.training import AdamWConfig, init_opt_state, make_train_step

__all__ = ["train", "reduce_config", "main"]


def reduce_config(cfg, d_model=128, vocab=512):
    """Reduced same-family config for CPU dev/smoke runs."""
    kw = dict(
        n_layers=len(cfg.pattern) * 2 + (cfg.moe.first_k_dense if cfg.moe else 0),
        d_model=d_model, vocab_size=vocab)
    if cfg.n_heads:
        kw.update(n_heads=8, n_kv_heads=min(cfg.n_kv_heads, 4), head_dim=16)
    if cfg.d_ff:
        kw.update(d_ff=d_model * 2)
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=min(2, cfg.moe.top_k), d_expert=64,
            dense_d_ff=d_model * 2)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, headdim=16, chunk=16)
    if cfg.encoder_layers:
        kw.update(encoder_layers=2, enc_len=32)
    return dataclasses.replace(cfg, **kw)


def train(arch: str, *, steps=100, batch=8, seq=256, reduce=True,
          mode="overlap", ckpt_dir=None, ckpt_every=50, lr=3e-4,
          production_mesh=False, dtype=jnp.float32, log_every=10,
          resume=True):
    cfg = get_config(arch)
    if reduce:
        cfg = reduce_config(cfg)
    mod = model_module(cfg)

    elastic = ElasticMesh(target_model=16 if production_mesh else 2)
    mesh, usable = (make_production_mesh(), 256) if production_mesh else elastic.build()
    pc = ParallelContext(mesh=mesh, mode=mode)

    params = mod.init(jax.random.PRNGKey(0), cfg, pc, dtype)
    pspecs = mod.specs(cfg, pc)
    params = place(params, mesh, pspecs)
    opt_state = init_opt_state(params)
    opt_state = place(opt_state, mesh,
                      {"mu": pspecs, "nu": pspecs, "step": jax.sharding.PartitionSpec()})

    opt_cfg = AdamWConfig(lr=lr, total_steps=steps, warmup_steps=max(5, steps // 20))
    masks = mod.grad_masks(cfg, pc)
    step_fn = make_train_step(mod, cfg, pc, opt_cfg, remat_policy="dots",
                              grad_masks=masks)

    pipe = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start = 0
    if mgr and resume and mgr.latest_step() is not None:
        s0 = mgr.latest_step()
        (restored, meta) = mgr.restore(
            s0, {"params": params, "opt": opt_state}, mesh,
            {"params": pspecs,
             "opt": {"mu": pspecs, "nu": pspecs,
                     "step": jax.sharding.PartitionSpec()}})
        params, opt_state = restored["params"], restored["opt"]
        pipe.restore(meta["extra"]["data"])
        start = s0
        print(f"resumed from step {s0}")

    wd = StepWatchdog()
    losses = []
    for step in range(start, steps):
        batch_np = pipe.host_batch()
        wd.start()
        params, opt_state, metrics = step_fn(params, opt_state, batch_np)
        straggler = wd.stop()
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step}: loss={losses[-1]:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"med_step={wd.median()*1e3:.0f}ms"
                  + (" [STRAGGLER]" if straggler else ""))
        if mgr and ckpt_every and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, params, opt_state,
                     extra={"data": pipe.state(), "arch": arch})
    if mgr:
        mgr.save(steps, params, opt_state,
                 extra={"data": pipe.state(), "arch": arch})
        mgr.wait()
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mode", default="overlap", choices=["overlap", "baseline"])
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--full", dest="reduce", action="store_false")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    losses = train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
                   reduce=args.reduce, mode=args.mode, ckpt_dir=args.ckpt_dir,
                   ckpt_every=args.ckpt_every, lr=args.lr,
                   production_mesh=args.production_mesh)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
