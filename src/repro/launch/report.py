"""Aggregate dry-run JSONs into the EXPERIMENTS.md §Roofline table.

Usage: PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun]
"""
import argparse
import glob
import json
import os

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_t(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def fmt_b(x):
    if x is None:
        return "-"
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def load(dirname):
    cells = {}
    for f in glob.glob(os.path.join(dirname, "*.json")):
        r = json.load(open(f))
        key = (r["arch"], r["shape"], "mp" if r["multi_pod"] else "sp",
               r.get("mode", "overlap"))
        cells[key] = r
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mode", default="overlap")
    args = ap.parse_args()
    cells = load(args.dir)
    archs = sorted({k[0] for k in cells})

    print("| arch | shape | compute | memory | collective | dominant | "
          "useful-FLOPs | mem/dev | mp-512 |")
    print("|---|---|---|---|---|---|---|---|---|")
    n_ok = n_skip = 0
    for arch in archs:
        for shape in SHAPE_ORDER:
            sp = cells.get((arch, shape, "sp", args.mode))
            mp = cells.get((arch, shape, "mp", args.mode))
            if sp is None:
                continue
            if sp["status"] == "skipped":
                n_skip += 1
                print(f"| {arch} | {shape} | — | — | — | skipped "
                      f"({sp['reason'][:40]}…) | — | — | "
                      f"{'skip' if mp and mp['status']=='skipped' else '?'} |")
                continue
            n_ok += 1
            r = dict(sp["roofline"])
            # uniform accounting across all cells: total per-kind byte sums
            # (per-direction refinement only stored for later cells)
            r["collective_s"] = sum(sp.get("collective_kinds", {}).values()) / 50e9
            mem = sp.get("memory") or {}
            # temp is whole-program on the CPU backend; /chips for per-device
            per_dev = None
            if mem.get("temp_size_in_bytes") is not None:
                per_dev = (mem["temp_size_in_bytes"] / sp["n_chips"]
                           + (mem.get("argument_size_in_bytes") or 0))
            mp_s = "-"
            if mp is not None:
                mp_s = "ok" if mp["status"] == "ok" else mp["status"]
            print(f"| {arch} | {shape} | {fmt_t(r['compute_s'])} | "
                  f"{fmt_t(r['memory_s'])} | {fmt_t(r['collective_s'])} | "
                  f"{sp['dominant'].replace('_s','')} | "
                  f"{sp['useful_flops_ratio']:.2f} | {fmt_b(per_dev)} | {mp_s} |")
    print(f"\n{n_ok} baselined cells, {n_skip} skipped "
          f"(long_500k on pure full-attention archs).")


if __name__ == "__main__":
    main()
