"""Serving driver: load (or init) a model and serve batched requests.

Example (CPU dev run):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduce \\
      --prompt-len 16 --new-tokens 16 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_dev_mesh
from repro.launch.train import reduce_config
from repro.models import lm
from repro.parallel.context import ParallelContext
from repro.parallel.sharding import place
from repro.serving import ServeEngine
from repro.checkpoint import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--mode", default="overlap", choices=["overlap", "baseline"])
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduce_config(cfg)
    if cfg.encoder_layers:
        raise SystemExit("serve.py drives decoder-only archs; enc-dec decode "
                         "is exercised in tests/test_models.py")
    mesh = make_dev_mesh()
    pc = ParallelContext(mesh=mesh, mode=args.mode)
    params = place(lm.init(jax.random.PRNGKey(0), cfg, pc, jnp.float32),
                   mesh, lm.specs(cfg, pc))
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        s0 = mgr.latest_step()
        if s0 is not None:
            (restored, _) = mgr.restore(s0, {"params": params, "opt": None})
            params = place(restored["params"], mesh, lm.specs(cfg, pc))
            print(f"loaded checkpoint step {s0}")

    engine = ServeEngine(cfg, pc, params,
                         max_len=args.prompt_len + args.new_tokens,
                         temperature=args.temperature)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(args.batch, args.prompt_len), dtype=np.int32)
    t0 = time.time()
    out = engine.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print("sample:", out[0, args.prompt_len:].tolist())


if __name__ == "__main__":
    main()
