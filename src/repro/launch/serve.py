"""Serving driver: load (or init) a model and serve continuous-batching
requests through the request-level engine.

Example (CPU dev run):
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduce \\
      --prompt-len 16 --new-tokens 16 --batch 4 --slots 2 --temperature 0.7
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_dev_mesh
from repro.launch.train import reduce_config
from repro.models import lm
from repro.parallel.context import ParallelContext
from repro.parallel.sharding import place
from repro.serving import Request, ServeEngine
from repro.checkpoint import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--mode", default="overlap", choices=["overlap", "baseline"])
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation (0 = full vocab)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop a request early when this token is sampled")
    ap.add_argument("--slots", type=int, default=8,
                    help="batch slots in the KV-cache pool; requests beyond "
                         "this queue and admit as slots free up")
    ap.add_argument("--decode-block", type=int, default=32,
                    help="max tokens decoded on device per step (one host "
                         "sync per step regardless)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduce_config(cfg)
    if cfg.encoder_layers:
        raise SystemExit("serve.py drives decoder-only archs; enc-dec decode "
                         "is exercised in tests/test_models.py")
    mesh = make_dev_mesh()
    pc = ParallelContext(mesh=mesh, mode=args.mode)
    params = place(lm.init(jax.random.PRNGKey(0), cfg, pc, jnp.float32),
                   mesh, lm.specs(cfg, pc))
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        s0 = mgr.latest_step()
        if s0 is not None:
            (restored, _) = mgr.restore(s0, {"params": params, "opt": None})
            params = place(restored["params"], mesh, lm.specs(cfg, pc))
            print(f"loaded checkpoint step {s0}")

    engine = ServeEngine(cfg, pc, params,
                         max_len=args.prompt_len + args.new_tokens,
                         temperature=args.temperature,
                         n_slots=args.slots, decode_block=args.decode_block)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(args.batch, args.prompt_len), dtype=np.int32)
    handles = [
        engine.submit(Request(tokens=row, max_new_tokens=args.new_tokens,
                              temperature=args.temperature, top_k=args.top_k,
                              eos_id=args.eos_id, seed=args.seed + i))
        for i, row in enumerate(prompts)
    ]
    t0 = time.time()
    outs = engine.drain(handles)
    dt = time.time() - t0
    n_tok = sum(len(outs[h]) for h in handles)
    st = engine.stats
    print(f"generated {n_tok} tokens over {args.batch} requests in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s; {st['steps']} steps, "
          f"{st['host_syncs']} host syncs, {st['step_traces']} trace)")
    print("sample:", outs[handles[0]].tolist())


if __name__ == "__main__":
    main()
