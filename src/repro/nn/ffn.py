"""Dense (gated) MLP block — the paper's motivational workload.

Forward = AG+GEMM (gate/up fused, column-parallel) -> activation ->
GEMM+RS (down, row-parallel): exactly the tensor-parallel MLP of paper Fig. 1.
In overlap mode both collectives lower through ``compile_overlap`` as tile
plans run by the generic schedule executor, so the layer inherits whatever
tile order / channel count / accum dtype / wire encoding ``pc.channel``
selects — or, with
``apply_seq(..., tune=True)``, whatever the ``repro.tune`` autotuner picks
per (kind, shape) on this mesh.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn.layers import rms_norm, he_init, ACTS

__all__ = ["init", "specs", "apply_seq", "apply_decode", "seam_proj"]


def init(key, cfg, tp: int, dtype=jnp.bfloat16, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2 = jax.random.split(key)
    return {
        "ln": jnp.zeros((d,), dtype),
        "w_gu": he_init(k1, (d, 2 * f), dtype, fan_in=d),
        "w_down": he_init(k2, (f, d), dtype, fan_in=f),
    }


def specs(cfg, tp: int, dp) -> dict:
    return {"ln": P(None), "w_gu": P(dp, "model"), "w_down": P("model", dp)}


def _act(cfg):
    return ACTS[cfg.act]


def seam_proj(params, cfg):
    """(glue, w) pair for fusing an upstream RS into THIS block's gate/up AG.

    ``glue`` maps the upstream residual output to this block's AG input (the
    pre-MLP rms_norm); ``w`` is the column-parallel gate/up weight.  Pass the
    pair as the upstream op's ``next_proj`` and feed the fused output back in
    as this block's ``gu``.
    """
    return (lambda y: rms_norm(y, params["ln"], cfg.norm_eps)), params["w_gu"]


def apply_seq(params, x, pc, cfg, *, tune=False, quant=None, gu=None,
              next_proj=None, ep=None):
    """x: [B, s_loc, D] -> [B, s_loc, D] (+residual). Inside manual region.

    Per-shard w_gu is [D, 2*f_loc] with gate|up halves interleaved per shard
    (column-parallel), so the activation is local.  ``tune=True`` lets each
    collective op resolve its own autotuned BlockChannel (repro.tune).
    ``quant`` pins a :class:`~repro.core.quant.QuantSpec` wire encoding on
    this block's collectives (or ``"auto"`` opens the int8 wire axis under
    ``tune=True``) — see ``ParallelContext.quant``.
    ``ep`` is accepted for keyword-surface symmetry across the nn blocks but
    must be falsy: a dense MLP has no expert-parallel form.

    Inter-op seam fusion (``pc.fuse_seams``): ``gu`` is this layer's gate/up
    projection already produced by the UPSTREAM op's fused RS->AG ring pass
    (skips the local norm + AG here); ``next_proj=(glue, w)`` asks this layer
    to fuse its down-proj RS with the NEXT consumer's AG over one shared ring
    pass — ``glue`` maps the full residual output to the consumer's AG input
    (e.g. the next layer norm) and ``w`` is the consumer's per-shard weight.
    With ``next_proj`` the return value is ``(y, next_out)``.
    """
    if ep:
        raise ValueError(
            "ffn.apply_seq has no expert-parallel form; ep= selects the "
            "dispatch/combine a2a in moe.apply_seq only")
    if tune and not pc.tune:
        pc = dataclasses.replace(pc, tune=True)
    if quant is not None and pc.quant != quant:
        pc = dataclasses.replace(pc, quant=quant)
    if gu is None:
        h = rms_norm(x, params["ln"], cfg.norm_eps)
        gu = pc.ag_matmul(h, params["w_gu"])  # AG + GEMM  [B, S, 2*f_loc]
    f_loc = gu.shape[-1] // 2
    a = _act(cfg)(gu[..., :f_loc]) * gu[..., f_loc:]
    a = a.astype(x.dtype)
    if next_proj is None:
        out = pc.matmul_rs(a, params["w_down"])  # GEMM + RS
        return x + out
    glue, w_next = next_proj
    # fused seam: down-proj RS flows into the consumer's AG in one ring pass
    return pc.matmul_rs_ag(a, params["w_down"], w_next, residual=x, glue=glue)


def apply_decode(params, x, pc, cfg):
    """x: [B, 1, D] replicated over model. Local matmuls + psum epilogue."""
    h = rms_norm(x, params["ln"], cfg.norm_eps)
    gu = jnp.einsum("bsd,df->bsf", h, params["w_gu"])
    f_loc = gu.shape[-1] // 2
    a = _act(cfg)(gu[..., :f_loc]) * gu[..., f_loc:]
    out = pc.psum(jnp.einsum("bsf,fd->bsd", a.astype(x.dtype), params["w_down"]))
    return x + out
