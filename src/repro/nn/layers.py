"""Primitive layers (pure JAX, params-as-pytrees) + TP layout helpers."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm", "layer_norm", "rope", "seq_flat", "seq_unflat",
    "he_init", "emb_init", "GQALayout", "gqa_layout", "cdiv", "ACTS",
]

ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = x32.var(-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


def rope(q, k, positions, theta: float = 1e4):
    """Rotary embedding. q/k: [..., S, n_heads, hd]; positions: [S] or [B, S]."""
    hd = q.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast over heads: [..., S, 1, hd/2]
    cos, sin = cos[..., None, :], sin[..., None, :]

    def rot(x):
        x1, x2 = x[..., ::2], x[..., 1::2]
        xr = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
        return xr.reshape(x.shape).astype(x.dtype)

    return rot(q), rot(k)


def seq_flat(x):
    """[B, s, D] -> [s*B, D] (sequence-major rows, so ring AG/RS chunks are
    contiguous global-sequence segments)."""
    b, s, d = x.shape
    return x.transpose(1, 0, 2).reshape(s * b, d)


def seq_unflat(x, b: int):
    """[S*B, N] -> [B, S, N]."""
    sb, n = x.shape
    s = sb // b
    return x.reshape(s, b, n).transpose(1, 0, 2)


def he_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan = fan_in or shape[-2] if len(shape) >= 2 else shape[-1]
    return (jax.random.normal(key, shape) * (1.0 / math.sqrt(fan))).astype(dtype)


def emb_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


@dataclasses.dataclass(frozen=True)
class GQALayout:
    """TP layout for (possibly awkward) GQA head counts on a fixed TP degree.

    h_pad:    q heads padded to a multiple of tp (pad heads grad-masked to 0)
    h_loc:    q heads per rank
    kv_pad:   kv heads padded to a divisor-or-multiple alignment of tp
    kv_loc:   kv heads per rank
    rep:      ranks sharing one kv head (kv weights stored expanded with `rep`
              identical copies; gradients group-averaged to keep them in sync)
    kv_store: stored kv head count (= kv_pad * 1 if rep == 1 else tp)
    """

    n_heads: int
    n_kv: int
    tp: int
    h_pad: int
    h_loc: int
    kv_pad: int
    kv_loc: int
    rep: int
    kv_store: int


def gqa_layout(n_heads: int, n_kv: int, tp: int) -> GQALayout:
    if n_kv >= tp:
        # pad kv up to a multiple of tp
        kv_pad = cdiv(n_kv, tp) * tp
        kv_loc = kv_pad // tp
        rep = 1
        kv_store = kv_pad
    else:
        # smallest divisor of tp that is >= n_kv
        kv_pad = next(d for d in range(n_kv, tp + 1) if tp % d == 0)
        rep = tp // kv_pad
        kv_loc = 1
        kv_store = tp  # expanded: rep identical copies per kv head
    # pad q heads so every rank's heads align to whole local kv groups
    h_pad = cdiv(n_heads, tp * kv_loc) * tp * kv_loc
    h_loc = h_pad // tp
    return GQALayout(n_heads, n_kv, tp, h_pad, h_loc, kv_pad, kv_loc, rep, kv_store)


def sync_kv_grad(g, layout: GQALayout, axis: int = -1):
    """Average the `rep` expanded copies of each kv head's gradient (global)."""
    if layout.rep == 1:
        return g
    shape = g.shape
    hd2 = shape[axis] // layout.kv_store
    g = jnp.moveaxis(g, axis, -1)
    lead = g.shape[:-1]
    g = g.reshape(*lead, layout.kv_pad, layout.rep, hd2)
    g = jnp.broadcast_to(g.mean(axis=-2, keepdims=True), g.shape)
    g = g.reshape(*lead, layout.kv_store * hd2)
    return jnp.moveaxis(g, -1, axis)
