"""GQA attention block — TP over heads, sequence-parallel residual stream.

Train/prefill path (``apply_seq``): the AG+GEMM producer gathers the
sequence-sharded residual stream while projecting to this rank's heads (the
paper's AG+GEMM), attention runs locally on the head shard with a
memory-efficient chunked online-softmax (differentiable), and the output
projection is the GEMM+RS consumer (paper Fig. 4).  Both collectives lower
through ``compile_overlap`` as tile plans, so the tile order / channel count /
accum dtype / wire encoding selected by ``pc.channel`` apply here uniformly.

Decode path (``apply_decode``): activations are replicated over the TP axis;
projections are local column/row-parallel matmuls with a psum epilogue, and the
KV cache is sharded over heads.

Awkward GQA head counts (kv < tp, non-dividing heads) are handled by the
GQALayout padding/replication scheme in nn/layers.py; padded weights are
grad-masked so semantics match the unpadded architecture exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.nn.layers import (
    rms_norm, rope, he_init, gqa_layout, GQALayout,
)

__all__ = [
    "init", "specs", "grad_masks", "apply_seq", "apply_seq_ring", "apply_decode",
    "init_cache", "chunked_attention", "seam_proj",
]


def _lay(cfg, tp) -> GQALayout:
    return gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)


def init(key, cfg, tp: int, dtype=jnp.bfloat16):
    lay = _lay(cfg, tp)
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    # orig-shaped kv weights, expanded with `rep` identical copies
    wkv_orig = he_init(ks[1], (d, lay.kv_pad, 2 * hd), dtype, fan_in=d)
    # zero the padded kv heads (stay zero via grad masks)
    kv_mask = (jnp.arange(lay.kv_pad) < cfg.n_kv_heads)[None, :, None]
    wkv_orig = wkv_orig * kv_mask
    wkv = jnp.repeat(wkv_orig, lay.rep, axis=1).reshape(d, lay.kv_store * 2 * hd)

    head_active = jnp.arange(lay.h_pad) < cfg.n_heads
    wq = he_init(ks[0], (d, lay.h_pad, hd), dtype, fan_in=d)
    wq = (wq * head_active[None, :, None]).reshape(d, lay.h_pad * hd)

    wo = he_init(ks[2], (lay.h_pad, hd, d), dtype, fan_in=lay.h_pad * hd)
    wo = (wo * head_active[:, None, None]).reshape(lay.h_pad * hd, d)
    p = {"ln": jnp.zeros((d,), dtype), "wq": wq, "wkv": wkv, "wo": wo}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((lay.h_pad * hd,), dtype)
        p["bkv"] = jnp.zeros((lay.kv_store * 2 * hd,), dtype)
    return p


def specs(cfg, tp: int, dp) -> dict:
    s = {
        "ln": P(None),
        "wq": P(dp, "model"),
        "wkv": P(dp, "model"),
        "wo": P("model", dp),
    }
    if cfg.qkv_bias:
        s["bq"] = P("model")
        s["bkv"] = P("model")
    return s


def grad_masks(cfg, tp: int):
    """0/1 masks keeping padded heads at zero. None entries = no mask."""
    lay = _lay(cfg, tp)
    hd = cfg.hd
    if lay.h_pad == cfg.n_heads and lay.kv_pad == cfg.n_kv_heads:
        return None
    qm = jnp.repeat((jnp.arange(lay.h_pad) < cfg.n_heads), hd).astype(jnp.float32)
    kv_head_active = jnp.arange(lay.kv_store) // lay.rep < cfg.n_kv_heads
    kvm = jnp.repeat(kv_head_active, 2 * hd).astype(jnp.float32)
    m = {
        "ln": None,
        "wq": qm[None, :],
        "wkv": kvm[None, :],
        "wo": qm[:, None],
    }
    if cfg.qkv_bias:
        m["bq"] = qm
        m["bkv"] = kvm
    return m


def chunked_attention(q, k, v, *, causal=True, window: Optional[int] = None,
                      chunk: int = 1024, q_offset=0, scale: Optional[float] = None,
                      p_bf16: bool = False):
    """Memory-efficient online-softmax attention (differentiable).

    q: [B, H, Sq, hd]; k/v: [B, Hkv, Sk, hd] with H % Hkv == 0.
    Scans KV chunks with a rematerialized per-chunk body: O(Sq * chunk) live
    memory forward and backward.
    """
    b, h, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else hd ** -0.5
    chunk = min(chunk, sk)
    assert sk % chunk == 0
    nc = sk // chunk

    q32 = (q * scale).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(sq)

    kc = k.reshape(b, hkv, nc, chunk, hd)
    vc = v.reshape(b, hkv, nc, chunk, hd)

    @jax.checkpoint
    def body(carry, kj, vj, cidx):
        m_i, l_i, o_i = carry
        if rep > 1:
            kj = jnp.repeat(kj, rep, axis=1)
            vj = jnp.repeat(vj, rep, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", q32, kj.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        k_pos = cidx * chunk + jnp.arange(chunk)
        mask = None
        if causal:
            mask = q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            wm = (q_pos[:, None] - k_pos[None, :]) < window
            mask = wm if mask is None else mask & wm
        if mask is not None:
            s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m_i, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + p.sum(-1, keepdims=True)
        if p_bf16:
            # §Perf: P in bf16 halves the attention matmul's HBM reads; the
            # P@V product still accumulates in fp32 on the MXU
            pv = jnp.einsum("bhqk,bhkd->bhqd", p.astype(jnp.bfloat16),
                            vj.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
        else:
            pv = jnp.einsum("bhqk,bhkd->bhqd", p, vj.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
        o_new = o_i * alpha + pv
        return (m_new, l_new, o_new)

    # python (unrolled) chunk loop: per-chunk rematerialized bodies; unrolled
    # (rather than lax.scan) so per-chunk compute is visible to HLO cost
    # analysis (while bodies are counted once regardless of trip count) and so
    # fully-masked chunks can be skipped statically (causal/sliding-window).
    m_i = jnp.full((b, h, sq, 1), -1e30, jnp.float32)
    l_i = jnp.zeros((b, h, sq, 1), jnp.float32)
    o_i = jnp.zeros((b, h, sq, hd), jnp.float32)
    carry = (m_i, l_i, o_i)
    q_lo = int(q_offset) if isinstance(q_offset, int) else None
    for ci in range(nc):
        if q_lo is not None:
            k_lo, k_hi = ci * chunk, (ci + 1) * chunk - 1
            if causal and k_lo > q_lo + sq - 1:
                continue  # chunk entirely in the future
            if window is not None and (q_lo - k_hi) >= window:
                continue  # chunk entirely outside the window
        carry = body(carry, kc[:, :, ci], vc[:, :, ci], ci)
    m_f, l_f, o_f = carry
    return (o_f / jnp.maximum(l_f, 1e-30)).astype(q.dtype)


def seam_proj(params, cfg):
    """(glue, w) pair for fusing an upstream RS into THIS layer's qkv AG.

    ``glue`` maps the upstream residual output to this layer's AG input (the
    pre-attention rms_norm); ``w`` is the fused qkv per-shard weight — the
    same concat :func:`_project_qkv` uses.  Bias stays local in the consumer.
    """
    w = jnp.concatenate([params["wq"], params["wkv"]], axis=1)
    return (lambda y: rms_norm(y, params["ln"], cfg.norm_eps)), w


def _project_qkv(params, h, pc, lay, hd, qkv=None):
    """Shared AG+GEMM producer for q and kv projections.

    h: [B, s_loc, D] -> q/k/v as [B, S, n, hd] (full gathered sequence).
    ``qkv`` is the already-gathered projection from an upstream fused RS->AG
    seam (pre-bias), skipping the AG+GEMM here."""
    if qkv is None:
        w = jnp.concatenate([params["wq"], params["wkv"]], axis=1)
        qkv = pc.ag_matmul(h, w)  # [B, S, (h_loc + 2*kv_loc)*hd]
    if "bq" in params:
        bias = jnp.concatenate([params["bq"], params["bkv"]])
        qkv = qkv + bias
    b, s_glob = qkv.shape[0], qkv.shape[1]
    qkv = qkv.reshape(b, s_glob, lay.h_loc + 2 * lay.kv_loc, hd)
    q = qkv[:, :, : lay.h_loc]
    k = qkv[:, :, lay.h_loc: lay.h_loc + lay.kv_loc]
    v = qkv[:, :, lay.h_loc + lay.kv_loc:]
    return q, k, v, s_glob


def apply_seq(params, x, pc, cfg, *, causal=True, window=None,
              rope_theta=None, attn_chunk=1024, return_kv=False, tune=False,
              quant=None, qkv=None, next_proj=None, ep=None):
    """Full-sequence attention block body (call inside pc.smap manual region).

    x: [B, s_loc, D] sequence-sharded. Returns [B, s_loc, D] (residual added);
    with ``return_kv``, also the per-shard KV in cache layout
    [B, kv_loc, S, hd] (prefill-into-cache).  ``tune=True`` lets the AG+GEMM
    and GEMM+RS collectives resolve autotuned BlockChannels (repro.tune);
    ``quant`` pins a QuantSpec wire encoding (or ``"auto"`` opens the int8
    wire axis under ``tune=True``) — see ``ParallelContext.quant``.

    Inter-op seam fusion (``pc.fuse_seams``): ``qkv`` is this layer's fused
    qkv projection already produced by the upstream op's RS->AG ring pass
    (see :func:`seam_proj`); ``next_proj=(glue, w)`` fuses the output-proj RS
    with the next consumer's AG over one shared ring pass, changing the
    return value to ``(y, next_out)`` (with ``return_kv``: ``(y, next_out,
    kv)``).  ``ep`` is accepted for keyword-surface symmetry across the nn
    blocks but must be falsy: attention has no expert-parallel form.
    """
    if ep:
        raise ValueError(
            "attention.apply_seq has no expert-parallel form; ep= selects "
            "the dispatch/combine a2a in moe.apply_seq only")
    if tune and not pc.tune:
        pc = dataclasses.replace(pc, tune=True)
    if quant is not None and pc.quant != quant:
        pc = dataclasses.replace(pc, quant=quant)
    lay = _lay(cfg, pc.tp)
    hd = cfg.hd
    b = x.shape[0]
    h = None if qkv is not None else rms_norm(x, params["ln"], cfg.norm_eps)
    q, k, v, s_glob = _project_qkv(params, h, pc, lay, hd, qkv=qkv)

    positions = jnp.arange(s_glob)
    q, k = rope(q, k, positions,
                rope_theta if rope_theta is not None else cfg.rope_theta)
    # [b, S, n, hd] -> [b, n, S, hd]
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    o = chunked_attention(q, k, v, causal=causal, window=window,
                          chunk=min(attn_chunk, s_glob), p_bf16=pc.attn_p_bf16)
    o_flat = o.transpose(0, 2, 1, 3).reshape(b, s_glob, lay.h_loc * hd)
    if next_proj is not None:
        glue, w_next = next_proj
        y, nxt = pc.matmul_rs_ag(o_flat, params["wo"], w_next,
                                 residual=x, glue=glue)
        if return_kv:
            return y, nxt, {"k": k, "v": v}
        return y, nxt
    out = pc.matmul_rs(o_flat, params["wo"])  # [B, s_loc, D]
    y = x + out
    if return_kv:
        return y, {"k": k, "v": v}
    return y


def apply_seq_ring(params, x, pc, cfg, *, causal=True, window=None,
                   rope_theta=None, tune=False, quant=None, next_proj=None,
                   ep=None):
    """AG-Q + ring-KV attention block body (paper Fig. 6 layer form).

    Where :func:`apply_seq` gathers the WHOLE qkv projection through the
    AG+GEMM producer and attends on fully-resident KV, this path gathers
    only the (narrow) query projection; K/V project LOCALLY on the sequence
    shard and stay resident while their tiles rotate through
    ``pc.ring_attention`` — the overlapped AG-KV + online-softmax tile plan,
    whose consumer honors the CompSpec tile as (block_q, block_kv).  Every
    rank attends the full query range with its local heads, so the output
    projection is the same GEMM+RS consumer as :func:`apply_seq`.
    x: [B, s_loc, D] -> [B, s_loc, D] (residual added).  ``tune=True``
    resolves each collective's BlockChannel (including the attention compute
    tile) per shape via repro.tune; results match :func:`apply_seq` up to fp
    reassociation.

    MQA (``kv_pad == 1``) rings the one shared head's local projection
    directly.  GQA rings per KV group: every rank gathers the (narrow)
    ``wkv`` columns once, dedupes the GQALayout's replicated copies, and
    projects the FULL distinct-KV width on its sequence shard — the rotating
    tiles then carry every group, and ``pc.ring_attention(kv_select=True)``
    has each rank's online softmax consume only the group its local query
    heads map to.  The extra wire per tile is ``kv_pad``-fold, still far
    below the ``h``-wide AG of :func:`apply_seq`.
    """
    if ep:
        raise ValueError(
            "attention.apply_seq_ring has no expert-parallel form; ep= "
            "selects the dispatch/combine a2a in moe.apply_seq only")
    if tune and not pc.tune:
        pc = dataclasses.replace(pc, tune=True)
    if quant is not None and pc.quant != quant:
        pc = dataclasses.replace(pc, quant=quant)
    lay = _lay(cfg, pc.tp)
    hd = cfg.hd
    b, s_loc, _ = x.shape
    h = rms_norm(x, params["ln"], cfg.norm_eps)

    q = pc.ag_matmul(h, params["wq"])  # [B, S, h_loc*hd] gathered
    if "bq" in params:
        q = q + params["bq"]
    if lay.kv_pad == 1:
        kv = jnp.einsum("bsd,dn->bsn", h, params["wkv"])  # local shared head
        if "bkv" in params:
            kv = kv + params["bkv"]
        kv = kv.reshape(b, s_loc, 2 * lay.kv_loc, hd)
        k = kv[:, :, : lay.kv_loc]
        v = kv[:, :, lay.kv_loc:]
    else:
        # per-KV-group ring: project all kv_pad distinct groups locally.
        # Per-rank wkv columns pack [K heads (kv_loc*hd) || V heads], so the
        # gather is rank-major: reshape, split k/v, then flatten the
        # (rank, local-head) axes back into the global expanded head order.
        wkv = pc.all_gather_seq(params["wkv"], 1)  # [D, tp * 2*kv_loc*hd]
        wkv = wkv.reshape(cfg.d_model, pc.tp, 2, lay.kv_loc, hd)
        wk = wkv[:, :, 0].reshape(cfg.d_model, lay.kv_store, hd)
        wv = wkv[:, :, 1].reshape(cfg.d_model, lay.kv_store, hd)
        if lay.rep > 1:
            wk = wk[:, :: lay.rep]  # drop the replicated copies
            wv = wv[:, :: lay.rep]
        k = jnp.einsum("bsd,dhe->bshe", h, wk)  # [B, s_loc, kv_pad, hd]
        v = jnp.einsum("bsd,dhe->bshe", h, wv)
        if "bkv" in params:
            bkv = pc.all_gather_seq(params["bkv"], 0)
            bkv = bkv.reshape(pc.tp, 2, lay.kv_loc, hd)
            bk = bkv[:, 0].reshape(lay.kv_store, hd)
            bv = bkv[:, 1].reshape(lay.kv_store, hd)
            if lay.rep > 1:
                bk, bv = bk[:: lay.rep], bv[:: lay.rep]
            k = k + bk
            v = v + bv
    s_glob = q.shape[1]
    q = q.reshape(b, s_glob, lay.h_loc, hd)

    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    q, _ = rope(q, q, jnp.arange(s_glob), theta)
    k_pos = pc.axis_index() * s_loc + jnp.arange(s_loc)  # global KV positions
    _, k = rope(k, k, k_pos, theta)
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    o = pc.ring_attention(q, k, v, causal=causal, window=window,
                          kv_select=lay.kv_pad > 1)
    o_flat = o.transpose(0, 2, 1, 3).reshape(b, s_glob, lay.h_loc * hd)
    if next_proj is not None:
        glue, w_next = next_proj
        # fused epilogue: output-proj RS feeds the next consumer's AG
        return pc.matmul_rs_ag(o_flat, params["wo"], w_next,
                               residual=x, glue=glue)
    out = pc.matmul_rs(o_flat, params["wo"])  # [B, s_loc, D]
    return x + out


def apply_cross_seq(params, x, enc, pc, cfg):
    """Cross-attention (enc-dec): queries from x, keys/values from enc.

    x: [B, s_loc, D] (dec seq-sharded), enc: [B, se_loc, D] (enc seq-sharded).
    No rope, non-causal. Inside manual region.
    """
    lay = _lay(cfg, pc.tp)
    hd = cfg.hd
    b = x.shape[0]
    h = rms_norm(x, params["ln"], cfg.norm_eps)

    q = pc.ag_matmul(h, params["wq"])  # [B, Sd, h_loc*hd]
    kv = pc.ag_matmul(enc, params["wkv"])  # [B, Se, kv_loc*2hd]
    if "bq" in params:
        q = q + params["bq"]
        kv = kv + params["bkv"]
    sd, se = q.shape[1], kv.shape[1]
    q = q.reshape(b, sd, lay.h_loc, hd).transpose(0, 2, 1, 3)
    kv = kv.reshape(b, se, 2 * lay.kv_loc, hd)
    k = kv[:, :, : lay.kv_loc].transpose(0, 2, 1, 3)
    v = kv[:, :, lay.kv_loc:].transpose(0, 2, 1, 3)

    o = chunked_attention(q, k, v, causal=False, chunk=min(1024, se))
    o_flat = o.transpose(0, 2, 1, 3).reshape(b, sd, lay.h_loc * hd)
    out = pc.matmul_rs(o_flat, params["wo"])
    return x + out


def build_cross_cache(params, enc, pc, cfg):
    """Precompute cross-attention K/V from the encoder output (decode path).

    enc: [B, se_loc, D] (enc seq-sharded). Returns per-shard k/v
    [B, kv_loc, Se, hd].
    """
    lay = _lay(cfg, pc.tp)
    hd = cfg.hd
    b = enc.shape[0]
    kv = pc.ag_matmul(enc, params["wkv"])
    if "bkv" in params:
        kv = kv + params["bkv"]
    se = kv.shape[1]
    kv = kv.reshape(b, se, 2 * lay.kv_loc, hd)
    k = kv[:, :, : lay.kv_loc].transpose(0, 2, 1, 3)
    v = kv[:, :, lay.kv_loc:].transpose(0, 2, 1, 3)
    return {"k": k, "v": v}


def apply_cross_decode(params, x, cross, pc, cfg):
    """Decode-time cross attention. x: [B, 1, D] replicated; cross: per-shard
    k/v [B, kv_loc, Se, hd]."""
    lay = _lay(cfg, pc.tp)
    hd = cfg.hd
    b = x.shape[0]
    h = rms_norm(x, params["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dn->bsn", h, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    qh = q.reshape(b, 1, lay.h_loc, hd).transpose(0, 2, 1, 3)
    rep = lay.h_loc // lay.kv_loc
    kk = jnp.repeat(cross["k"], rep, axis=1) if rep > 1 else cross["k"]
    vv = jnp.repeat(cross["v"], rep, axis=1) if rep > 1 else cross["v"]
    s = jnp.einsum("bhqd,bhkd->bhqk", (qh * hd ** -0.5).astype(jnp.float32),
                   kk.astype(jnp.float32))
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(x.dtype)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, lay.h_loc * hd)
    out = pc.psum(jnp.einsum("bsn,nd->bsd", o, params["wo"]))
    return x + out


def init_cache(cfg, tp: int, batch: int, max_len: int, dtype=jnp.bfloat16,
               window: Optional[int] = None):
    """Global KV cache arrays (head dim sharded over model).

    Sliding-window layers allocate a *ring buffer* of ``window`` slots instead
    of ``max_len`` — the sub-quadratic memory that makes long-context decode
    (gemma3 long_500k) fit HBM.  Slot ``p % window`` holds position ``p``.
    """
    lay = _lay(cfg, tp)
    length = min(max_len, window) if window is not None else max_len
    shape = (batch, tp * lay.kv_loc, length, cfg.hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def cache_specs(dp):
    return {"k": P(dp, "model", None, None), "v": P(dp, "model", None, None)}


def apply_decode(params, x, cache, cache_len, pc, cfg, *, window=None,
                 rope_theta=None, q_valid=None):
    """Chunked decode body (inside manual region).

    x: [B, C, D] replicated over model (C == 1 is plain decode; C > 1 is a
    prefill chunk); cache k/v: [B, kv_loc, S_max, hd] per-shard.
    ``cache_len`` is the number of tokens already in each slot's cache — a
    scalar or a per-slot [B] vector (the continuous-batching engine runs
    heterogeneous lengths).  ``q_valid`` ([B] int, optional) is how many of
    the C chunk rows are real per slot: rows past it write nothing (the
    scatter index goes out of bounds and is dropped) and their outputs are
    garbage the caller ignores.  Returns (x_out, new_cache).

    The chunk attends in two parts — the pre-existing cache rows, then the
    causal in-chunk keys — so the chunk's own k/v never round-trip through a
    ring slot another in-flight query still needs.  Requires C <= cache size
    for ring (sliding-window) layers.
    """
    lay = _lay(cfg, pc.tp)
    hd = cfg.hd
    b, c, _ = x.shape
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    nv = (jnp.full((b,), c, jnp.int32) if q_valid is None
          else jnp.asarray(q_valid, jnp.int32))
    h = rms_norm(x, params["ln"], cfg.norm_eps)
    w = jnp.concatenate([params["wq"], params["wkv"]], axis=1)
    qkv = jnp.einsum("bsd,dn->bsn", h, w)
    if "bq" in params:
        qkv = qkv + jnp.concatenate([params["bq"], params["bkv"]])
    qkv = qkv.reshape(b, c, lay.h_loc + 2 * lay.kv_loc, hd)
    q = qkv[:, :, : lay.h_loc]
    k = qkv[:, :, lay.h_loc: lay.h_loc + lay.kv_loc]
    v = qkv[:, :, lay.h_loc + lay.kv_loc:]

    pos = lens[:, None] + jnp.arange(c)[None, :]  # [B, C] global positions
    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    q, k = rope(q, k, pos, theta)

    cache_size = cache["k"].shape[2]
    ring = window is not None and cache_size <= window
    if ring and c > cache_size:
        raise ValueError(
            f"decode chunk C={c} exceeds ring cache size {cache_size}; "
            "chunked prefill must keep chunks within the sliding window")
    # per-(slot, row) scatter: invalid rows target slot ``cache_size``,
    # which is out of bounds and dropped by mode="drop"
    slots = jnp.remainder(pos, cache_size) if ring else pos
    slots = jnp.where(jnp.arange(c)[None, :] < nv[:, None], slots, cache_size)

    def _write(buf, vals, idx):
        # buf [kv_loc, L, hd], vals [kv_loc, C, hd], idx [C]
        return buf.at[:, idx].set(vals, mode="drop")

    ck = jax.vmap(_write)(cache["k"], k.transpose(0, 2, 1, 3), slots)
    cv = jax.vmap(_write)(cache["v"], v.transpose(0, 2, 1, 3), slots)

    qh = q.transpose(0, 2, 1, 3)  # [b, h_loc, C, hd]
    rep = lay.h_loc // lay.kv_loc
    kk = jnp.repeat(cache["k"], rep, axis=1) if rep > 1 else cache["k"]
    vv = jnp.repeat(cache["v"], rep, axis=1) if rep > 1 else cache["v"]
    kc = jnp.repeat(k, rep, axis=2) if rep > 1 else k  # [b, C, h_loc, hd]
    vc = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    qf = (qh * hd ** -0.5).astype(jnp.float32)
    # part 1: the pre-existing cache rows (the chunk is not in them yet)
    s1 = jnp.einsum("bhqd,bhkd->bhqk", qf, kk.astype(jnp.float32))
    j = jnp.arange(cache_size)
    if ring:
        # slot j last held position p_j = last - ((last - j) mod size)
        last = lens - 1
        p_j = last[:, None] - jnp.remainder(last[:, None] - j[None, :],
                                            cache_size)  # [B, L]
        m1 = (p_j >= 0)[:, None, :] & ((pos[:, :, None] - p_j[:, None, :])
                                       < window)  # [B, C, L]
    else:
        m1 = jnp.broadcast_to((j[None, :] < lens[:, None])[:, None, :],
                              (b, c, cache_size))
        if window is not None:
            m1 = m1 & ((pos[:, :, None] - j[None, None, :]) < window)
    s1 = jnp.where(m1[:, None], s1, -1e30)
    # part 2: causal in-chunk keys (row i attends rows <= i, valid only)
    s2 = jnp.einsum("bhqd,bkhd->bhqk", qf, kc.astype(jnp.float32))
    qi = jnp.arange(c)
    m2 = (qi[None, :, None] >= qi[None, None, :]) & \
        (qi[None, None, :] < nv[:, None, None])  # [B, C, C]
    if window is not None:
        m2 = m2 & ((qi[None, :, None] - qi[None, None, :]) < window)
    s2 = jnp.where(m2[:, None], s2, -1e30)

    p = jax.nn.softmax(jnp.concatenate([s1, s2], axis=-1), axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p[..., :cache_size],
                   vv.astype(jnp.float32))
    o = o + jnp.einsum("bhqk,bkhd->bhqd", p[..., cache_size:],
                       vc.astype(jnp.float32))
    o = o.astype(x.dtype).transpose(0, 2, 1, 3).reshape(b, c, lay.h_loc * hd)
    out = pc.psum(jnp.einsum("bsn,nd->bsd", o, params["wo"]))
    return x + out, {"k": ck, "v": cv}
