"""GQA attention block — TP over heads, sequence-parallel residual stream.

Train/prefill path (``apply_seq``): the AG+GEMM producer gathers the
sequence-sharded residual stream while projecting to this rank's heads (the
paper's AG+GEMM), attention runs locally on the head shard with a
memory-efficient chunked online-softmax (differentiable), and the output
projection is the GEMM+RS consumer (paper Fig. 4).  Both collectives lower
through ``compile_overlap`` as tile plans, so the tile order / channel count /
flow dtype selected by ``pc.channel`` apply here uniformly.

Decode path (``apply_decode``): activations are replicated over the TP axis;
projections are local column/row-parallel matmuls with a psum epilogue, and the
KV cache is sharded over heads.

Awkward GQA head counts (kv < tp, non-dividing heads) are handled by the
GQALayout padding/replication scheme in nn/layers.py; padded weights are
grad-masked so semantics match the unpadded architecture exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.nn.layers import (
    rms_norm, rope, he_init, gqa_layout, GQALayout,
)

__all__ = [
    "init", "specs", "grad_masks", "apply_seq", "apply_seq_ring", "apply_decode",
    "init_cache", "chunked_attention", "seam_proj",
]


def _lay(cfg, tp) -> GQALayout:
    return gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)


def init(key, cfg, tp: int, dtype=jnp.bfloat16):
    lay = _lay(cfg, tp)
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 4)
    # orig-shaped kv weights, expanded with `rep` identical copies
    wkv_orig = he_init(ks[1], (d, lay.kv_pad, 2 * hd), dtype, fan_in=d)
    # zero the padded kv heads (stay zero via grad masks)
    kv_mask = (jnp.arange(lay.kv_pad) < cfg.n_kv_heads)[None, :, None]
    wkv_orig = wkv_orig * kv_mask
    wkv = jnp.repeat(wkv_orig, lay.rep, axis=1).reshape(d, lay.kv_store * 2 * hd)

    head_active = jnp.arange(lay.h_pad) < cfg.n_heads
    wq = he_init(ks[0], (d, lay.h_pad, hd), dtype, fan_in=d)
    wq = (wq * head_active[None, :, None]).reshape(d, lay.h_pad * hd)

    wo = he_init(ks[2], (lay.h_pad, hd, d), dtype, fan_in=lay.h_pad * hd)
    wo = (wo * head_active[:, None, None]).reshape(lay.h_pad * hd, d)
    p = {"ln": jnp.zeros((d,), dtype), "wq": wq, "wkv": wkv, "wo": wo}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((lay.h_pad * hd,), dtype)
        p["bkv"] = jnp.zeros((lay.kv_store * 2 * hd,), dtype)
    return p


def specs(cfg, tp: int, dp) -> dict:
    s = {
        "ln": P(None),
        "wq": P(dp, "model"),
        "wkv": P(dp, "model"),
        "wo": P("model", dp),
    }
    if cfg.qkv_bias:
        s["bq"] = P("model")
        s["bkv"] = P("model")
    return s


def grad_masks(cfg, tp: int):
    """0/1 masks keeping padded heads at zero. None entries = no mask."""
    lay = _lay(cfg, tp)
    hd = cfg.hd
    if lay.h_pad == cfg.n_heads and lay.kv_pad == cfg.n_kv_heads:
        return None
    qm = jnp.repeat((jnp.arange(lay.h_pad) < cfg.n_heads), hd).astype(jnp.float32)
    kv_head_active = jnp.arange(lay.kv_store) // lay.rep < cfg.n_kv_heads
    kvm = jnp.repeat(kv_head_active, 2 * hd).astype(jnp.float32)
    m = {
        "ln": None,
        "wq": qm[None, :],
        "wkv": kvm[None, :],
        "wo": qm[:, None],
    }
    if cfg.qkv_bias:
        m["bq"] = qm
        m["bkv"] = kvm
    return m


def chunked_attention(q, k, v, *, causal=True, window: Optional[int] = None,
                      chunk: int = 1024, q_offset=0, scale: Optional[float] = None,
                      p_bf16: bool = False):
    """Memory-efficient online-softmax attention (differentiable).

    q: [B, H, Sq, hd]; k/v: [B, Hkv, Sk, hd] with H % Hkv == 0.
    Scans KV chunks with a rematerialized per-chunk body: O(Sq * chunk) live
    memory forward and backward.
    """
    b, h, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else hd ** -0.5
    chunk = min(chunk, sk)
    assert sk % chunk == 0
    nc = sk // chunk

    q32 = (q * scale).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(sq)

    kc = k.reshape(b, hkv, nc, chunk, hd)
    vc = v.reshape(b, hkv, nc, chunk, hd)

    @jax.checkpoint
    def body(carry, kj, vj, cidx):
        m_i, l_i, o_i = carry
        if rep > 1:
            kj = jnp.repeat(kj, rep, axis=1)
            vj = jnp.repeat(vj, rep, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", q32, kj.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        k_pos = cidx * chunk + jnp.arange(chunk)
        mask = None
        if causal:
            mask = q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            wm = (q_pos[:, None] - k_pos[None, :]) < window
            mask = wm if mask is None else mask & wm
        if mask is not None:
            s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m_i, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + p.sum(-1, keepdims=True)
        if p_bf16:
            # §Perf: P in bf16 halves the attention matmul's HBM reads; the
            # P@V product still accumulates in fp32 on the MXU
            pv = jnp.einsum("bhqk,bhkd->bhqd", p.astype(jnp.bfloat16),
                            vj.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
        else:
            pv = jnp.einsum("bhqk,bhkd->bhqd", p, vj.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
        o_new = o_i * alpha + pv
        return (m_new, l_new, o_new)

    # python (unrolled) chunk loop: per-chunk rematerialized bodies; unrolled
    # (rather than lax.scan) so per-chunk compute is visible to HLO cost
    # analysis (while bodies are counted once regardless of trip count) and so
    # fully-masked chunks can be skipped statically (causal/sliding-window).
    m_i = jnp.full((b, h, sq, 1), -1e30, jnp.float32)
    l_i = jnp.zeros((b, h, sq, 1), jnp.float32)
    o_i = jnp.zeros((b, h, sq, hd), jnp.float32)
    carry = (m_i, l_i, o_i)
    q_lo = int(q_offset) if isinstance(q_offset, int) else None
    for ci in range(nc):
        if q_lo is not None:
            k_lo, k_hi = ci * chunk, (ci + 1) * chunk - 1
            if causal and k_lo > q_lo + sq - 1:
                continue  # chunk entirely in the future
            if window is not None and (q_lo - k_hi) >= window:
                continue  # chunk entirely outside the window
        carry = body(carry, kc[:, :, ci], vc[:, :, ci], ci)
    m_f, l_f, o_f = carry
    return (o_f / jnp.maximum(l_f, 1e-30)).astype(q.dtype)


def seam_proj(params, cfg):
    """(glue, w) pair for fusing an upstream RS into THIS layer's qkv AG.

    ``glue`` maps the upstream residual output to this layer's AG input (the
    pre-attention rms_norm); ``w`` is the fused qkv per-shard weight — the
    same concat :func:`_project_qkv` uses.  Bias stays local in the consumer.
    """
    w = jnp.concatenate([params["wq"], params["wkv"]], axis=1)
    return (lambda y: rms_norm(y, params["ln"], cfg.norm_eps)), w


def _project_qkv(params, h, pc, lay, hd, qkv=None):
    """Shared AG+GEMM producer for q and kv projections.

    h: [B, s_loc, D] -> q/k/v as [B, S, n, hd] (full gathered sequence).
    ``qkv`` is the already-gathered projection from an upstream fused RS->AG
    seam (pre-bias), skipping the AG+GEMM here."""
    if qkv is None:
        w = jnp.concatenate([params["wq"], params["wkv"]], axis=1)
        qkv = pc.ag_matmul(h, w)  # [B, S, (h_loc + 2*kv_loc)*hd]
    if "bq" in params:
        bias = jnp.concatenate([params["bq"], params["bkv"]])
        qkv = qkv + bias
    b, s_glob = qkv.shape[0], qkv.shape[1]
    qkv = qkv.reshape(b, s_glob, lay.h_loc + 2 * lay.kv_loc, hd)
    q = qkv[:, :, : lay.h_loc]
    k = qkv[:, :, lay.h_loc: lay.h_loc + lay.kv_loc]
    v = qkv[:, :, lay.h_loc + lay.kv_loc:]
    return q, k, v, s_glob


def apply_seq(params, x, pc, cfg, *, causal=True, window=None,
              rope_theta=None, attn_chunk=1024, return_kv=False, tune=False,
              qkv=None, next_proj=None):
    """Full-sequence attention block body (call inside pc.smap manual region).

    x: [B, s_loc, D] sequence-sharded. Returns [B, s_loc, D] (residual added);
    with ``return_kv``, also the per-shard KV in cache layout
    [B, kv_loc, S, hd] (prefill-into-cache).  ``tune=True`` lets the AG+GEMM
    and GEMM+RS collectives resolve autotuned BlockChannels (repro.tune).

    Inter-op seam fusion (``pc.fuse_seams``): ``qkv`` is this layer's fused
    qkv projection already produced by the upstream op's RS->AG ring pass
    (see :func:`seam_proj`); ``next_proj=(glue, w)`` fuses the output-proj RS
    with the next consumer's AG over one shared ring pass, changing the
    return value to ``(y, next_out)`` (with ``return_kv``: ``(y, next_out,
    kv)``).
    """
    if tune and not pc.tune:
        pc = dataclasses.replace(pc, tune=True)
    lay = _lay(cfg, pc.tp)
    hd = cfg.hd
    b = x.shape[0]
    h = None if qkv is not None else rms_norm(x, params["ln"], cfg.norm_eps)
    q, k, v, s_glob = _project_qkv(params, h, pc, lay, hd, qkv=qkv)

    positions = jnp.arange(s_glob)
    q, k = rope(q, k, positions,
                rope_theta if rope_theta is not None else cfg.rope_theta)
    # [b, S, n, hd] -> [b, n, S, hd]
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    o = chunked_attention(q, k, v, causal=causal, window=window,
                          chunk=min(attn_chunk, s_glob), p_bf16=pc.attn_p_bf16)
    o_flat = o.transpose(0, 2, 1, 3).reshape(b, s_glob, lay.h_loc * hd)
    if next_proj is not None:
        glue, w_next = next_proj
        y, nxt = pc.matmul_rs_ag(o_flat, params["wo"], w_next,
                                 residual=x, glue=glue)
        if return_kv:
            return y, nxt, {"k": k, "v": v}
        return y, nxt
    out = pc.matmul_rs(o_flat, params["wo"])  # [B, s_loc, D]
    y = x + out
    if return_kv:
        return y, {"k": k, "v": v}
    return y


def apply_seq_ring(params, x, pc, cfg, *, causal=True, window=None,
                   rope_theta=None, tune=False, next_proj=None):
    """AG-Q + ring-KV attention block body (paper Fig. 6 layer form).

    Where :func:`apply_seq` gathers the WHOLE qkv projection through the
    AG+GEMM producer and attends on fully-resident KV, this path gathers
    only the (narrow) query projection; K/V project LOCALLY on the sequence
    shard and stay resident while their tiles rotate through
    ``pc.ring_attention`` — the overlapped AG-KV + online-softmax tile plan,
    whose consumer honors the CompSpec tile as (block_q, block_kv).  Every
    rank attends the full query range with its local heads, so the output
    projection is the same GEMM+RS consumer as :func:`apply_seq`.
    x: [B, s_loc, D] -> [B, s_loc, D] (residual added).  ``tune=True``
    resolves each collective's BlockChannel (including the attention compute
    tile) per shape via repro.tune; results match :func:`apply_seq` up to fp
    reassociation.

    Requires MQA (one padded KV head): the rotating tiles must be the SAME
    kv head's rows on every rank, which the GQALayout replication gives
    exactly when ``kv_pad == 1`` — with genuinely sharded KV heads each
    rank's local projection is a different head, and a ring would mix them.
    """
    if tune and not pc.tune:
        pc = dataclasses.replace(pc, tune=True)
    lay = _lay(cfg, pc.tp)
    if lay.kv_pad != 1:
        raise ValueError(
            "apply_seq_ring needs MQA (padded n_kv_heads == 1, so every rank "
            f"holds the same KV head); got kv_pad={lay.kv_pad} — use apply_seq")
    hd = cfg.hd
    b, s_loc, _ = x.shape
    h = rms_norm(x, params["ln"], cfg.norm_eps)

    q = pc.ag_matmul(h, params["wq"])  # [B, S, h_loc*hd] gathered
    kv = jnp.einsum("bsd,dn->bsn", h, params["wkv"])  # [B, s_loc, ...] local
    if "bq" in params:
        q = q + params["bq"]
        kv = kv + params["bkv"]
    s_glob = q.shape[1]
    q = q.reshape(b, s_glob, lay.h_loc, hd)
    kv = kv.reshape(b, s_loc, 2 * lay.kv_loc, hd)
    k = kv[:, :, : lay.kv_loc]
    v = kv[:, :, lay.kv_loc:]

    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    q, _ = rope(q, q, jnp.arange(s_glob), theta)
    k_pos = pc.axis_index() * s_loc + jnp.arange(s_loc)  # global KV positions
    _, k = rope(k, k, k_pos, theta)
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    o = pc.ring_attention(q, k, v, causal=causal, window=window)
    o_flat = o.transpose(0, 2, 1, 3).reshape(b, s_glob, lay.h_loc * hd)
    if next_proj is not None:
        glue, w_next = next_proj
        # fused epilogue: output-proj RS feeds the next consumer's AG
        return pc.matmul_rs_ag(o_flat, params["wo"], w_next,
                               residual=x, glue=glue)
    out = pc.matmul_rs(o_flat, params["wo"])  # [B, s_loc, D]
    return x + out


def apply_cross_seq(params, x, enc, pc, cfg):
    """Cross-attention (enc-dec): queries from x, keys/values from enc.

    x: [B, s_loc, D] (dec seq-sharded), enc: [B, se_loc, D] (enc seq-sharded).
    No rope, non-causal. Inside manual region.
    """
    lay = _lay(cfg, pc.tp)
    hd = cfg.hd
    b = x.shape[0]
    h = rms_norm(x, params["ln"], cfg.norm_eps)

    q = pc.ag_matmul(h, params["wq"])  # [B, Sd, h_loc*hd]
    kv = pc.ag_matmul(enc, params["wkv"])  # [B, Se, kv_loc*2hd]
    if "bq" in params:
        q = q + params["bq"]
        kv = kv + params["bkv"]
    sd, se = q.shape[1], kv.shape[1]
    q = q.reshape(b, sd, lay.h_loc, hd).transpose(0, 2, 1, 3)
    kv = kv.reshape(b, se, 2 * lay.kv_loc, hd)
    k = kv[:, :, : lay.kv_loc].transpose(0, 2, 1, 3)
    v = kv[:, :, lay.kv_loc:].transpose(0, 2, 1, 3)

    o = chunked_attention(q, k, v, causal=False, chunk=min(1024, se))
    o_flat = o.transpose(0, 2, 1, 3).reshape(b, sd, lay.h_loc * hd)
    out = pc.matmul_rs(o_flat, params["wo"])
    return x + out


def build_cross_cache(params, enc, pc, cfg):
    """Precompute cross-attention K/V from the encoder output (decode path).

    enc: [B, se_loc, D] (enc seq-sharded). Returns per-shard k/v
    [B, kv_loc, Se, hd].
    """
    lay = _lay(cfg, pc.tp)
    hd = cfg.hd
    b = enc.shape[0]
    kv = pc.ag_matmul(enc, params["wkv"])
    if "bkv" in params:
        kv = kv + params["bkv"]
    se = kv.shape[1]
    kv = kv.reshape(b, se, 2 * lay.kv_loc, hd)
    k = kv[:, :, : lay.kv_loc].transpose(0, 2, 1, 3)
    v = kv[:, :, lay.kv_loc:].transpose(0, 2, 1, 3)
    return {"k": k, "v": v}


def apply_cross_decode(params, x, cross, pc, cfg):
    """Decode-time cross attention. x: [B, 1, D] replicated; cross: per-shard
    k/v [B, kv_loc, Se, hd]."""
    lay = _lay(cfg, pc.tp)
    hd = cfg.hd
    b = x.shape[0]
    h = rms_norm(x, params["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dn->bsn", h, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    qh = q.reshape(b, 1, lay.h_loc, hd).transpose(0, 2, 1, 3)
    rep = lay.h_loc // lay.kv_loc
    kk = jnp.repeat(cross["k"], rep, axis=1) if rep > 1 else cross["k"]
    vv = jnp.repeat(cross["v"], rep, axis=1) if rep > 1 else cross["v"]
    s = jnp.einsum("bhqd,bhkd->bhqk", (qh * hd ** -0.5).astype(jnp.float32),
                   kk.astype(jnp.float32))
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(x.dtype)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, lay.h_loc * hd)
    out = pc.psum(jnp.einsum("bsn,nd->bsd", o, params["wo"]))
    return x + out


def init_cache(cfg, tp: int, batch: int, max_len: int, dtype=jnp.bfloat16,
               window: Optional[int] = None):
    """Global KV cache arrays (head dim sharded over model).

    Sliding-window layers allocate a *ring buffer* of ``window`` slots instead
    of ``max_len`` — the sub-quadratic memory that makes long-context decode
    (gemma3 long_500k) fit HBM.  Slot ``p % window`` holds position ``p``.
    """
    lay = _lay(cfg, tp)
    length = min(max_len, window) if window is not None else max_len
    shape = (batch, tp * lay.kv_loc, length, cfg.hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def cache_specs(dp):
    return {"k": P(dp, "model", None, None), "v": P(dp, "model", None, None)}


def apply_decode(params, x, cache, cache_len, pc, cfg, *, window=None,
                 rope_theta=None):
    """Single-token decode body (inside manual region).

    x: [B, 1, D] replicated over model; cache k/v: [B, kv_loc, S_max, hd]
    per-shard.  Returns (x_out, new_cache).
    """
    lay = _lay(cfg, pc.tp)
    hd = cfg.hd
    b = x.shape[0]
    h = rms_norm(x, params["ln"], cfg.norm_eps)
    w = jnp.concatenate([params["wq"], params["wkv"]], axis=1)
    qkv = jnp.einsum("bsd,dn->bsn", h, w)
    if "bq" in params:
        qkv = qkv + jnp.concatenate([params["bq"], params["bkv"]])
    qkv = qkv.reshape(b, 1, lay.h_loc + 2 * lay.kv_loc, hd)
    q = qkv[:, :, : lay.h_loc]
    k = qkv[:, :, lay.h_loc: lay.h_loc + lay.kv_loc]
    v = qkv[:, :, lay.h_loc + lay.kv_loc:]

    pos = jnp.full((1, 1), cache_len)
    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    q, k = rope(q, k, pos, theta)

    cache_size = cache["k"].shape[2]
    ring = window is not None and cache_size <= window
    write_pos = jnp.remainder(cache_len, cache_size) if ring else cache_len
    ck = lax.dynamic_update_slice(cache["k"], k.transpose(0, 2, 1, 3),
                                  (0, 0, write_pos, 0))
    cv = lax.dynamic_update_slice(cache["v"], v.transpose(0, 2, 1, 3),
                                  (0, 0, write_pos, 0))

    qh = q.transpose(0, 2, 1, 3)  # [b, h_loc, 1, hd]
    rep = lay.h_loc // lay.kv_loc
    kk = jnp.repeat(ck, rep, axis=1) if rep > 1 else ck
    vv = jnp.repeat(cv, rep, axis=1) if rep > 1 else cv
    s = jnp.einsum("bhqd,bhkd->bhqk", (qh * hd ** -0.5).astype(jnp.float32),
                   kk.astype(jnp.float32))
    j = jnp.arange(s.shape[-1])
    if ring:
        # slot j holds position p_j = cache_len - ((cache_len - j) mod size)
        p_j = cache_len - jnp.remainder(cache_len - j, cache_size)
        mask = (p_j >= 0) & (p_j <= cache_len) & ((cache_len - p_j) < window)
    else:
        mask = j <= cache_len
        if window is not None:
            mask = mask & ((cache_len - j) < window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(x.dtype)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, lay.h_loc * hd)
    out = pc.psum(jnp.einsum("bsn,nd->bsd", o, params["wo"]))
    return x + out, {"k": ck, "v": cv}
