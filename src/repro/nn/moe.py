"""MoE block — expert parallelism over the model axis + TileLink AG+MoE ring.

Routing (dynamic mapping), dispatch, expert FFN and combine follow the paper's
Fig. 5 workload: the router fills the dynamic lookup tables; the overlapped
"ag_rs" tile plan in core/moe_overlap.py (an AG flow of token tiles + a
reduction riding the same permutes, run by the generic schedule executor)
gathers token chunks and reduce-scatters combined outputs while local experts
compute — under whatever tile order / channel count ``pc.channel`` selects,
and with the per-expert grouped GEMMs blocked by the CompSpec (tm, tn, tk)
tile when one is set (or tuner-resolved via ``tune=True`` — the attention/MoE
consumers have a compute-tile axis in the joint search space).
Shared experts (DeepSeek-style) run as a dense TP MLP in parallel with the
routed path (paper §7.3 does the same for Qwen1.5's shared experts).

With ``ParallelContext(ep_axis=...)`` (or ``apply_seq(..., ep=True)``) the
routed path switches to true expert parallelism: the overlapped
dispatch/combine all-to-all (``pc.a2a_moe`` -> ``core/moe_overlap.a2a_moe``),
where token tiles and their routing tables exchange pairwise per step, local
experts' grouped GEMMs run on landed tiles while the next exchange is in
flight, and weighted partials return home along the reversed edge.

Expert count is padded up to a multiple of the EP degree; padding experts get
-inf router logits and are never selected (their weights receive zero gradient
structurally — no masks needed).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.moe_overlap import moe_router
from repro.nn.layers import rms_norm, he_init, cdiv, ACTS
from repro.nn import ffn as dense_ffn

__all__ = ["init", "specs", "apply_seq", "apply_decode", "padded_experts"]


def padded_experts(cfg, tp: int) -> int:
    return cdiv(cfg.moe.num_experts, tp) * tp


def init(key, cfg, tp: int, dtype=jnp.bfloat16):
    d = cfg.d_model
    m = cfg.moe
    e_pad = padded_experts(cfg, tp)
    f = m.d_expert
    ks = jax.random.split(key, 4)
    p = {
        "ln": jnp.zeros((d,), dtype),
        "router": he_init(ks[0], (d, e_pad), jnp.float32, fan_in=d),
        "w_gu": he_init(ks[1], (e_pad, d, 2 * f), dtype, fan_in=d),
        "w_down": he_init(ks[2], (e_pad, f, d), dtype, fan_in=f),
    }
    if m.num_shared:
        p["shared"] = dense_ffn.init(ks[3], cfg, tp, dtype,
                                     d_ff=m.num_shared * f)
    return p


def specs(cfg, tp: int, dp) -> dict:
    s = {
        "ln": P(None),
        "router": P(None, None),
        "w_gu": P("model", dp, None),
        "w_down": P("model", None, dp),
    }
    if cfg.moe.num_shared:
        s["shared"] = dense_ffn.specs(cfg, tp, dp)
    return s


def apply_seq(params, x, pc, cfg, *, tune=False, quant=None, ep=None,
              next_proj=None):
    """x: [B, s_loc, D] -> ([B, s_loc, D], aux_loss). Inside manual region.

    Batch rows are routed/dispatched independently (vmap over B) so the
    DP-sharded batch dim partitions cleanly; capacity is per (batch row,
    sequence chunk).  ``tune=True`` lets the routed exchange (and the
    shared-expert MLP, which sees the same pc) resolve autotuned
    BlockChannels (repro.tune).  ``quant`` pins a QuantSpec wire encoding
    (or ``"auto"``, a no-op for the a2a exchange itself — the MoE kinds
    carry int32 routing tables — but live for the shared-expert MLP) — see
    ``ParallelContext.quant``.

    ``ep`` selects the expert-parallel path (``pc.a2a_moe``: overlapped
    dispatch/combine all-to-all with the routing tables riding the token
    tiles) instead of the TP AG+MoE double ring (``pc.ag_moe``).  It
    defaults to whether the context opted in via
    ``ParallelContext(ep_axis=...)``; passing ``ep=True`` without an
    ``ep_axis`` raises.  Both paths share capacity/drop semantics.

    ``next_proj`` is accepted for keyword-surface symmetry with
    ffn/attention ``apply_seq`` but must be None: the MoE combine ends at
    the residual stream (a reduction, not a projection), so there is no
    RS -> AG seam to fuse into a downstream consumer.
    """
    if next_proj is not None:
        raise ValueError(
            "moe.apply_seq does not support next_proj: the MoE combine ends "
            "at the residual stream, so there is no RS->AG seam to fuse "
            "into a consumer")
    if ep is None:
        ep = pc.ep_axis is not None
    if ep and pc.ep_axis is None:
        raise ValueError(
            "moe.apply_seq(ep=True) requires ParallelContext(ep_axis=...); "
            "expert parallelism is opt-in")
    if tune and not pc.tune:
        pc = dataclasses.replace(pc, tune=True)
    if quant is not None and pc.quant != quant:
        pc = dataclasses.replace(pc, quant=quant)
    m = cfg.moe
    e_pad = params["w_gu"].shape[0] * pc.tp  # per-shard E_loc * tp
    h = rms_norm(x, params["ln"], cfg.norm_eps)

    def route(tok):
        return moe_router(tok, params["router"], num_experts=e_pad,
                          top_k=m.top_k, valid_experts=m.num_experts)

    ids, wts, aux = jax.vmap(route)(h)  # [B, s_loc, k], aux [B]
    moe_op = pc.a2a_moe if ep else pc.ag_moe
    out = jax.vmap(
        lambda t, i, w: moe_op(t, i, w, params["w_gu"], params["w_down"],
                               capacity_factor=m.capacity_factor,
                               act=ACTS[cfg.act])
    )(h, ids, wts)
    # aux loss: mean over batch rows + ring members
    aux = jax.lax.pmean(aux.mean(), pc.axis)
    y = x + out.astype(x.dtype)
    if "shared" in params:
        y = dense_ffn.apply_seq(params["shared"], y, pc, cfg)  # residual inside
    return y, aux


def apply_decode(params, x, pc, cfg):
    """Decode: tokens replicated over model; local experts + psum combine.

    Bytes-optimal for small decode batches (§Perf): every LOCAL expert's
    weights are streamed from HBM exactly once and applied to all tokens with
    a masked combine — instead of per-(token, k) weight gathers, which read
    the same expert matrix up to m·k times.  Decode is memory-bound, so the
    extra (tiny-m) FLOPs are free and HBM traffic drops by ~m·k/E_loc.
    """
    m = cfg.moe
    e_loc = params["w_gu"].shape[0]
    e_pad = e_loc * pc.tp
    b, s, d = x.shape
    h = rms_norm(x, params["ln"], cfg.norm_eps)
    tokens = h.reshape(b * s, d)
    ids, wts, _ = moe_router(
        tokens, params["router"], num_experts=e_pad, top_k=m.top_k,
        valid_experts=m.num_experts,
    )
    e_lo = pc.axis_index() * e_loc
    f = params["w_down"].shape[1]
    local = ids - e_lo
    valid = (local >= 0) & (local < e_loc)

    if getattr(pc, "moe_decode_stream", False):
        # §Perf optimized path: stream each local expert ONCE over all tokens
        # with a masked combine — HBM weight traffic / (m*k / E_loc)
        onehot = jax.nn.one_hot(jnp.where(valid, local, 0), e_loc,
                                dtype=jnp.float32) * valid[..., None]
        comb = jnp.einsum("mke,mk->me", onehot, wts).astype(x.dtype)
        hdn = jnp.einsum("md,edf->emf", tokens, params["w_gu"])
        a = ACTS[cfg.act](hdn[..., :f]) * hdn[..., f:]
        ye = jnp.einsum("emf,efd->emd", a.astype(x.dtype), params["w_down"])
        out = pc.psum(jnp.einsum("emd,me->md", ye, comb))
    else:
        # baseline: per-(token, k) weight gathers
        local_g = jnp.where(valid, local, 0).astype(jnp.int32)
        wg = params["w_gu"][local_g]  # [m, k, d, 2f]
        hdn = jnp.einsum("md,mkdf->mkf", tokens, wg)
        a = ACTS[cfg.act](hdn[..., :f]) * hdn[..., f:]
        wd = params["w_down"][local_g]  # [m, k, f, d]
        ye = jnp.einsum("mkf,mkfd->mkd", a.astype(x.dtype), wd)
        comb = (wts * valid.astype(jnp.float32)).astype(x.dtype)
        out = pc.psum(jnp.einsum("mkd,mk->md", ye, comb))
    y = x + out.reshape(b, s, d)
    if "shared" in params:
        y = dense_ffn.apply_decode(params["shared"], y, pc, cfg)
    return y
