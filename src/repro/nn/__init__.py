from repro.nn import layers, attention, ffn, moe, mamba

__all__ = ["layers", "attention", "ffn", "moe", "mamba"]
