"""Mamba-2 (SSD) block — heads sharded over the model axis.

The attention-free mixer: TileLink's AG-KV overlap is inapplicable here (see
DESIGN.md §Arch-applicability), but the paper's AG+GEMM / GEMM+RS pattern still
covers the in/out projections, which dominate the block's FLOPs.  The SSD scan
itself runs locally on each rank's head shard over the full (gathered)
sequence.

Layout per rank: d_inner_loc = d_inner / tp channels, h_loc = heads / tp.
B/C projections are head-group (G) global and small -> computed replicated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.mamba_ssd import ssd_chunked
from repro.nn.layers import rms_norm, he_init

__all__ = ["init", "specs", "apply_seq", "apply_decode", "apply_decode_chunk",
           "init_cache", "cache_specs"]


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.headdim
    return d_inner, n_heads


def init(key, cfg, tp: int, dtype=jnp.bfloat16):
    d = cfg.d_model
    s = cfg.ssm
    d_inner, n_heads = _dims(cfg)
    assert d_inner % tp == 0 and n_heads % tp == 0, (d_inner, n_heads, tp)
    ks = jax.random.split(key, 6)
    return {
        "ln": jnp.zeros((d,), dtype),
        # x and z (gate) projections — column parallel [D, 2*d_inner]
        "w_xz": he_init(ks[0], (d, 2 * d_inner), dtype, fan_in=d),
        # dt projection — per head, column parallel
        "w_dt": he_init(ks[1], (d, n_heads), dtype, fan_in=d),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        # B and C projections — small, replicated
        "w_bc": he_init(ks[2], (d, 2 * s.n_groups * s.d_state), dtype, fan_in=d),
        "a_log": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        # depthwise conv over sequence (x part only)
        "conv": he_init(ks[3], (s.d_conv, d_inner), dtype, fan_in=s.d_conv),
        "w_out": he_init(ks[4], (d_inner, d), dtype, fan_in=d_inner),
    }


def specs(cfg, tp: int, dp) -> dict:
    return {
        "ln": P(None),
        "w_xz": P(dp, "model"),
        "w_dt": P(None, "model"),
        "dt_bias": P("model"),
        "w_bc": P(dp, None),
        "a_log": P("model"),
        "d_skip": P("model"),
        "conv": P(None, "model"),
        "w_out": P("model", dp),
    }


def _conv1d(x, w):
    """Causal depthwise conv. x: [B, S, C], w: [K, C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i: xp.shape[1] - (k - 1 - i), :] * w[i] for i in range(k))
    return out


def apply_seq(params, x, pc, cfg, return_state: bool = False):
    """x: [B, s_loc, D] -> [B, s_loc, D] (+residual). Inside manual region.

    ``return_state`` additionally returns the decode cache (final SSM state +
    conv tail) for prefill-into-cache."""
    s_cfg = cfg.ssm
    b = x.shape[0]
    h = rms_norm(x, params["ln"], cfg.norm_eps)

    # AG + GEMM: gather sequence, project to local channels (x | z | dt)
    w = jnp.concatenate([params["w_xz"], params["w_dt"].astype(params["w_xz"].dtype)],
                        axis=1)
    xzdt = pc.ag_matmul(h, w)  # [B, S, 2*di_loc + h_loc]
    di_loc = params["w_xz"].shape[1] // 2
    h_loc = params["w_dt"].shape[1]
    s_glob = xzdt.shape[1]

    xin = xzdt[..., :di_loc]
    z = xzdt[..., di_loc: 2 * di_loc]
    dt = jax.nn.softplus(
        xzdt[..., 2 * di_loc:].astype(jnp.float32) + params["dt_bias"]
    )

    # B/C: replicated small projection on the gathered sequence
    hfull = pc.all_gather_seq(h, 1)  # [B, S, D]
    bc = jnp.einsum("bsd,dn->bsn", hfull, params["w_bc"])
    gn = s_cfg.n_groups * s_cfg.d_state
    b_mat = bc[..., :gn].reshape(b, s_glob, s_cfg.n_groups, s_cfg.d_state)
    c_mat = bc[..., gn:].reshape(b, s_glob, s_cfg.n_groups, s_cfg.d_state)

    # causal depthwise conv on local channels (full sequence — no halo needed;
    # params["conv"] is already the per-shard [K, di_loc] slice in here)
    xin = jax.nn.silu(_conv1d(xin, params["conv"]))

    xh = xin.reshape(b, s_glob, h_loc, s_cfg.headdim)
    y = ssd_chunked(xh, dt, params["a_log"], b_mat, c_mat, chunk=s_cfg.chunk,
                    return_state=return_state)
    if return_state:
        y, h_last = y
    y = y + xh * params["d_skip"][None, None, :, None]
    y = y.reshape(b, s_glob, di_loc) * jax.nn.silu(z)

    # GEMM + RS back to the sequence-sharded residual stream
    out = pc.matmul_rs(y.astype(x.dtype), params["w_out"])
    res = x + out
    if return_state:
        # conv tail: last (d_conv - 1) pre-conv inputs of the local channels
        k = s_cfg.d_conv - 1
        tail = xzdt[:, -k:, :di_loc]
        return res, {"ssm": h_last, "conv": tail.astype(x.dtype)}
    return res


def init_cache(cfg, tp: int, batch: int, dtype=jnp.bfloat16):
    """Decode state: SSM state [B, H, N, P] + conv tail [B, d_conv-1, d_inner]."""
    s = cfg.ssm
    d_inner, n_heads = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, n_heads, s.d_state, s.headdim), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, d_inner), dtype),
    }


def cache_specs(dp):
    return {"ssm": P(dp, "model", None, None), "conv": P(dp, None, "model")}


def apply_decode(params, x, cache, pc, cfg):
    """Single-token recurrent step. x: [B, 1, D] replicated over model."""
    s_cfg = cfg.ssm
    b = x.shape[0]
    h = rms_norm(x, params["ln"], cfg.norm_eps)[:, 0]  # [B, D]
    di_loc = params["w_xz"].shape[1] // 2
    h_loc = params["w_dt"].shape[1]

    xz = jnp.einsum("bd,dn->bn", h, params["w_xz"])
    xin, z = xz[:, :di_loc], xz[:, di_loc:]
    dt = jax.nn.softplus(
        jnp.einsum("bd,dn->bn", h, params["w_dt"]).astype(jnp.float32)
        + params["dt_bias"]
    )  # [B, h_loc]
    bc = jnp.einsum("bd,dn->bn", h, params["w_bc"])
    gn = s_cfg.n_groups * s_cfg.d_state
    b_mat = bc[:, :gn].reshape(b, s_cfg.n_groups, s_cfg.d_state)
    c_mat = bc[:, gn:].reshape(b, s_cfg.n_groups, s_cfg.d_state)

    # conv step: cache holds the last (d_conv - 1) x inputs (local channels)
    conv_tail = cache["conv"]  # [B, K-1, di_loc]
    xcat = jnp.concatenate([conv_tail, xin[:, None, :]], axis=1)
    wconv = params["conv"]
    xc = jax.nn.silu((xcat * wconv.astype(xcat.dtype)).sum(axis=1))
    new_conv = xcat[:, 1:]

    # recurrence: h_t = h_{t-1} * exp(dt*A) + dt * B x ; y = C . h + D x
    a = -jnp.exp(params["a_log"])  # [h_loc]
    xh = xc.reshape(b, h_loc, s_cfg.headdim).astype(jnp.float32)
    rep = h_loc // s_cfg.n_groups if s_cfg.n_groups <= h_loc else 1
    bh = jnp.repeat(b_mat, rep, axis=1)[:, :h_loc].astype(jnp.float32)
    ch = jnp.repeat(c_mat, rep, axis=1)[:, :h_loc].astype(jnp.float32)
    decay = jnp.exp(dt * a[None, :])  # [B, h_loc]
    upd = jnp.einsum("bh,bhn,bhp->bhnp", dt, bh, xh)
    new_ssm = cache["ssm"] * decay[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", ch, new_ssm)
    y = y + xh * params["d_skip"][None, :, None]
    y = (y.reshape(b, di_loc) * jax.nn.silu(z)).astype(x.dtype)

    out = pc.psum(jnp.einsum("bn,nd->bd", y, params["w_out"]))
    return x + out[:, None, :], {"ssm": new_ssm, "conv": new_conv}


def apply_decode_chunk(params, x, cache, pc, cfg, q_valid=None):
    """Chunked decode: scan the single-token recurrence over the C axis.

    x: [B, C, D] replicated over model.  ``q_valid`` ([B] int, optional)
    marks how many of the C rows are real per slot — masked steps leave the
    SSM/conv state untouched (unlike attention, a stale recurrent state
    would silently poison every later token, so the mask is load-bearing).
    """
    b, c, _ = x.shape
    if c == 1 and q_valid is None:
        return apply_decode(params, x, cache, pc, cfg)
    valid = (jnp.arange(c)[:, None] < jnp.full((b,), c, jnp.int32)[None, :]
             if q_valid is None
             else jnp.arange(c)[:, None] < jnp.asarray(q_valid, jnp.int32))

    def step(state, inp):
        xt, ok = inp  # xt [B, D], ok [B] bool
        y, new = apply_decode(params, xt[:, None], state, pc, cfg)
        new = jax.tree_util.tree_map(
            lambda n, o: jnp.where(ok.reshape((b,) + (1,) * (n.ndim - 1)),
                                   n, o), new, state)
        return new, y[:, 0]

    cache, ys = jax.lax.scan(step, cache, (x.transpose(1, 0, 2), valid))
    return ys.transpose(1, 0, 2), cache
