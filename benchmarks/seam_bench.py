"""Fused RS->AG seam bench + CI smoke (``--smoke`` -> ``BENCH_seam.json``).

The inter-op overlap claim made gateable: for every dense FFN seam shape the
fused ``compile_overlap(["matmul_rs", "ag_matmul"])`` plan must beat the best unfused
``matmul_rs`` + ``ag_matmul`` pair on the MODELED cost scale — the seam
credits ``min(fill_drain(rs), fill_drain(ag))``, the exposed-collective time
the fusion eliminates, so a fused plan that does not win means the seam
costing (or the candidate enumeration behind ``channel="auto"``) broke.

``--smoke`` additionally:

  * runs ``tune.resolve_seq`` end-to-end on the smallest shape and asserts
    it verdicts FUSED (the auto path exercises the same pricing);
  * measures fused vs. unfused wall time for the smallest shape on a 4-rank
    emulated mesh (informational on CPU — emulated wall time is not a perf
    signal, ROADMAP; the ``us`` leaves are tolerance-gated like every other
    smoke timing) and checks numerical parity between the two paths.

Modeled costs land under ungated ``*_modeled_us`` leaves (floats, but
deterministic); the per-shape ``ok`` health leaf (fused wins modeled) and
``considered`` (seam candidate count) gate exactly via benchmarks/compare.py.
Any violation exits non-zero so CI fails loudly.
"""
import argparse
import json
import sys

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import tune
from repro.compat import shard_map
from repro.core import BlockChannel, compile_overlap
from repro.tune import cost as tune_cost

try:  # package import (python -m benchmarks.seam_bench / pytest)
    from benchmarks.common import mesh_tp, row, time_fn
except ImportError:  # plain script: the benchmarks/ dir is sys.path[0]
    from common import mesh_tp, row, time_fn

WORLD = 4

# dense FFN seam signatures (lead, m_glob, k_loc, n_mid, n2_loc): the
# down-proj GEMM+RS of one block feeding the next block's AG+GEMM —
# m_glob = sequence, k_loc = f/tp, n_mid = d_model, n2_loc = next cols/tp
DENSE_SHAPES = {
    "small": (1, 64, 32, 64, 32),
    "mlp-1k": (1, 1024, 256, 1024, 512),
    "mlp-4k": (1, 4096, 1024, 4096, 2048),
}


def _best(sig, *, fused):
    """(cost_us, candidate) of the cheapest shared-channel seam candidate."""
    cands = tune.enumerate_seq_candidates(sig=sig, world=WORLD)
    if not cands:
        raise ValueError(f"no seam candidates for sig={sig}")
    best = min(cands, key=lambda c: tune_cost.predict_seq_cost(sig, WORLD, c, fused=fused))
    return tune_cost.predict_seq_cost(sig, WORLD, best, fused=fused) * 1e6, best, len(cands)


def _measured_case(mesh, sig):
    """Jitted fused + unfused seam callables over global operands."""
    lead, m_glob, k_loc, n_mid, n2_loc = sig
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m_glob, WORLD * k_loc), jnp.float32)
    w1 = jax.random.normal(jax.random.PRNGKey(1), (WORLD * k_loc, n_mid), jnp.float32)
    w2 = jax.random.normal(jax.random.PRNGKey(2), (n_mid, WORLD * n2_loc), jnp.float32)
    res = jax.random.normal(jax.random.PRNGKey(3), (m_glob, n_mid), jnp.float32)
    glue = lambda y: y * 0.5 + 1.0  # noqa: E731 — any row-local map
    ch = BlockChannel(axis="model", num_channels=2)
    specs = dict(
        in_specs=(P(None, "model"), P("model", None), P(None, "model"), P("model", None)),
        out_specs=(P("model", None), P(None, "model")),
    )

    fused = compile_overlap(["matmul_rs", "ag_matmul"], channel=ch)
    rs = compile_overlap("matmul_rs", ch)
    ag = compile_overlap("ag_matmul", ch)

    def unfused(x_, w1_, w2_, r_):
        y = r_ + rs(x_, w1_)
        return y, ag(glue(y), w2_)

    f_fn = jax.jit(shard_map(
        lambda x_, w1_, w2_, r_: fused(x_, w1_, w2_, residual=r_, glue=glue),
        mesh, **specs))
    u_fn = jax.jit(shard_map(unfused, mesh, **specs))
    return f_fn, u_fn, (x, w1, w2, res)


def smoke(out_path: str = "BENCH_seam.json") -> int:
    results, failures = {"shapes": {}}, []

    for name, sig in DENSE_SHAPES.items():
        entry = {"signature": list(sig)}
        try:
            fused_us, cand, considered = _best(sig, fused=True)
            unfused_us, _, _ = _best(sig, fused=False)
            saving_us = tune_cost.seam_saving(sig, WORLD, cand) * 1e6
            ok = fused_us < unfused_us
            if not ok:
                failures.append(
                    f"{name}: fused modeled cost {fused_us:.1f}us does not beat "
                    f"the unfused pair {unfused_us:.1f}us — the seam credit is dead"
                )
            entry.update(
                winner=cand.label(),
                considered=considered,
                fused_modeled_us=round(fused_us, 3),
                unfused_modeled_us=round(unfused_us, 3),
                modeled_saving_us=round(saving_us, 3),
                ok=ok,
            )
            row(f"seam/{name}/modeled/{cand.label()}", fused_us,
                f"unfused {unfused_us:.0f}us")
        except Exception as exc:  # loud: any seam-costing error fails CI
            failures.append(f"{name}: {type(exc).__name__}: {exc}")
            entry["error"] = str(exc)
        results["shapes"][name] = entry

    # ---- the auto path verdicts FUSED on a dense seam ----------------------
    try:
        sig = DENSE_SHAPES["small"]
        fused, ch_rs, ch_ag = tune.resolve_seq(sig=sig, world=WORLD)
        if not fused:
            failures.append("resolve_seq verdicted UNFUSED on a dense seam shape")
        results["resolve"] = {"fused": bool(fused), "ok": bool(fused),
                              "channels": [ch_rs.num_channels, ch_ag.num_channels]}
    except Exception as exc:
        failures.append(f"resolve: {type(exc).__name__}: {exc}")
        results["resolve"] = {"error": str(exc), "ok": False}

    # ---- smoke-measured fused vs unfused + parity (emulated mesh) ----------
    try:
        mesh = mesh_tp(WORLD)
        f_fn, u_fn, args = _measured_case(mesh, DENSE_SHAPES["small"])
        yf, gf = f_fn(*args)
        yu, gu = u_fn(*args)
        err = max(float(jnp.max(jnp.abs(yf - yu))), float(jnp.max(jnp.abs(gf - gu))))
        parity_ok = err < 1e-3
        if not parity_ok:
            failures.append(f"measured: fused vs unfused parity error {err:.3e}")
        fused_us = time_fn(f_fn, *args)
        unfused_us = time_fn(u_fn, *args)
        results["measured"] = {
            "fused": {"us": round(fused_us, 1)},
            "unfused": {"us": round(unfused_us, 1)},
            "max_abs_err": err,
            "ok": parity_ok,
        }
        row("seam/small/measured/fused", fused_us)
        row("seam/small/measured/unfused", unfused_us)
    except Exception as exc:  # loud: the executor path must run on CPU
        failures.append(f"measured: {type(exc).__name__}: {exc}")
        results["measured"] = {"error": str(exc), "ok": False}

    with open(out_path, "w") as fh:
        json.dump(results, fh, indent=2, sort_keys=True)
    print(f"wrote {out_path}: {len(results['shapes'])} shapes, {len(failures)} failures")
    for f_ in failures:
        print(f"FAIL {f_}", file=sys.stderr)
    return 1 if failures else 0


def main() -> int:
    print("# modeled fused vs unfused seam cost per dense FFN shape "
          f"(world={WORLD})")
    for name, sig in DENSE_SHAPES.items():
        fused_us, cand, _ = _best(sig, fused=True)
        unfused_us, _, _ = _best(sig, fused=False)
        row(f"seam/{name}/{cand.label()}", fused_us,
            f"unfused {unfused_us:.0f}us ({unfused_us / max(fused_us, 1e-9):.2f}x)")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="CI guard: modeled fused-beats-unfused on every dense shape, "
        "resolve_seq verdict, measured parity; write BENCH_seam.json",
    )
    ap.add_argument("--out", default="BENCH_seam.json")
    a = ap.parse_args()
    sys.exit(smoke(a.out) if a.smoke else main())
