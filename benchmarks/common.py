"""Benchmark harness utilities.

CPU host runs 8 simulated devices; shapes are the paper's divided by SCALE so a
call completes in ms on one core.  The reported quantity mirrors the paper's
evaluation: *relative speedup of overlapped vs non-overlapping* (and vs
host-dispatched decomposition).  Absolute TPU projections come from the
dry-run roofline (EXPERIMENTS.md), not from CPU wall time.
"""
import os

os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_force_host_platform_device_count=8 "
    "--xla_disable_hlo_passes=all-reduce-promotion",
)

import time
from typing import Callable

import jax
import numpy as np

from repro.compat import make_mesh

SCALE = 8  # divide paper dims by this
REPEATS = 5
WARMUP = 2


def mesh8():
    return make_mesh((8,), ("model",))


def mesh_tp(n=8):
    return make_mesh((n,), ("model",))


def time_fn(fn: Callable, *args, repeats=REPEATS, warmup=WARMUP) -> float:
    """Median wall-time per call in microseconds (blocking on results)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def row(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.0f},{derived}")
