"""Paper Table 2 (motivational): TP MLP (LLaMA-7B shape) — AG+GEMM and GEMM+RS
under non-overlap / decomposition / TileLink."""

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import overlap
from benchmarks.common import SCALE, mesh8, time_fn, row


def _decomposed_ag_gemm(mesh, n_chunks=8):
    """Async-TP-style operator decomposition: one host-dispatched jit per
    chunk's (permute + matmul) pair — models the host-intervention overhead the
    paper attributes to decomposition."""
    @jax.jit
    def shift(x):
        return jax.jit(shard_map(
            lambda c: jax.lax.ppermute(
                c, "model", [(j, (j + 1) % 8) for j in range(8)]),
            mesh, in_specs=P("model", None), out_specs=P("model", None)))(x)

    @jax.jit
    def mm(c, w):
        return c @ w

    def run(x, w):
        outs = []
        c = x
        for _ in range(8):
            outs.append(mm(c, w))
            c = shift(c)
        return jnp.concatenate(outs, 0)

    return run


def main():
    s, h, i = 8192 // SCALE, 4096 // SCALE, 11008 // SCALE
    i = (i // 8) * 8
    mesh = mesh8()
    key = jax.random.PRNGKey(0)
    x = jax.device_put(jax.random.normal(key, (s, h), jnp.float32),
                       NamedSharding(mesh, P("model", None)))
    w1 = jax.device_put(jax.random.normal(key, (h, i), jnp.float32),
                        NamedSharding(mesh, P(None, "model")))
    xr = jax.device_put(jax.random.normal(key, (s, i), jnp.float32),
                        NamedSharding(mesh, P(None, "model")))
    w2 = jax.device_put(jax.random.normal(key, (i, h), jnp.float32),
                        NamedSharding(mesh, P("model", None)))

    def sm(fn, ins, outs):
        return jax.jit(shard_map(fn, mesh, in_specs=ins, out_specs=outs))

    ag_base = sm(lambda a, b: overlap.ag_matmul_baseline(a, b, axis="model"),
                 (P("model", None), P(None, "model")), P(None, "model"))
    ag_tl = sm(lambda a, b: overlap.ag_matmul(a, b, axis="model"),
               (P("model", None), P(None, "model")), P(None, "model"))
    rs_base = sm(lambda a, b: overlap.matmul_rs_baseline(a, b, axis="model"),
                 (P(None, "model"), P("model", None)), P("model", None))
    rs_tl = sm(lambda a, b: overlap.matmul_rs(a, b, axis="model"),
               (P(None, "model"), P("model", None)), P("model", None))
    ag_dec = _decomposed_ag_gemm(mesh)

    t = {}
    t["ag_nonoverlap"] = time_fn(ag_base, x, w1)
    t["ag_decompose"] = time_fn(ag_dec, x, w1)
    t["ag_tilelink"] = time_fn(ag_tl, x, w1)
    t["rs_nonoverlap"] = time_fn(rs_base, xr, w2)
    t["rs_tilelink"] = time_fn(rs_tl, xr, w2)

    row("tab2/AG+GEMM/non-overlap", t["ag_nonoverlap"], "1.00x")
    row("tab2/AG+GEMM/decompose", t["ag_decompose"],
        f"{t['ag_nonoverlap']/t['ag_decompose']:.2f}x")
    row("tab2/AG+GEMM/tilelink", t["ag_tilelink"],
        f"{t['ag_nonoverlap']/t['ag_tilelink']:.2f}x")
    row("tab2/GEMM+RS/non-overlap", t["rs_nonoverlap"], "1.00x")
    row("tab2/GEMM+RS/tilelink", t["rs_tilelink"],
        f"{t['rs_nonoverlap']/t['rs_tilelink']:.2f}x")


if __name__ == "__main__":
    main()
