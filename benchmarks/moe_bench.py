"""Expert-parallel MoE a2a bench + CI smoke (``--smoke`` -> ``BENCH_moe.json``).

The EP overlap claim made gateable: for every MoE shape the fused
``compile_overlap(["a2a_dispatch", "combine_rs"])`` program must beat the
split dispatch + combine pair on the MODELED cost scale — the fusion credits
``min(fill_drain(dispatch), fill_drain(combine))``, the exposed-exchange time
the shared pipeline hides under the grouped GEMMs, so a fused plan that does
not win means the a2a costing (or the candidate enumeration behind
``channel="auto"``) broke.

``--smoke`` additionally:

  * runs ``tune.resolve_a2a`` end-to-end on the smallest shape and asserts
    it verdicts FUSED with one shared channel for both halves;
  * sweeps the verifier over the a2a pair's candidate space (orders x worlds
    {2,3,4,8} x channels) and records the proved plan count — zero failures
    or the smoke fails;
  * measures overlapped vs. baseline (bulk AG + GroupGEMM + RS) wall time for
    the smallest shape on a 4-rank emulated mesh (informational on CPU —
    emulated wall time is not a perf signal, ROADMAP) and checks numerical
    parity between the two paths.

Modeled costs land under ungated ``*_modeled_us`` leaves; the per-shape
``ok`` health leaf (fused wins modeled) and ``considered`` (candidate count)
gate exactly via benchmarks/compare.py.  Any violation exits non-zero so CI
fails loudly.
"""
import argparse
import json
import sys

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import tune
from repro.compat import shard_map
from repro.core import BlockChannel, compile_overlap
from repro.core.moe_overlap import moe_router
from repro.tune import cost as tune_cost

try:  # package import (python -m benchmarks.moe_bench / pytest)
    from benchmarks.common import mesh_tp, row, time_fn
except ImportError:  # plain script: the benchmarks/ dir is sys.path[0]
    from common import mesh_tp, row, time_fn

WORLD = 4

# MoE a2a signatures (m_loc, d_model, top_k, e_loc, d_expert), per shard at
# world=4, paper-class shapes / common.SCALE: deepseek-moe-16b routes top-6 of
# 64 experts at d=2048/f=1408; granite-3b-a800m top-8 of 40 at d=1536/f=512
MOE_SHAPES = {
    "small": (32, 16, 2, 2, 8),
    "deepseek-16b": (512, 256, 6, 16, 176),
    "granite-3b": (512, 192, 8, 10, 64),
}


def _best(sig, *, fused):
    """(cost_us, candidate, considered) of the cheapest shared-channel pair."""
    cands = tune.enumerate_a2a_candidates(sig=sig, world=WORLD)
    if not cands:
        raise ValueError(f"no a2a candidates for sig={sig}")
    best = min(cands, key=lambda c: tune_cost.predict_a2a_cost(sig, WORLD, c, fused=fused))
    return (tune_cost.predict_a2a_cost(sig, WORLD, best, fused=fused) * 1e6,
            best, len(cands))


def _measured_case(mesh, sig):
    """Jitted overlapped + baseline EP MoE callables over global operands."""
    m_loc, d, k_top, e_loc, f = sig
    e = e_loc * WORLD
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (WORLD * m_loc, d), jnp.float32) * 0.5
    wr = jax.random.normal(jax.random.PRNGKey(1), (d, e), jnp.float32)
    wgu = jax.random.normal(jax.random.PRNGKey(2), (e, d, 2 * f), jnp.float32) * 0.1
    wdn = jax.random.normal(jax.random.PRNGKey(3), (e, f, d), jnp.float32) * 0.1
    ch = BlockChannel(axis="model", num_channels=2)
    specs = dict(
        in_specs=(P("model", None), P("model", None, None), P("model", None, None)),
        out_specs=P("model", None),
    )

    def body(fn):
        def f_(xs, wgu_, wdn_):
            ids, wts, _ = moe_router(xs, wr, num_experts=e, top_k=k_top)
            return fn(xs, ids, wts, wgu_, wdn_)
        return jax.jit(shard_map(f_, mesh, **specs))

    o_fn = body(compile_overlap(["a2a_dispatch", "combine_rs"], channel=ch,
                                capacity_factor=2.0))
    b_fn = body(compile_overlap(["a2a_dispatch", "combine_rs"], channel=ch,
                                overlapped=False, capacity_factor=2.0))
    return o_fn, b_fn, (x, wgu, wdn)


def smoke(out_path: str = "BENCH_moe.json") -> int:
    results, failures = {"shapes": {}}, []

    for name, sig in MOE_SHAPES.items():
        entry = {"signature": list(sig)}
        try:
            fused_us, cand, considered = _best(sig, fused=True)
            unfused_us, _, _ = _best(sig, fused=False)
            saving_us = tune_cost.a2a_saving(sig, WORLD, cand) * 1e6
            ok = fused_us < unfused_us
            if not ok:
                failures.append(
                    f"{name}: fused modeled cost {fused_us:.1f}us does not beat "
                    f"the split pair {unfused_us:.1f}us — the a2a overlap credit is dead"
                )
            entry.update(
                winner=cand.label(),
                considered=considered,
                fused_modeled_us=round(fused_us, 3),
                unfused_modeled_us=round(unfused_us, 3),
                modeled_saving_us=round(saving_us, 3),
                ok=ok,
            )
            row(f"moe/{name}/modeled/{cand.label()}", fused_us,
                f"unfused {unfused_us:.0f}us")
        except Exception as exc:  # loud: any a2a-costing error fails CI
            failures.append(f"{name}: {type(exc).__name__}: {exc}")
            entry["error"] = str(exc)
        results["shapes"][name] = entry

    # ---- the auto path verdicts FUSED with one shared channel --------------
    try:
        fused, ch_d, ch_c = tune.resolve_a2a(sig=MOE_SHAPES["small"], world=WORLD)
        shared = (ch_d.num_channels == ch_c.num_channels
                  and ch_d.comm.order == ch_c.comm.order)
        if not fused:
            failures.append("resolve_a2a verdicted UNFUSED on an EP MoE shape")
        if not shared:
            failures.append("resolve_a2a returned mismatched dispatch/combine channels")
        results["resolve"] = {"fused": bool(fused), "ok": bool(fused and shared),
                              "channels": [ch_d.num_channels, ch_c.num_channels]}
    except Exception as exc:
        failures.append(f"resolve: {type(exc).__name__}: {exc}")
        results["resolve"] = {"error": str(exc), "ok": False}

    # ---- the verifier proves the whole a2a candidate space -----------------
    try:
        from repro.analysis.verify import verify_seq_space

        plans = checks = 0
        for rep in verify_seq_space(kinds=("a2a_dispatch", "combine_rs")):
            plans += 1
            checks += len(rep.passes)
        ok = plans > 0
        if not ok:
            failures.append("verify: empty a2a plan space")
        results["verify"] = {"plans": plans, "passes": checks, "ok": ok}
    except Exception as exc:  # loud: a verifier rejection IS the failure
        failures.append(f"verify: {type(exc).__name__}: {exc}")
        results["verify"] = {"error": str(exc), "ok": False}

    # ---- smoke-measured overlapped vs baseline + parity (emulated mesh) ----
    try:
        mesh = mesh_tp(WORLD)
        o_fn, b_fn, args = _measured_case(mesh, MOE_SHAPES["small"])
        yo = o_fn(*args)
        yb = b_fn(*args)
        err = float(jnp.max(jnp.abs(yo - yb)))
        parity_ok = err < 1e-3
        if not parity_ok:
            failures.append(f"measured: overlapped vs baseline parity error {err:.3e}")
        overlapped_us = time_fn(o_fn, *args)
        baseline_us = time_fn(b_fn, *args)
        results["measured"] = {
            "overlapped": {"us": round(overlapped_us, 1)},
            "baseline": {"us": round(baseline_us, 1)},
            "max_abs_err": err,
            "ok": parity_ok,
        }
        row("moe/small/measured/overlapped", overlapped_us)
        row("moe/small/measured/baseline", baseline_us)
    except Exception as exc:  # loud: the executor path must run on CPU
        failures.append(f"measured: {type(exc).__name__}: {exc}")
        results["measured"] = {"error": str(exc), "ok": False}

    with open(out_path, "w") as fh:
        json.dump(results, fh, indent=2, sort_keys=True)
    print(f"wrote {out_path}: {len(results['shapes'])} shapes, {len(failures)} failures")
    for f_ in failures:
        print(f"FAIL {f_}", file=sys.stderr)
    return 1 if failures else 0


def main() -> int:
    print("# modeled fused vs split a2a dispatch/combine cost per MoE shape "
          f"(world={WORLD})")
    for name, sig in MOE_SHAPES.items():
        fused_us, cand, _ = _best(sig, fused=True)
        unfused_us, _, _ = _best(sig, fused=False)
        row(f"moe/{name}/{cand.label()}", fused_us,
            f"unfused {unfused_us:.0f}us ({unfused_us / max(fused_us, 1e-9):.2f}x)")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="CI guard: modeled fused-beats-split on every MoE shape, "
        "resolve_a2a verdict, verifier plan-space sweep, measured parity; "
        "write BENCH_moe.json",
    )
    ap.add_argument("--out", default="BENCH_moe.json")
    a = ap.parse_args()
    sys.exit(smoke(a.out) if a.smoke else main())
