"""Benchmark harness entry — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  CPU relative speedups mirror the
paper's evaluation axis; absolute roofline projections live in EXPERIMENTS.md.
"""
import sys
import traceback

# common must be imported first: it pins the simulated device count
from benchmarks import common  # noqa: F401

from benchmarks import (
    tab2_motivational, fig8_mlp, fig9_moe, fig10_attention, fig11_e2e,
    kernel_bench,
)

TABLES = [
    ("tab2", tab2_motivational),
    ("fig8", fig8_mlp),
    ("fig9", fig9_moe),
    ("fig10", fig10_attention),
    ("fig11", fig11_e2e),
    ("kernel", kernel_bench),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = []
    for name, mod in TABLES:
        if only and only != name:
            continue
        try:
            mod.main()
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
