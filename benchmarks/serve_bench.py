"""Serving-engine bench + CI smoke (``--smoke`` -> ``BENCH_serve.json``).

Drives the request-level continuous-batching engine end to end on the
emulated mesh: heterogeneous prompts/budgets over a slot pool smaller than
the request count, so admission, block decode, and eviction all exercise.
Reports tokens/s and inter-token latency percentiles (informational on CPU —
emulated wall time is not a perf signal, ROADMAP; the ``us`` leaf is
tolerance-gated like every other smoke timing) and GATES the engine's
no-per-token-round-trip contract:

  * ``host_syncs == steps`` — exactly ONE device_get per step, however many
    tokens the block decode emitted;
  * ``step_traces == 1`` — static shapes: the jit'd step traces once, ever;
  * every request finishes with exactly its ``max_new_tokens`` tokens
    (greedy, no eos) and matches a second engine run token for token.

Violations land in the ``ok`` health leaf and exit non-zero so CI fails
loudly.
"""
import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.train import reduce_config
from repro.models import lm
from repro.parallel.context import ParallelContext
from repro.parallel.sharding import place
from repro.serving import Request, ServeEngine

try:  # package import (python -m benchmarks.serve_bench / pytest)
    from benchmarks.common import mesh_tp, row
except ImportError:  # plain script: the benchmarks/ dir is sys.path[0]
    from common import mesh_tp, row

WORLD = 4
PROMPT_LENS = (5, 13, 9, 7)
BUDGETS = (6, 10, 4, 8)


def _build_engine(**over):
    mesh = mesh_tp(WORLD)
    pc = ParallelContext(mesh=mesh, mode="overlap")
    cfg = reduce_config(get_config("smollm-360m"))
    cfg = dataclasses.replace(cfg, vocab_size=128)
    params = place(lm.init(jax.random.PRNGKey(0), cfg, pc, jnp.float32),
                   mesh, lm.specs(cfg, pc))
    kw = dict(max_len=64, n_slots=2, decode_block=8)
    kw.update(over)
    return ServeEngine(cfg, pc, params, **kw), cfg


def _run(eng):
    """Drain a heterogeneous request mix; returns (outputs, per-step stats)."""
    rng = np.random.default_rng(0)
    handles = [
        eng.submit(Request(tokens=rng.integers(0, 128, size=ln).astype(np.int32),
                           max_new_tokens=b))
        for ln, b in zip(PROMPT_LENS, BUDGETS)
    ]
    durs, toks = [], []
    while eng.scheduler.has_work:
        t0 = time.perf_counter()
        out = eng.step()  # blocks on its own single device_get
        durs.append(time.perf_counter() - t0)
        toks.append(sum(len(v) for v in out.values()))
    outs = {h: np.asarray(eng.scheduler.states[h].generated, np.int32)
            for h in handles}
    return outs, durs, toks


def smoke(out_path: str = "BENCH_serve.json") -> int:
    failures = []
    eng, _ = _build_engine()
    outs, durs, toks = _run(eng)

    steps, syncs = eng.stats["steps"], eng.stats["host_syncs"]
    traces = eng.stats["step_traces"]
    if syncs != steps:
        failures.append(f"host_syncs {syncs} != steps {steps} — the step "
                        "must sync the host exactly once")
    if traces != 1:
        failures.append(f"step_traces {traces} != 1 — shapes are static, the "
                        "jit'd step may trace only once")
    for h, budget in zip(sorted(outs), BUDGETS):
        if len(outs[h]) != budget:
            failures.append(f"request {h}: {len(outs[h])} tokens, wanted "
                            f"exactly {budget}")

    # determinism: a fresh engine must reproduce every greedy stream
    eng2, _ = _build_engine()
    outs2, _, _ = _run(eng2)
    if not all(np.array_equal(outs[h], outs2[h]) for h in outs):
        failures.append("greedy decode is not reproducible across engines")

    total_tokens = int(sum(toks))
    total_s = float(sum(durs))
    # every token in a step shares that step's wall time
    itl = np.concatenate([np.full(n, d / n) for d, n in zip(durs, toks) if n]
                         or [np.zeros(1)])
    results = {"smoke": {
        "requests": len(BUDGETS),
        "tokens": total_tokens,
        "steps": steps,
        "host_syncs_per_step": round(syncs / max(steps, 1), 3),
        "step_traces": traces,
        "tokens_per_s": round(total_tokens / max(total_s, 1e-9), 1),
        "itl_p50_ms": round(float(np.percentile(itl, 50)) * 1e3, 3),
        "itl_p99_ms": round(float(np.percentile(itl, 99)) * 1e3, 3),
        "step": {"us": round(total_s / max(steps, 1) * 1e6, 1)},
        "ok": not failures,
    }}
    with open(out_path, "w") as fh:
        json.dump(results, fh, indent=2, sort_keys=True)
    print(f"wrote {out_path}: {total_tokens} tokens over {steps} steps, "
          f"{len(failures)} failures")
    row("serve/smoke/step", results["smoke"]["step"]["us"],
        f"{results['smoke']['tokens_per_s']:.0f} tok/s")
    for f_ in failures:
        print(f"FAIL {f_}", file=sys.stderr)
    return 1 if failures else 0


def main() -> int:
    print("# continuous-batching engine on the emulated mesh "
          f"(world={WORLD}, slots=2)")
    eng, _ = _build_engine()
    _, durs, toks = _run(eng)
    for i, (d, n) in enumerate(zip(durs, toks)):
        row(f"serve/step{i}", d * 1e6, f"{n} tokens")
    total = sum(toks)
    row("serve/total", sum(durs) * 1e6,
        f"{total / max(sum(durs), 1e-9):.0f} tok/s")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="CI guard: one host sync per step, one trace ever, exact token "
        "counts, reproducible greedy streams; write BENCH_serve.json",
    )
    ap.add_argument("--out", default="BENCH_serve.json")
    a = ap.parse_args()
    sys.exit(smoke(a.out) if a.smoke else main())
