"""Decoupled design-space sweep (paper §3.1) through the compiled frontend.

Default mode: the paper's argument that communication and computation must
tune independently — comm tile count (channels, f_C) x tile order for
AG+GEMM, timed against the C=1 ring base.

``--smoke``: CI guard for the plan layer.  Sweeps a few ``BlockChannel``
design points through ``compile_overlap`` for every workload kind, checks
each against its non-overlapping baseline, times it, and emits
``BENCH_kernels.json``.  Any parity failure or compile error exits non-zero,
so schedule regressions fail the build loudly.
"""
import argparse
import json
import sys

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import BlockChannel, CommSpec, CompSpec, compile_overlap
from repro.core.moe_overlap import moe_router

try:  # package import (python -m benchmarks.kernel_bench / pytest)
    from benchmarks.common import mesh8, mesh_tp, time_fn, row
except ImportError:  # plain script: the benchmarks/ dir is sys.path[0]
    from common import mesh8, mesh_tp, time_fn, row


def main():
    mesh = mesh8()
    key = jax.random.PRNGKey(0)
    s, h, i = 2048, 512, 1408
    x = jax.device_put(jax.random.normal(key, (s, h), jnp.float32),
                       NamedSharding(mesh, P("model", None)))
    w = jax.device_put(jax.random.normal(key, (h, i), jnp.float32),
                       NamedSharding(mesh, P(None, "model")))
    base = None
    for channels in (1, 2, 4):
        for order in ("ring", "bidir_ring", "all2all"):
            ch = BlockChannel(axis="model", num_channels=channels,
                              comm=CommSpec(order=order))
            fn = jax.jit(shard_map(
                compile_overlap("ag_matmul", ch),
                mesh, in_specs=(P("model", None), P(None, "model")),
                out_specs=P(None, "model")))
            t = time_fn(fn, x, w)
            if base is None:
                base = t
            row(f"kernel/ag_gemm/C={channels}/{order}", t, f"{base/t:.2f}x")


# ---- --smoke: sweep the plan layer across every kind ------------------------

SMOKE_POINTS = [
    # (order, num_channels, accum_dtype)
    ("ring", 1, "float32"),
    ("ring", 2, "float32"),
    ("bidir_ring", 2, "float32"),
    ("all2all", 1, "float32"),
    ("ring", 2, "bfloat16"),
]


def _smoke_cases(mesh, r):
    """kind -> (overlap fn(ch), baseline fn, args) on tiny shapes."""
    key = jax.random.PRNGKey(0)
    m, k, n = r * 16, 32, 32
    x_ag = jax.random.normal(key, (m, k), jnp.float32)
    w_ag = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    x_rs = jax.random.normal(key, (m, r * 16), jnp.float32)
    w_rs = jax.random.normal(jax.random.PRNGKey(2), (r * 16, n), jnp.float32)
    b, hh, sq, d = 1, 2, r * 16, 16
    q = jax.random.normal(key, (b, hh, sq, d))
    kv = jax.random.normal(jax.random.PRNGKey(3), (b, 1, sq, d))
    e, ktop, dm, f = 8, 2, 16, 16
    x_moe = jax.random.normal(key, (r * 16, dm)) * 0.5
    wr = jax.random.normal(jax.random.PRNGKey(4), (dm, e))
    wgu = jax.random.normal(jax.random.PRNGKey(5), (e, dm, 2 * f)) * 0.1
    wdn = jax.random.normal(jax.random.PRNGKey(6), (e, f, dm)) * 0.1

    def sm(fn, in_specs, out_specs):
        return jax.jit(shard_map(fn, mesh, in_specs=in_specs,
                                 out_specs=out_specs))

    def moe_wrap(ch, overlapped):
        g = compile_overlap("ag_moe", ch, overlapped=overlapped,
                            capacity_factor=8.0)

        def f_(xs, wgu_, wdn_):
            ids, wts, _ = moe_router(xs, wr, num_experts=e, top_k=ktop)
            return g(xs, ids, wts, wgu_, wdn_)
        return f_

    mspecs = (P("model", None), P("model", None, None), P("model", None, None))
    return {
        "ag_matmul": (
            lambda ch, ov: sm(compile_overlap("ag_matmul", ch, overlapped=ov),
                              (P("model", None), P(None, None)), P(None, None)),
            (x_ag, w_ag)),
        "matmul_rs": (
            lambda ch, ov: sm(compile_overlap("matmul_rs", ch, overlapped=ov),
                              (P(None, "model"), P("model", None)),
                              P("model", None)),
            (x_rs, w_rs)),
        "ag_attention": (
            lambda ch, ov: sm(compile_overlap("ag_attention", ch, overlapped=ov,
                                              causal=True),
                              (P(None, None, "model"),) * 3,
                              P(None, None, "model")),
            (q, kv, kv)),
        "ag_moe": (
            lambda ch, ov: sm(moe_wrap(ch, ov), mspecs, P("model", None)),
            (x_moe, wgu, wdn)),
    }


def smoke(out_path: str = "BENCH_kernels.json") -> int:
    r = 4
    mesh = mesh_tp(r)
    cases = _smoke_cases(mesh, r)
    results, failures = {}, []
    for kind, (build, args) in cases.items():
        base_fn = build(BlockChannel(axis="model"), False)
        ref = base_fn(*args)
        base_us = time_fn(base_fn, *args, repeats=3, warmup=1)
        for order, nch, accum in SMOKE_POINTS:
            tag = f"{kind}/{order}/C={nch}/{accum}"
            ch = BlockChannel(axis="model", num_channels=nch,
                              comm=CommSpec(order=order),
                              comp=CompSpec(accum_dtype=accum))
            try:
                fn = build(ch, True)
                y = fn(*args)
                tol = 1e-3 if accum == "float32" else 1e-1
                err = float(jnp.max(jnp.abs(
                    jnp.asarray(y, jnp.float32) - jnp.asarray(ref, jnp.float32))))
                ok = bool(err < tol * max(1.0, float(jnp.max(jnp.abs(ref)))))
                us = time_fn(fn, *args, repeats=3, warmup=1)
            except Exception as exc:  # loud: any compile/run error fails CI
                failures.append(f"{tag}: {type(exc).__name__}: {exc}")
                results[tag] = {"error": str(exc)}
                continue
            if not ok:
                failures.append(f"{tag}: parity error {err:.3e} (tol {tol})")
            results[tag] = {
                "us": round(us, 1),
                "baseline_us": round(base_us, 1),
                "speedup_vs_nonoverlap": round(base_us / us, 3),
                "max_abs_err": err,
                "ok": ok,
            }
            row(f"smoke/{tag}", us, f"{base_us/us:.2f}x")
    with open(out_path, "w") as fh:
        json.dump(results, fh, indent=2, sort_keys=True)
    print(f"wrote {out_path}: {len(results)} design points, "
          f"{len(failures)} failures")
    for f_ in failures:
        print(f"FAIL {f_}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sweep of BlockChannel configs through "
                         "compile_overlap; writes BENCH_kernels.json")
    ap.add_argument("--out", default="BENCH_kernels.json")
    a = ap.parse_args()
    sys.exit(smoke(a.out) if a.smoke else main())
