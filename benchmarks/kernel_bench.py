"""Decoupled design-space sweep (paper §3.1): comm tile count (channels, f_C)
and tile order (ring vs bidirectional) for AG+GEMM — the paper's argument that
communication and computation must tune independently."""
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import overlap, BlockChannel, CommSpec
from benchmarks.common import mesh8, time_fn, row


def main():
    mesh = mesh8()
    key = jax.random.PRNGKey(0)
    s, h, i = 2048, 512, 1408
    x = jax.device_put(jax.random.normal(key, (s, h), jnp.float32),
                       NamedSharding(mesh, P("model", None)))
    w = jax.device_put(jax.random.normal(key, (h, i), jnp.float32),
                       NamedSharding(mesh, P(None, "model")))
    base = None
    for channels in (1, 2, 4):
        for order in ("ring", "bidir_ring"):
            ch = BlockChannel(axis="model", num_channels=channels,
                              comm=CommSpec(order=order))
            fn = jax.jit(shard_map(
                lambda a, b: overlap.ag_matmul(a, b, axis="model", channel=ch),
                mesh, in_specs=(P("model", None), P(None, "model")),
                out_specs=P(None, "model")))
            t = time_fn(fn, x, w)
            if base is None:
                base = t
            row(f"kernel/ag_gemm/C={channels}/{order}", t, f"{base/t:.2f}x")


if __name__ == "__main__":
    main()
