"""Paper Fig. 8: six TP-MLP shapes — AG+GEMM, GEMM+RS, and the full MLP
(AG+GEMM -> SiLU-Mul -> GEMM+RS), overlap vs non-overlap."""
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import overlap
from repro.configs.paper import PAPER_MLP
from benchmarks.common import SCALE, mesh8, time_fn, row


def full_mlp(mode):
    def f(x, w1, w2):
        if mode == "overlap":
            h = overlap.ag_matmul(x, w1, axis="model")
            f_loc = h.shape[-1] // 2
            a = jax.nn.silu(h[..., :f_loc]) * h[..., f_loc:]
            return overlap.matmul_rs(a, w2, axis="model")
        h = overlap.ag_matmul_baseline(x, w1, axis="model")
        f_loc = h.shape[-1] // 2
        a = jax.nn.silu(h[..., :f_loc]) * h[..., f_loc:]
        return overlap.matmul_rs_baseline(a, w2, axis="model")
    return f


def main():
    mesh = mesh8()
    key = jax.random.PRNGKey(0)
    for name, (s, h, i, src) in PAPER_MLP.items():
        s_, h_, i_ = s // SCALE, h // SCALE, (i // SCALE // 16) * 16
        x = jax.device_put(jax.random.normal(key, (s_, h_), jnp.float32),
                           NamedSharding(mesh, P("model", None)))
        w1 = jax.device_put(jax.random.normal(key, (h_, 2 * i_), jnp.float32),
                            NamedSharding(mesh, P(None, "model")))
        w2 = jax.device_put(jax.random.normal(key, (i_, h_), jnp.float32),
                            NamedSharding(mesh, P("model", None)))
        specs = ((P("model", None), P(None, "model"), P("model", None)),
                 P("model", None))
        base = jax.jit(shard_map(full_mlp("baseline"), mesh,
                                 in_specs=specs[0], out_specs=specs[1]))
        tl = jax.jit(shard_map(full_mlp("overlap"), mesh,
                               in_specs=specs[0], out_specs=specs[1]))
        tb = time_fn(base, x, w1, w2)
        tt = time_fn(tl, x, w1, w2)
        row(f"fig8/{name}({src})/non-overlap", tb, "1.00x")
        row(f"fig8/{name}({src})/tilelink", tt, f"{tb/tt:.2f}x")


if __name__ == "__main__":
    main()
