"""Autotuner bench + CI smoke (``--smoke`` -> ``BENCH_autotune.json``).

Default mode: tune a small sweep of shapes per kind with the analytic
cost-model ranker and print each shape's winning design point — the paper's
§3.1 point made concrete: the winner changes with the shape.

``--smoke``: CI guard for the tuning subsystem.  For every workload kind it
tunes one shape with the cost-model ranker (emulated-CPU wall time is not a
perf signal; ROADMAP), then asserts the full cache contract:

  1. a second ``autotune`` call is a cache HIT returning the same winner;
  2. the hit survives a process-memo flush (disk round-trip);
  3. the winner, realized through ``compile_overlap``, is parity-equal to
     the explicit default-``BlockChannel`` path (tolerance matched to the
     winner's flow dtype).

The smoke additionally sweeps the JOINT (CommSpec x CompSpec) space per
kind (ISSUE 4): every joint winner must stay parity-equal to the
default-tile lowering, and at least one GEMM shape must resolve a compute
tile that genuinely differs from the (128, 128, 128) default — the
decoupled compute half is searchable, not decorative.  Joint winners land
in ``BENCH_autotune.json`` under each kind's ``joint`` entry
(``benchmarks/compare.py`` gates their candidate counts exactly).

Any violation exits non-zero so CI fails loudly.
"""
import argparse
import json
import sys
import tempfile

import jax.numpy as jnp

from repro import tune
from repro.core import BlockChannel
from repro.core.comp_tiles import DEFAULT_TILE
from repro.tune import cache as tune_cache
from repro.tune import cost as tune_cost
from repro.tune.measure import build_case, time_fn

try:  # package import (python -m benchmarks.autotune_bench / pytest)
    from benchmarks.common import mesh_tp, row
except ImportError:  # plain script: the benchmarks/ dir is sys.path[0]
    from common import mesh_tp, row

# one per-shard signature per kind (see repro.tune.signature for the layout)
SMOKE_SHAPES = {
    "ag_matmul": (1, 32, 32, 32),  # (lead, m_loc, k, n_loc)
    "matmul_rs": (1, 64, 16, 32),  # (lead, m_glob, k_loc, n)
    "ag_attention": (1, 2, 1, 32, 16),  # (b, h, hkv, s_loc, d)
    "ag_moe": (32, 16, 2, 2, 16),  # (m_loc, d_model, top_k, e_loc, f)
}

# joint-space shapes: the GEMM kinds get extents large enough that explicit
# MXU blocking can beat the default tile under the per-tile cost terms
JOINT_SMOKE_SHAPES = {
    "ag_matmul": (1, 256, 512, 256),
    "matmul_rs": (1, 1024, 128, 512),
    "ag_attention": (1, 2, 1, 32, 16),
    "ag_moe": (32, 16, 2, 2, 16),
}

SWEEP_SHAPES = {
    "ag_matmul": [(1, 32, 64, 64), (1, 512, 1024, 512), (1, 4096, 8192, 4096)],
    "matmul_rs": [(1, 128, 32, 64), (1, 4096, 512, 1024), (1, 32768, 1024, 4096)],
    "ag_attention": [(1, 4, 1, 64, 32), (4, 16, 2, 1024, 128), (8, 16, 2, 4096, 128)],
    "ag_moe": [(64, 32, 2, 2, 32), (2048, 512, 2, 8, 256), (8192, 1024, 2, 16, 512)],
}


def _tol(accum_dtype: str) -> float:
    return 1e-3 if accum_dtype == "float32" else 1e-1


def _check_winner(kind, result, mesh):
    """(parity_err, parity_ok, us): the realized winner vs. the explicit
    default-BlockChannel path, plus its wall time (informational on CPU)."""
    build, args = build_case(kind, mesh, result.channel.axis, result.signature)
    fn = build(result.channel)
    got = fn(*args)
    ref = build(BlockChannel(axis=result.channel.axis))(*args)
    ref32 = jnp.asarray(ref, jnp.float32)
    err = float(jnp.max(jnp.abs(jnp.asarray(got, jnp.float32) - ref32)))
    ok = err < _tol(result.candidate.accum_dtype) * max(1.0, float(jnp.max(jnp.abs(ref32))))
    return err, ok, time_fn(fn, *args, repeats=3, warmup=1)


def smoke(out_path: str = "BENCH_autotune.json") -> int:
    mesh = mesh_tp(4)
    cache_dir = tempfile.mkdtemp(prefix="repro-tune-smoke-")
    results, failures = {}, []
    for kind, sig in SMOKE_SHAPES.items():
        entry = {"signature": list(sig)}
        kw = dict(signature=sig, mesh=mesh, ranker="model", cache_dir=cache_dir)
        try:
            first = tune.autotune(kind, **kw)
            again = tune.autotune(kind, **kw)
            tune_cache.clear_memo()  # force the disk read
            rt = tune.autotune(kind, **kw)
            if first.cache_hit:
                failures.append(f"{kind}: first tune was already a cache hit")
            for name, res in (("memo", again), ("disk", rt)):
                if not res.cache_hit:
                    failures.append(f"{kind}: {name} lookup re-tuned instead of hitting the cache")
                if res.candidate != first.candidate:
                    failures.append(
                        f"{kind}: {name} round-trip changed the winner "
                        f"{first.candidate} -> {res.candidate}"
                    )
            err, ok, us = _check_winner(kind, first, mesh)
            if not ok:
                failures.append(f"{kind}: auto-channel parity error {err:.3e}")
            entry.update(
                winner=first.candidate.label(),
                predicted=tune_cost.explain(kind, sig, 4, first.candidate),
                cache_round_trip=bool(again.cache_hit and rt.cache_hit),
                max_abs_err=err,
                us=round(us, 1),
                considered=first.considered,
            )
            row(f"autotune/{kind}/{first.candidate.label()}", us)
        except Exception as exc:  # loud: any tuner error fails CI
            failures.append(f"{kind}: {type(exc).__name__}: {exc}")
            entry["error"] = str(exc)
        results[kind] = entry

    # ---- joint (CommSpec x CompSpec) sweep — ISSUE 4 acceptance ------------
    non_default_tiles = 0
    for kind, sig in JOINT_SMOKE_SHAPES.items():
        entry = {"signature": list(sig)}
        try:
            res = tune.autotune(
                kind,
                signature=sig,
                mesh=mesh,
                ranker="model",
                cache_dir=cache_dir,
                space=tune.JOINT_SPACE,
            )
            err, ok, us = _check_winner(kind, res, mesh)
            if not ok:
                failures.append(f"{kind}: joint-winner parity error {err:.3e}")
            if tuple(res.candidate.comp_tile) != DEFAULT_TILE:
                non_default_tiles += 1
            entry.update(
                winner=res.candidate.label(),
                comp_tile=list(res.candidate.comp_tile),
                max_abs_err=err,
                us=round(us, 1),
                considered=res.considered,
            )
            row(f"autotune/joint/{kind}/{res.candidate.label()}", us)
        except Exception as exc:  # loud: any tuner error fails CI
            failures.append(f"joint/{kind}: {type(exc).__name__}: {exc}")
            entry["error"] = str(exc)
        results[kind]["joint"] = entry
    if non_default_tiles == 0:
        failures.append(
            "joint sweep: no shape resolved a compute tile different from "
            f"{DEFAULT_TILE} — the CompSpec half of the search is dead"
        )

    with open(out_path, "w") as fh:
        json.dump(results, fh, indent=2, sort_keys=True)
    print(f"wrote {out_path}: {len(results)} kinds, {len(failures)} failures")
    for f_ in failures:
        print(f"FAIL {f_}", file=sys.stderr)
    return 1 if failures else 0


def main(world: int) -> int:
    print(f"# cost-model winners per shape (world={world}); the point of the")
    print("# paper's decoupling: the best design point is shape-dependent")
    for kind, shapes in SWEEP_SHAPES.items():
        for sig in shapes:
            cands = tune.enumerate_candidates(kind, extent=tune.chunk_extent(kind, sig))
            best = min(cands, key=lambda c: tune_cost.predict_cost(kind, sig, world, c))
            us = tune_cost.predict_cost(kind, sig, world, best) * 1e6
            row(f"tune/{kind}/{'x'.join(map(str, sig))}/{best.label()}", us)
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="CI guard: tune one shape per kind, assert the cache round-trip, "
        "write BENCH_autotune.json",
    )
    ap.add_argument("--out", default="BENCH_autotune.json")
    ap.add_argument("--world", type=int, default=8, help="ring size for the cost-model sweep")
    a = ap.parse_args()
    sys.exit(smoke(a.out) if a.smoke else main(a.world))
