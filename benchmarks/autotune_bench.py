"""Autotuner bench + CI smoke (``--smoke`` -> ``BENCH_autotune.json``).

Default mode: tune a small sweep of shapes per kind with the analytic
cost-model ranker and print each shape's winning design point — the paper's
§3.1 point made concrete: the winner changes with the shape.

``--smoke``: CI guard for the tuning subsystem.  For every workload kind it
tunes one shape with the cost-model ranker (emulated-CPU wall time is not a
perf signal; ROADMAP), then asserts the full cache contract:

  1. a second ``autotune`` call is a cache HIT returning the same winner;
  2. the hit survives a process-memo flush (disk round-trip);
  3. the winner, realized through ``compile_overlap``, is parity-equal to
     the explicit default-``BlockChannel`` path (tolerance matched to the
     winner's flow dtype).

The smoke additionally sweeps the JOINT (CommSpec x CompSpec) space per
kind (ISSUE 4): every joint winner must stay parity-equal to the
default-tile lowering, and at least one GEMM shape must resolve a compute
tile that genuinely differs from the (128, 128, 128) default — the
decoupled compute half is searchable, not decorative.  Since ISSUE 5 the
attention/MoE consumers have compute-tile axes too: their joint spaces
must be wider than the comm-only 18 points.  Joint winners land in
``BENCH_autotune.json`` under each kind's ``joint`` entry
(``benchmarks/compare.py`` gates their candidate counts exactly).

The measured-sweep section (ISSUE 5) asserts the early-exit pruning
contract per (kind, shape): at most 50% of the joint space is ever timed,
at least 50% is pruned unmeasured, and the pruned sweep returns the SAME
winner as the exhaustive full-repeat sweep.  Emulated-CPU wall time is not
a perf signal (ROADMAP), so the smoke drives both sweeps through ONE
deterministic oracle (the analytic cost in us plus a stable per-candidate
skew) — the algorithm is what CI can verify; real timings come from a TPU
runner.  The pruning ledger lands under each kind's ``sweep`` entry
(``total``/``screened``/``timed``/``pruned`` gate exactly).

Any violation exits non-zero so CI fails loudly.
"""
import argparse
import hashlib
import json
import sys
import tempfile

import jax.numpy as jnp

from repro import tune
from repro.core import BlockChannel
from repro.core.comp_tiles import DEFAULT_TILE
from repro.core.plan import plan_cache_info
from repro.tune import cache as tune_cache
from repro.tune import cost as tune_cost
from repro.tune import sweep as tune_sweep
from repro.tune.measure import build_case, time_fn

try:  # package import (python -m benchmarks.autotune_bench / pytest)
    from benchmarks.common import mesh_tp, row
except ImportError:  # plain script: the benchmarks/ dir is sys.path[0]
    from common import mesh_tp, row

# one per-shard signature per kind (see repro.tune.signature for the layout)
SMOKE_SHAPES = {
    "ag_matmul": (1, 32, 32, 32),  # (lead, m_loc, k, n_loc)
    "matmul_rs": (1, 64, 16, 32),  # (lead, m_glob, k_loc, n)
    "ag_attention": (1, 2, 1, 32, 16),  # (b, h, hkv, s_loc, d)
    "ag_moe": (32, 16, 2, 2, 16),  # (m_loc, d_model, top_k, e_loc, f)
}

# joint-space shapes: the GEMM kinds get extents large enough that explicit
# MXU blocking can beat the default tile under the per-tile cost terms; the
# attention/MoE shapes are large enough that their tile lattices survive
# divisor/alignment pruning (ISSUE 5)
JOINT_SMOKE_SHAPES = {
    "ag_matmul": (1, 256, 512, 256),
    "matmul_rs": (1, 1024, 128, 512),
    "ag_attention": (1, 2, 1, 64, 32),
    "ag_moe": (32, 16, 2, 2, 16),
}

SWEEP_SHAPES = {
    "ag_matmul": [(1, 32, 64, 64), (1, 512, 1024, 512), (1, 4096, 8192, 4096)],
    "matmul_rs": [(1, 128, 32, 64), (1, 4096, 512, 1024), (1, 32768, 1024, 4096)],
    "ag_attention": [(1, 4, 1, 64, 32), (4, 16, 2, 1024, 128), (8, 16, 2, 4096, 128)],
    "ag_moe": [(64, 32, 2, 2, 32), (2048, 512, 2, 8, 256), (8192, 1024, 2, 16, 512)],
}


def _tol(accum_dtype: str) -> float:
    return 1e-3 if accum_dtype == "float32" else 1e-1


def _check_winner(kind, result, mesh):
    """(parity_err, parity_ok, us): the realized winner vs. the explicit
    default-BlockChannel path, plus its wall time (informational on CPU)."""
    build, args = build_case(kind, mesh, result.channel.axis, result.signature)
    fn = build(result.channel)
    got = fn(*args)
    ref = build(BlockChannel(axis=result.channel.axis))(*args)
    ref32 = jnp.asarray(ref, jnp.float32)
    err = float(jnp.max(jnp.abs(jnp.asarray(got, jnp.float32) - ref32)))
    ok = err < _tol(result.candidate.accum_dtype) * max(1.0, float(jnp.max(jnp.abs(ref32))))
    median_us, _ = time_fn(fn, *args, repeats=3, warmup=1)
    return err, ok, median_us


def _sweep_oracle(kind, sig, world):
    """Deterministic stand-in for the measured timer (module docstring).

    The analytic cost in us, skewed per candidate by a stable hash of its
    label, so exhaustive-vs-pruned winner agreement is meaningful (ties
    break identically) and CI runs are reproducible.
    """

    def timer(cand, *, repeats=3, warmup=1):
        skew = int(hashlib.sha256(cand.label().encode()).hexdigest()[:4], 16) % 97
        base_us = tune_cost.predict_cost(kind, sig, world, cand) * 1e6
        return base_us * (1.0 + skew / 9700.0), 0.0

    return timer


def smoke(out_path: str = "BENCH_autotune.json") -> int:
    mesh = mesh_tp(4)
    cache_dir = tempfile.mkdtemp(prefix="repro-tune-smoke-")
    results, failures = {}, []
    for kind, sig in SMOKE_SHAPES.items():
        entry = {"signature": list(sig)}
        kw = dict(signature=sig, mesh=mesh, ranker="model", cache_dir=cache_dir)
        try:
            first = tune.autotune(kind, **kw)
            again = tune.autotune(kind, **kw)
            tune_cache.clear_memo()  # force the disk read
            rt = tune.autotune(kind, **kw)
            if first.cache_hit:
                failures.append(f"{kind}: first tune was already a cache hit")
            for name, res in (("memo", again), ("disk", rt)):
                if not res.cache_hit:
                    failures.append(f"{kind}: {name} lookup re-tuned instead of hitting the cache")
                if res.candidate != first.candidate:
                    failures.append(
                        f"{kind}: {name} round-trip changed the winner "
                        f"{first.candidate} -> {res.candidate}"
                    )
            err, ok, us = _check_winner(kind, first, mesh)
            if not ok:
                failures.append(f"{kind}: auto-channel parity error {err:.3e}")
            entry.update(
                winner=first.candidate.label(),
                predicted=tune_cost.explain(kind, sig, 4, first.candidate),
                cache_round_trip=bool(again.cache_hit and rt.cache_hit),
                max_abs_err=err,
                us=round(us, 1),
                considered=first.considered,
            )
            row(f"autotune/{kind}/{first.candidate.label()}", us)
        except Exception as exc:  # loud: any tuner error fails CI
            failures.append(f"{kind}: {type(exc).__name__}: {exc}")
            entry["error"] = str(exc)
        results[kind] = entry

    # ---- joint (CommSpec x CompSpec) sweep — ISSUE 4 acceptance ------------
    non_default_tiles = 0
    for kind, sig in JOINT_SMOKE_SHAPES.items():
        entry = {"signature": list(sig)}
        try:
            res = tune.autotune(
                kind,
                signature=sig,
                mesh=mesh,
                ranker="model",
                cache_dir=cache_dir,
                space=tune.JOINT_SPACE,
            )
            err, ok, us = _check_winner(kind, res, mesh)
            if not ok:
                failures.append(f"{kind}: joint-winner parity error {err:.3e}")
            if tuple(res.candidate.comp_tile) != DEFAULT_TILE:
                non_default_tiles += 1
            if res.considered <= 18:  # ISSUE 5: every kind has a tile axis now
                failures.append(
                    f"joint/{kind}: only {res.considered} candidates — the "
                    "compute-tile axis collapsed to the comm-only space"
                )
            entry.update(
                winner=res.candidate.label(),
                comp_tile=list(res.candidate.comp_tile),
                max_abs_err=err,
                us=round(us, 1),
                considered=res.considered,
            )
            row(f"autotune/joint/{kind}/{res.candidate.label()}", us)
        except Exception as exc:  # loud: any tuner error fails CI
            failures.append(f"joint/{kind}: {type(exc).__name__}: {exc}")
            entry["error"] = str(exc)
        results[kind]["joint"] = entry
    if non_default_tiles == 0:
        failures.append(
            "joint sweep: no shape resolved a compute tile different from "
            f"{DEFAULT_TILE} — the CompSpec half of the search is dead"
        )

    # ---- measured sweep: early-exit pruning contract (ISSUE 5) -------------
    for kind, sig in JOINT_SMOKE_SHAPES.items():
        entry = {}
        try:
            cands = tune.enumerate_candidates(
                kind,
                extent=tune.chunk_extent(kind, sig),
                space=tune.JOINT_SPACE,
                sig=sig,
                world=4,
            )
            timer = _sweep_oracle(kind, sig, 4)
            sw = tune_sweep.measured_sweep(kind, sig, 4, cands, timer)
            exhaustive = tune_sweep.measured_sweep(
                kind, sig, 4, cands, timer, config=tune_sweep.SweepConfig(enabled=False)
            )
            if sw.winner != exhaustive.winner:
                failures.append(
                    f"sweep/{kind}: pruned winner {sw.winner.label()} != "
                    f"exhaustive winner {exhaustive.winner.label()}"
                )
            if 2 * sw.stats["screened"] > len(cands):
                failures.append(
                    f"sweep/{kind}: screened {sw.stats['screened']} of "
                    f"{len(cands)} — timed more than 50% of the joint space"
                )
            if 2 * sw.stats["pruned"] < len(cands):
                failures.append(
                    f"sweep/{kind}: pruned only {sw.stats['pruned']} of "
                    f"{len(cands)} — less than 50% of the joint space"
                )
            entry.update(winner=sw.winner.label(), **sw.stats)
            row(f"autotune/sweep/{kind}/{sw.winner.label()}", sw.median_us)
        except Exception as exc:  # loud: any sweep error fails CI
            failures.append(f"sweep/{kind}: {type(exc).__name__}: {exc}")
            entry["error"] = str(exc)
        results[kind]["sweep"] = entry

    # one REAL measured sweep end-to-end (AOT timing path, pruning ledger in
    # the v3 record) — wall time is informational on CPU, never gated
    try:
        measured = tune.autotune(
            "ag_matmul",
            signature=SMOKE_SHAPES["ag_matmul"],
            mesh=mesh,
            ranker="measure",
            cache_dir=cache_dir,
        )
        if measured.sweep is None:
            failures.append("measured: record carries no sweep stats")
        elif measured.sweep["total"] != measured.considered:
            failures.append(f"measured: sweep ledger total {measured.sweep} != considered")
        # emit only the wall-clock-INDEPENDENT ledger fields: "timed" (and
        # early_exit) depend on CPU-runner jitter, and compare.py gates the
        # emitted ledger exactly — a noisy field would make the bench-gate
        # nondeterministically red on unrelated PRs
        stable = {
            key: val
            for key, val in (measured.sweep or {}).items()
            if key in ("total", "screened", "pruned")
        }
        results["measured"] = {
            "kind": "ag_matmul",
            "winner": measured.candidate.label(),
            "sweep": stable,
        }
    except Exception as exc:  # loud: the real timing path must work on CPU
        failures.append(f"measured: {type(exc).__name__}: {exc}")
        results["measured"] = {"error": str(exc)}

    # plan-layer cache growth (bounded LRU since the static-verifier PR):
    # hits/misses are informational (ungated leaves); "ok" gates boundedness
    info = plan_cache_info()
    results["plan_cache"] = {
        "hits": info.hits,
        "misses": info.misses,
        "currsize": info.currsize,
        "maxsize": info.maxsize,
        "ok": info.maxsize is not None,
    }

    with open(out_path, "w") as fh:
        json.dump(results, fh, indent=2, sort_keys=True)
    print(f"wrote {out_path}: {len(results)} kinds, {len(failures)} failures")
    for f_ in failures:
        print(f"FAIL {f_}", file=sys.stderr)
    return 1 if failures else 0


def main(world: int) -> int:
    print(f"# cost-model winners per shape (world={world}); the point of the")
    print("# paper's decoupling: the best design point is shape-dependent")
    for kind, shapes in SWEEP_SHAPES.items():
        for sig in shapes:
            cands = tune.enumerate_candidates(kind, extent=tune.chunk_extent(kind, sig))
            best = min(cands, key=lambda c: tune_cost.predict_cost(kind, sig, world, c))
            us = tune_cost.predict_cost(kind, sig, world, best) * 1e6
            row(f"tune/{kind}/{'x'.join(map(str, sig))}/{best.label()}", us)
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="CI guard: tune one shape per kind, assert the cache round-trip, "
        "write BENCH_autotune.json",
    )
    ap.add_argument("--out", default="BENCH_autotune.json")
    ap.add_argument("--world", type=int, default=8, help="ring size for the cost-model sweep")
    a = ap.parse_args()
    sys.exit(smoke(a.out) if a.smoke else main(a.world))
