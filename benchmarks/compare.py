"""Gate BENCH_*.json artifacts against the last successful main-branch run.

CI's ``bench-gate`` job downloads the previous successful main-branch BENCH
artifact into one directory, this run's artifact into another, and calls:

    python benchmarks/compare.py --baseline baseline/ --current current/

Exit is non-zero on any regression, so the PR fails visibly instead of
perf/coverage drift landing silently (the benches used to *emit* these files
on every run and never read them back).

Rules, applied to every ``BENCH_*.json`` present in the baseline:

  * smoke timings (leaf keys named ``us``) — the current value may exceed
    the baseline by at most ``--tolerance`` (default 20%).  An absolute
    floor (``--floor-us``, default 200us) ignores micro-benchmark jitter;
    speedups and derived ratios are never gated (they move with the
    baseline term).  CI-runner noise above the tolerance is exactly what
    the gate exists to surface — re-run the job if you believe it is noise.
  * invariants — candidate counts (``considered``) and the measured-sweep
    pruning ledger (``total``/``screened``/``timed``/``pruned``) compare
    EXACTLY when present in BOTH runs: the design space and the pruning
    behavior may not drift without the reviewer seeing it (an intentional
    change makes this gate red until it merges to main and becomes the new
    baseline; say so in the PR).  Boolean health flags (``cache_round_trip``,
    ``ok``) may not regress True -> False.
  * coverage — asymmetric by design: an entry present in the baseline but
    missing from the current run is a FAILURE (a silently dropped design
    point), but an entry present only in the current run — a new kind, a
    new stat block — is a "new entry" NOTICE, never a failure, even for the
    exact-gated invariant leaves above: a PR that widens coverage must not
    be punished by its own new entries.  New subtrees are reported once,
    not once per leaf.
  * deliberate refresh — a PR that intentionally changes an exact-gated
    invariant (grows the candidate space, restructures a ledger) declares it
    with ``--refresh-baseline 'BENCH_file.json:path/*'`` (fnmatch over
    ``name:key``, repeatable) or a pattern line in the refresh file
    (``--refresh-baseline-file``, default ``benchmarks/refresh_baseline.txt``
    — check the line in WITH the change).  Matching failures downgrade to
    loud notices, so a deliberate change blocks a PR at most once — never
    twice: after the merge the main baseline carries the new values and the
    pattern line can be dropped.

No baseline (first run on a fresh repo/fork, expired artifacts) passes with
a loud notice — the gate arms itself on the next main-branch run.
"""
from __future__ import annotations

import argparse
import fnmatch
import glob
import json
import os
import sys
from typing import Dict, List, Tuple

# leaf names gated exactly when present in both runs; a key carrying one of
# these that exists only in the current run is a "new entry" notice instead
EXACT_LEAVES = ("considered", "total", "screened", "timed", "pruned")

# boolean health flags that may never regress True -> False
HEALTH_LEAVES = ("cache_round_trip", "ok")


def flatten(obj, prefix: str = "") -> Dict[str, object]:
    """Nested dicts -> {"a/b/c": leaf}; lists stay leaves (compared whole)."""
    out: Dict[str, object] = {}
    if isinstance(obj, dict):
        for key, val in sorted(obj.items()):
            out.update(flatten(val, f"{prefix}/{key}" if prefix else str(key)))
    else:
        out[prefix] = obj
    return out


def load_bench_files(directory: str) -> Dict[str, Dict[str, object]]:
    """{file name: flattened payload} for every BENCH_*.json under ``directory``."""
    found = {}
    for path in sorted(glob.glob(os.path.join(directory, "**", "BENCH_*.json"), recursive=True)):
        try:
            with open(path) as fh:
                found[os.path.basename(path)] = flatten(json.load(fh))
        except (OSError, ValueError) as exc:
            print(f"::warning::unreadable bench artifact {path}: {exc}")
    return found


def _refresh_match(tag: str, patterns: List[str]) -> str:
    """First fnmatch pattern covering ``tag`` ("name:key"), or ""."""
    for pat in patterns:
        if fnmatch.fnmatch(tag, pat):
            return pat
    return ""


def load_refresh_patterns(cli: List[str], path: str) -> List[str]:
    """CLI patterns + non-comment lines of the refresh file (if present)."""
    patterns = list(cli or [])
    if path and os.path.exists(path):
        with open(path) as fh:
            for line in fh:
                line = line.strip()
                if line and not line.startswith("#"):
                    patterns.append(line)
    return patterns


def compare_file(
    name: str,
    base: Dict[str, object],
    cur: Dict[str, object],
    *,
    tolerance: float,
    floor_us: float,
    refresh: List[str] = (),
) -> Tuple[list, list]:
    """(failures, notices) from gating ``cur`` against ``base`` for one file."""
    failures, notices = [], []

    def fail_or_refresh(tag: str, message: str) -> None:
        pat = _refresh_match(tag, refresh)
        if pat:
            notices.append(
                f"{message} [refreshed: matched --refresh-baseline {pat!r}; "
                "this run's value becomes the baseline on merge]"
            )
        else:
            failures.append(message)

    for key, bval in base.items():
        tag = f"{name}:{key}"
        if key not in cur:
            fail_or_refresh(tag, f"{tag}: present in baseline but missing from this run")
            continue
        cval = cur[key]
        leaf = key.rsplit("/", 1)[-1]
        if leaf == "us":
            try:
                b, c = float(bval), float(cval)
            except (TypeError, ValueError):
                continue
            if c > b * (1.0 + tolerance) and c - b > floor_us:
                failures.append(
                    f"{tag}: timing regression {b:.0f}us -> {c:.0f}us "
                    f"(+{100.0 * (c - b) / max(b, 1e-9):.0f}%, tolerance "
                    f"{100.0 * tolerance:.0f}%)"
                )
        elif leaf in EXACT_LEAVES:
            if cval != bval:
                fail_or_refresh(
                    tag,
                    f"{tag}: exact invariant changed {bval} -> {cval} (design-"
                    "space/pruning drift; if intentional, declare it with "
                    "--refresh-baseline or a benchmarks/refresh_baseline.txt "
                    "pattern line in the same PR)",
                )
        elif leaf in HEALTH_LEAVES:
            if bool(bval) and not bool(cval):
                failures.append(f"{tag}: health flag regressed True -> False")
    # entries only the PR run has: a "new entry" notice, NEVER a failure —
    # grouped per subtree so a new kind/stat block reports once, not per leaf
    new = [key for key in cur if key not in base]
    groups: Dict[str, int] = {}
    for key in new:
        prefix = key.rsplit("/", 1)[0] if "/" in key else key
        groups[prefix] = groups.get(prefix, 0) + 1
    for prefix in sorted(groups):
        noun = "leaf" if groups[prefix] == 1 else "leaves"
        notices.append(
            f"{name}:{prefix}: new entry ({groups[prefix]} {noun} not in the "
            "baseline — gated once a main run makes it the baseline)"
        )
    return failures, notices


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="dir with the main-branch BENCH_*.json")
    ap.add_argument("--current", required=True, help="dir with this run's BENCH_*.json")
    ap.add_argument(
        "--tolerance", type=float, default=0.2, help="relative slowdown allowed on us timings"
    )
    ap.add_argument(
        "--floor-us", type=float, default=200.0, help="absolute us change ignored as jitter"
    )
    ap.add_argument(
        "--refresh-baseline", action="append", default=[], metavar="PATTERN",
        help="fnmatch over 'BENCH_file.json:key' — matching exact-invariant/"
             "coverage failures become notices (deliberate baseline refresh)",
    )
    ap.add_argument(
        "--refresh-baseline-file", default="benchmarks/refresh_baseline.txt",
        help="file of refresh patterns, one per line (# comments); checked in "
             "alongside the deliberate change so the gate never blocks it twice",
    )
    args = ap.parse_args()
    refresh = load_refresh_patterns(args.refresh_baseline, args.refresh_baseline_file)

    current = load_bench_files(args.current)
    if not current:
        print(f"::error::no BENCH_*.json under {args.current} — the bench jobs did not run?")
        return 1
    baseline = load_bench_files(args.baseline) if os.path.isdir(args.baseline) else {}
    if not baseline:
        print(
            "::notice::no baseline BENCH artifacts found (first run on this "
            "branch history?) — gate passes; the next successful main run "
            "becomes the baseline"
        )
        return 0

    failures, notices = [], []
    for name, base in sorted(baseline.items()):
        if name not in current:
            failures.append(f"{name}: baseline artifact has no counterpart in this run")
            continue
        f_, n_ = compare_file(
            name, base, current[name], tolerance=args.tolerance,
            floor_us=args.floor_us, refresh=refresh,
        )
        failures.extend(f_)
        notices.extend(n_)
    for name in current:
        if name not in baseline:
            notices.append(f"{name}: new bench artifact (not in baseline)")

    for n_ in notices:
        print(f"::notice::{n_}")
    for f_ in failures:
        print(f"::error::{f_}")
    print(
        f"compared {len(baseline)} baseline file(s) against {len(current)}: "
        f"{len(failures)} regression(s), {len(notices)} notice(s)"
    )
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
