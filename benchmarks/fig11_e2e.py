"""Paper Fig. 11: end-to-end LM train-step time, TileLink overlap vs
operator-centric baseline, across model families (reduced configs on the
8-device CPU mesh; the relative speedup is the paper's reported quantity)."""

import jax
import jax.numpy as jnp

from repro.compat import make_mesh
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.launch.specs import model_module
from repro.launch.train import reduce_config
from repro.parallel.context import ParallelContext
from repro.parallel.sharding import place
from repro.training import AdamWConfig, init_opt_state, make_train_step
from benchmarks.common import time_fn, row

MODELS = ["smollm-360m", "qwen2-72b", "starcoder2-7b", "gemma3-27b",
          "granite-moe-3b-a800m", "deepseek-moe-16b"]


def bench_model(arch: str, mesh, mode: str) -> float:
    cfg = reduce_config(get_config(arch), d_model=128, vocab=512)
    pc = ParallelContext(mesh=mesh, mode=mode)
    mod = model_module(cfg)
    params = place(mod.init(jax.random.PRNGKey(0), cfg, pc, jnp.float32),
                   mesh, mod.specs(cfg, pc))
    opt = init_opt_state(params)
    step = make_train_step(mod, cfg, pc, AdamWConfig(total_steps=10),
                           donate=False)
    pipe = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=128, global_batch=4)
    batch = pipe.host_batch()
    return time_fn(lambda: step(params, opt, batch)[2]["loss"], repeats=3)


def main():
    mesh = make_mesh((1, 2, 4), ("pod", "data", "model"))
    for arch in MODELS:
        tb = bench_model(arch, mesh, "baseline")
        tt = bench_model(arch, mesh, "overlap")
        row(f"fig11/{arch}/non-overlap", tb, "1.00x")
        row(f"fig11/{arch}/tilelink", tt, f"{tb/tt:.2f}x")


if __name__ == "__main__":
    main()
