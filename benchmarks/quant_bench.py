"""Quantized-flows bench + CI smoke (``--smoke`` -> ``BENCH_quant.json``).

The QuantSpec claim made gateable, four ways:

  * **modeled** — for every comm-bound GEMM shape the best int8-wire
    candidate must beat the best full-precision candidate on the MODELED
    cost scale: the int8 wire quarters bytes-on-wire at a fixed scale-table
    overhead, so a non-win means the wire pricing (``tune/cost.step_terms``
    with ``wire_dtype``) or the flow-axis enumeration broke;
  * **resolve** — ``channel="auto"`` with the quant-widened space must
    actually explore the flow axis end-to-end and return an int8 winner on
    a comm-bound shape (``result.candidate.flow == "int8"``);
  * **measured** — the int8-wire executor must stay within tolerance of the
    full-precision path on a real (emulated) mesh, and the fp32 wire must
    stay BITWISE identical to the pre-quant default;
  * **migration** — a schema-3 cache record (pre flow axis) must re-tune
    silently and be rewritten as schema 4 with the winner's ``flow``.

Modeled costs land under ungated ``*_modeled_us`` leaves; the ``ok`` health
leaves gate exactly via benchmarks/compare.py.  Any violation exits non-zero
so CI fails loudly.
"""
import argparse
import json
import os
import sys
import tempfile

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import tune
from repro.compat import shard_map
from repro.core import BlockChannel, compile_overlap
from repro.core.quant import QuantSpec
from repro.tune import cost as tune_cost
from repro.tune.candidates import QUANT_SPACE, enumerate_candidates

try:  # package import (python -m benchmarks.quant_bench / pytest)
    from benchmarks.common import mesh_tp, row, time_fn
except ImportError:  # plain script: the benchmarks/ dir is sys.path[0]
    from common import mesh_tp, row, time_fn

WORLD = 4

# comm-bound GEMM signatures: narrow contraction per byte moved, so the wire
# gates the pipeline and the int8 repricing shows.  matmul_rs sigs are
# (lead, m_glob, k_loc, n); ag_matmul sigs are (lead, m_loc, k, n_loc).
SHAPES = {
    "rs-long-seq": ("matmul_rs", (1, 2048, 32, 2048)),
    "rs-wide-out": ("matmul_rs", (1, 1024, 128, 4096)),
    "ag-deep-k": ("ag_matmul", (1, 512, 4096, 512)),
}
# compute-bound control: int8 may NOT win here (overlap already hides the
# wire); keeps the flow axis honest in both directions
CONTROL = ("matmul_rs", (1, 256, 2048, 256))


def _best(kind, sig, flow):
    """(cost_us, candidate) of the cheapest design point at one wire flow."""
    cands = [c for c in enumerate_candidates(
        kind, space=QUANT_SPACE, sig=sig, world=WORLD) if c.flow == flow]
    if not cands:
        raise ValueError(f"no flow={flow!r} candidates for {kind} sig={sig}")
    best = min(cands, key=lambda c: tune_cost.predict_cost(kind, sig, WORLD, c))
    return tune_cost.predict_cost(kind, sig, WORLD, best) * 1e6, best


def _jit(mesh, fn):
    f = shard_map(fn, mesh, in_specs=(P(None, None), P(None, None)),
                  out_specs=P("model", None), check_rep=False,
                  axis_names={"model"})
    return jax.jit(f)


def smoke(out_path: str = "BENCH_quant.json") -> int:
    results, failures = {"shapes": {}}, []

    # ---- modeled: int8 wire beats full precision on comm-bound shapes ------
    for name, (kind, sig) in SHAPES.items():
        entry = {"kind": kind, "signature": list(sig)}
        try:
            f32_us, _ = _best(kind, sig, None)
            int8_us, cand = _best(kind, sig, "int8")
            ok = int8_us < f32_us
            if not ok:
                failures.append(
                    f"{name}: int8 wire modeled {int8_us:.1f}us does not beat "
                    f"full precision {f32_us:.1f}us on a comm-bound shape — "
                    f"the wire repricing is dead")
            entry.update(
                winner=cand.label(),
                f32_modeled_us=round(f32_us, 3),
                int8_modeled_us=round(int8_us, 3),
                ok=ok,
            )
            row(f"quant/{name}/modeled/{cand.label()}", int8_us,
                f"f32 {f32_us:.0f}us ({f32_us / max(int8_us, 1e-9):.2f}x)")
        except Exception as exc:  # loud: any flow-axis error fails CI
            failures.append(f"{name}: {type(exc).__name__}: {exc}")
            entry["error"] = str(exc)
        results["shapes"][name] = entry

    # ---- resolve: channel="auto" explores the flow axis end-to-end ---------
    try:
        kind, sig = SHAPES["rs-long-seq"]
        with tempfile.TemporaryDirectory() as tmp:
            res = tune.autotune(kind, signature=sig, world=WORLD,
                                axis="model", ranker="model",
                                space=QUANT_SPACE, cache_dir=tmp)
        ok = res.candidate.flow == "int8"
        if not ok:
            failures.append(
                f"resolve: auto winner flow={res.candidate.flow!r} on a "
                f"comm-bound shape (expected 'int8')")
        quant = res.channel.quant
        results["resolve"] = {
            "winner": res.candidate.label(),
            "flow": res.candidate.flow,
            "wire_dtype": None if quant is None else quant.wire_dtype,
            "ok": ok,
        }
    except Exception as exc:
        failures.append(f"resolve: {type(exc).__name__}: {exc}")
        results["resolve"] = {"error": str(exc), "ok": False}

    # ---- control: compute-bound shape records its verdict (ungated) --------
    try:
        kind, sig = CONTROL
        f32_us, _ = _best(kind, sig, None)
        int8_us, _ = _best(kind, sig, "int8")
        results["control"] = {
            "kind": kind, "signature": list(sig),
            "f32_modeled_us": round(f32_us, 3),
            "int8_modeled_us": round(int8_us, 3),
            "int8_wins": bool(int8_us < f32_us),
        }
    except Exception as exc:
        failures.append(f"control: {type(exc).__name__}: {exc}")
        results["control"] = {"error": str(exc)}

    # ---- measured: int8 parity within tolerance; fp32 wire bitwise ---------
    try:
        mesh = mesh_tp(WORLD)
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (256, 128), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (128, 256), jnp.float32) * 0.1
        ch = BlockChannel(axis="model")
        f_f32 = _jit(mesh, compile_overlap("matmul_rs", ch))
        f_int8 = _jit(mesh, compile_overlap(
            "matmul_rs", ch, quant=QuantSpec(wire_dtype="int8")))
        f_wire32 = _jit(mesh, compile_overlap(
            "matmul_rs", ch, quant=QuantSpec(wire_dtype="float32")))
        y_f32, y_int8, y_wire32 = f_f32(x, w), f_int8(x, w), f_wire32(x, w)
        rel = float(jnp.linalg.norm(y_int8 - y_f32) / jnp.linalg.norm(y_f32))
        parity_ok = rel < 0.05  # per-tile symmetric absmax: elemwise <= scale/2
        bitwise_ok = bool(jnp.all(y_wire32 == y_f32))
        if not parity_ok:
            failures.append(f"measured: int8 wire relative error {rel:.3e} "
                            f"exceeds the 5% smoke tolerance")
        if not bitwise_ok:
            failures.append("measured: fp32 wire is not bitwise identical to "
                            "the pre-quant default path")
        int8_us = time_fn(f_int8, x, w)
        f32_us = time_fn(f_f32, x, w)
        results["measured"] = {
            "int8": {"us": round(int8_us, 1)},
            "f32": {"us": round(f32_us, 1)},
            "rel_err": rel,
            "bitwise_f32_wire": bitwise_ok,
            "ok": parity_ok and bitwise_ok,
        }
        row("quant/measured/int8", int8_us, f"rel_err {rel:.2e}")
        row("quant/measured/f32", f32_us)
    except Exception as exc:  # loud: the executor path must run on CPU
        failures.append(f"measured: {type(exc).__name__}: {exc}")
        results["measured"] = {"error": str(exc), "ok": False}

    # ---- migration: schema-3 records re-tune into schema-4 entries ---------
    try:
        from repro.tune import CACHE_SCHEMA, _entry_key, _parse_record
        from repro.tune import cache as tune_cache

        kind, sig = SHAPES["rs-long-seq"]
        with tempfile.TemporaryDirectory() as tmp:
            fp = tune_cache.mesh_fingerprint(None, axis="model", world=WORLD)
            key = _entry_key(kind, "model", WORLD, sig, QUANT_SPACE)
            v3 = {"schema": 3, "kind": kind, "signature": list(sig),
                  "world": WORLD, "order": "ring", "num_channels": 1,
                  "accum_dtype": "float32", "comp_tile": [64, 128, 128],
                  "ranker": "model", "score": 1.0}
            tune_cache.store_entry(fp, key, v3, directory=tmp)
            stale_rejected = _parse_record(v3) is None
            res = tune.autotune(kind, signature=sig, world=WORLD,
                                axis="model", ranker="model",
                                space=QUANT_SPACE, cache_dir=tmp)
            rec = tune_cache.load_entry(fp, key, directory=tmp)
        migrated = rec is not None and int(rec.get("schema", 0)) == CACHE_SCHEMA
        has_flow = rec is not None and "flow" in rec
        ok = stale_rejected and migrated and has_flow
        if not ok:
            failures.append(
                f"migration: stale_rejected={stale_rejected} "
                f"migrated={migrated} has_flow={has_flow} — v3 records must "
                f"re-tune into schema-{CACHE_SCHEMA} entries carrying 'flow'")
        results["migration"] = {
            "stale_rejected": stale_rejected,
            "schema": None if rec is None else rec.get("schema"),
            "winner_flow": None if rec is None else rec.get("flow"),
            "retuned_winner": res.candidate.label(),
            "ok": ok,
        }
    except Exception as exc:
        failures.append(f"migration: {type(exc).__name__}: {exc}")
        results["migration"] = {"error": str(exc), "ok": False}

    with open(out_path, "w") as fh:
        json.dump(results, fh, indent=2, sort_keys=True)
    print(f"wrote {out_path}: {len(results['shapes'])} shapes, "
          f"{len(failures)} failures")
    for f_ in failures:
        print(f"FAIL {f_}", file=sys.stderr)
    return 1 if failures else 0


def main() -> int:
    print(f"# modeled int8-wire vs full-precision cost per shape (world={WORLD})")
    for name, (kind, sig) in list(SHAPES.items()) + [("control", CONTROL)]:
        f32_us, _ = _best(kind, sig, None)
        int8_us, cand = _best(kind, sig, "int8")
        row(f"quant/{name}/{cand.label()}", int8_us,
            f"f32 {f32_us:.0f}us ({f32_us / max(int8_us, 1e-9):.2f}x)")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: gate the int8-win/parity/migration "
                         "contract, write BENCH_quant.json")
    ap.add_argument("--out", default="BENCH_quant.json")
    args = ap.parse_args()
    sys.exit(smoke(args.out) if args.smoke else main())
