"""Paper Fig. 9: six MoE shapes — AG + GroupGEMM + TopkReduce + RS
(double ring) vs non-overlapping AllGather/ReduceScatter."""

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.moe_overlap import ag_moe, ag_moe_baseline, moe_router
from repro.configs.paper import PAPER_MOE
from benchmarks.common import SCALE, mesh8, time_fn, row


def main():
    mesh = mesh8()
    key = jax.random.PRNGKey(0)
    for name, (s, h, i, e, topk) in PAPER_MOE.items():
        s_, h_, i_ = s // SCALE, h // SCALE, (i // SCALE // 8) * 8
        e = max(e, 8)
        x = jax.device_put(jax.random.normal(key, (s_, h_), jnp.float32),
                           NamedSharding(mesh, P("model", None)))
        wr = jax.random.normal(key, (h_, e), jnp.float32)
        wgu = jax.device_put(
            jax.random.normal(key, (e, h_, 2 * i_), jnp.float32) * 0.1,
            NamedSharding(mesh, P("model", None, None)))
        wdn = jax.device_put(
            jax.random.normal(key, (e, i_, h_), jnp.float32) * 0.1,
            NamedSharding(mesh, P("model", None, None)))

        def make(overlapped):
            def f(xs, wgu_, wdn_):
                ids, wts, _ = moe_router(xs, wr, num_experts=e, top_k=topk)
                g = ag_moe if overlapped else ag_moe_baseline
                return g(xs, ids, wts, wgu_, wdn_, axis="model")
            return jax.jit(shard_map(
                f, mesh,
                in_specs=(P("model", None), P("model", None, None),
                          P("model", None, None)),
                out_specs=P("model", None)))

        tb = time_fn(make(False), x, wgu, wdn)
        tt = time_fn(make(True), x, wgu, wdn)
        row(f"fig9/{name}(E={e},k={topk})/non-overlap", tb, "1.00x")
        row(f"fig9/{name}(E={e},k={topk})/tilelink", tt, f"{tb/tt:.2f}x")


if __name__ == "__main__":
    main()
