"""Paper Fig. 10: sequence-parallel self-attention at growing sequence length —
TileLink AG-KV overlap (ring, copy-engine mapping) vs non-overlap AllGather.

Also prints the paper's overlap ratio
  (comp_only + comm_only - overlapped) / comm_only
measured from comm-only / compute-only decompositions.
"""
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import overlap
from repro.configs.paper import PAPER_ATTN
from benchmarks.common import SCALE, mesh8, time_fn, row


def main():
    mesh = mesh8()
    key = jax.random.PRNGKey(0)
    for name, (heads, hd, seqs) in PAPER_ATTN.items():
        h = max(heads // SCALE, 2)
        for s in seqs[:2]:  # 16k, 32k (scaled)
            s_ = s // SCALE
            q = jax.device_put(
                jax.random.normal(key, (1, h, s_, hd), jnp.float32),
                NamedSharding(mesh, P(None, None, "model", None)))
            k = jax.device_put(
                jax.random.normal(key, (1, h, s_, hd), jnp.float32),
                NamedSharding(mesh, P(None, None, "model", None)))
            v = jax.device_put(
                jax.random.normal(key, (1, h, s_, hd), jnp.float32),
                NamedSharding(mesh, P(None, None, "model", None)))
            specs = (P(None, None, "model", None),) * 3

            ring = jax.jit(shard_map(
                lambda *a: overlap.ring_attention(*a, axis="model", causal=True),
                mesh, in_specs=specs, out_specs=P(None, None, "model", None)))
            base = jax.jit(shard_map(
                lambda *a: overlap.ag_attention_baseline(*a, axis="model",
                                                         causal=True),
                mesh, in_specs=specs, out_specs=P(None, None, "model", None)))
            comm_only = jax.jit(shard_map(
                lambda kk: jax.lax.all_gather(kk, "model", axis=2, tiled=True),
                mesh, in_specs=specs[:1], out_specs=P(None, None, None, None)))

            tb = time_fn(base, q, k, v)
            tt = time_fn(ring, q, k, v)
            tc = time_fn(comm_only, k) * 2  # K and V
            ratio = max(0.0, min(1.0, (tb - tt) / max(tc, 1e-9)))
            row(f"fig10/{name}/S={s}/non-overlap", tb, "1.00x")
            row(f"fig10/{name}/S={s}/tilelink", tt,
                f"{tb/tt:.2f}x;overlap_ratio={ratio:.2f}")


if __name__ == "__main__":
    main()
