"""Plan-layer tests: the full CommSpec x CompSpec space on every kind.

The tentpole claim of the frontend refactor: ``(kind, BlockChannel)``
genuinely compiles — ``order`` in {ring, bidir_ring, all2all},
``num_channels`` in {1, 2, 4} and ``accum_dtype`` in {float32, bfloat16}
produce correct results for ALL four workload kinds through the one generic
schedule executor, verified against the non-overlapping baselines on a
4-rank emulated mesh.
"""
import itertools
import warnings

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map, make_mesh
from repro.core import (
    BlockChannel, CommSpec, CompSpec, compile_overlap,
    SeamFallbackWarning, build_plan, effective_channels, schedules,
    unsupported_error,
)
from repro.core.moe_overlap import moe_router
from repro.core.plan import ChannelSchedule
from utils import allclose

KEY = jax.random.PRNGKey(0)
R = 4  # world size of the parity mesh

ORDERS = ("ring", "bidir_ring", "all2all")
CHANNELS = (1, 2, 4)
ACCUMS = ("float32", "bfloat16")
SWEEP = list(itertools.product(ORDERS, CHANNELS, ACCUMS))


@pytest.fixture(scope="module")
def mesh4():
    return make_mesh((R,), ("model",))


def _chan(order, channels, accum):
    return BlockChannel(axis="model", num_channels=channels,
                        comm=CommSpec(order=order),
                        comp=CompSpec(accum_dtype=accum))


def _tol(accum):
    # bf16 flow/accum dtype is genuinely lossy (~0.8% relative); fp32 is exact
    return dict(atol=2e-4, rtol=2e-3) if accum == "float32" else dict(atol=8e-2, rtol=3e-2)


# ---- parity sweep: every kind x the full comm/comp space --------------------

@pytest.mark.parametrize("order,channels,accum", SWEEP)
def test_parity_ag_matmul(mesh4, order, channels, accum):
    m, k, n = R * 8, 16, 12
    x = jax.random.normal(KEY, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    fn = compile_overlap("ag_matmul", _chan(order, channels, accum))
    sm = shard_map(fn, mesh4, in_specs=(P("model", None), P(None, None)),
                   out_specs=P(None, None))
    allclose(jax.jit(sm)(x, w), x @ w, **_tol(accum))


@pytest.mark.parametrize("order,channels,accum", SWEEP)
def test_parity_matmul_rs(mesh4, order, channels, accum):
    m, k, n = R * 8, R * 8, 16
    x = jax.random.normal(KEY, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(2), (k, n), jnp.float32)
    fn = compile_overlap("matmul_rs", _chan(order, channels, accum))
    sm = shard_map(fn, mesh4, in_specs=(P(None, "model"), P("model", None)),
                   out_specs=P("model", None))
    allclose(jax.jit(sm)(x, w), x @ w, **_tol(accum))


@pytest.mark.parametrize("order,channels,accum", SWEEP)
def test_parity_ag_attention(mesh4, order, channels, accum):
    b, h, s, d, hkv = 1, 2, R * 8, 8, 1
    q = jax.random.normal(KEY, (b, h, s, d))
    k = jax.random.normal(jax.random.PRNGKey(3), (b, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(4), (b, hkv, s, d))
    ch = _chan(order, channels, accum)
    specs = (P(None, None, "model"),) * 3
    fn = compile_overlap("ag_attention", ch, causal=True)
    fnb = compile_overlap("ag_attention", ch, overlapped=False, causal=True)
    sm = shard_map(fn, mesh4, in_specs=specs, out_specs=P(None, None, "model"))
    smb = shard_map(fnb, mesh4, in_specs=specs, out_specs=P(None, None, "model"))
    allclose(jax.jit(sm)(q, k, v), jax.jit(smb)(q, k, v), **_tol(accum))


@pytest.mark.parametrize("order,channels,accum", SWEEP)
def test_parity_ag_moe(mesh4, order, channels, accum):
    e, k_top, d, f = 8, 2, 16, 16
    m = R * 16
    x = jax.random.normal(KEY, (m, d)) * 0.5
    wr = jax.random.normal(jax.random.PRNGKey(5), (d, e))
    wgu = jax.random.normal(jax.random.PRNGKey(6), (e, d, 2 * f)) * 0.1
    wdn = jax.random.normal(jax.random.PRNGKey(7), (e, f, d)) * 0.1
    ch = _chan(order, channels, accum)

    def shard_fn(overlapped):
        g = compile_overlap("ag_moe", ch, overlapped=overlapped,
                            capacity_factor=8.0)

        def f_(xs, wgu_, wdn_):
            ids, wts, _ = moe_router(xs, wr, num_experts=e, top_k=k_top)
            return g(xs, ids, wts, wgu_, wdn_)

        return shard_map(f_, mesh4,
                         in_specs=(P("model", None), P("model", None, None),
                                   P("model", None, None)),
                         out_specs=P("model", None))

    y_o = jax.jit(shard_fn(True))(x, wgu, wdn)
    y_b = jax.jit(shard_fn(False))(x, wgu, wdn)
    allclose(y_o, y_b, **_tol(accum))


# ---- parity sweep: fused Pallas kernels consume the same plan ---------------
# (reduced channel set — each interpret-mode run simulates the full DMA +
#  semaphore machinery; the xla sweep above covers the full grid)

PALLAS_SWEEP = [(o, c, a) for o, c, a in itertools.product(ORDERS, (1, 2), ("float32",))] + [
    ("ring", 2, "bfloat16")
]


@pytest.mark.parametrize("order,channels,accum", PALLAS_SWEEP)
def test_parity_pallas_ag_gemm(mesh4, order, channels, accum):
    m, k, n = R * 16, 32, R * 32
    x = jax.random.normal(KEY, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(10), (k, n), jnp.float32)
    fn = compile_overlap("ag_matmul", _chan(order, channels, accum),
                         backend="pallas", world_size=R, interpret=True)
    sm = shard_map(fn, mesh4, in_specs=(P("model", None), P(None, "model")),
                   out_specs=P(None, "model"))
    allclose(jax.jit(sm)(x, w), x @ w, **_tol(accum))


@pytest.mark.parametrize("order,channels,accum", PALLAS_SWEEP)
def test_parity_pallas_gemm_rs(mesh4, order, channels, accum):
    m, k, n = 64, R * 32, 2 * R * 32
    x = jax.random.normal(KEY, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(11), (k, n), jnp.float32)
    fn = compile_overlap("matmul_rs", _chan(order, channels, accum),
                         backend="pallas", world_size=R, interpret=True)
    sm = shard_map(fn, mesh4, in_specs=(P(None, "model"), P("model", None)),
                   out_specs=P("model", None))
    # K here is 4x the xla sweep's — bf16 flow error grows with sqrt(K)
    tol = _tol(accum) if accum == "float32" else dict(atol=3e-1, rtol=3e-2)
    allclose(jax.jit(sm)(x, w), x @ w, **tol)


# ---- schedule/plan invariants (host-side) -----------------------------------

@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize("world", [2, 3, 4, 8])
@pytest.mark.parametrize("direction", [1, -1])
def test_channel_schedule_invariants(order, world, direction):
    ch = ChannelSchedule(order=order, world=world, direction=direction)
    for r in range(world):
        # every rank consumes every source exactly once
        assert sorted(ch.source(r, s) for s in range(world)) == list(range(world))
        # step 0 holds the local tile; RS ends at the home segment
        assert ch.source(r, 0) == r
        assert ch.rs_segment(r, world - 1) == r
    for s in range(world - 1):
        perm = ch.flow_perm(s)
        rperm = ch.rs_perm(s)
        assert sorted(d for _, d in perm) == list(range(world))
        assert sorted(d for _, d in rperm) == list(range(world))
        for j, d in perm:
            # the tile j holds is exactly what d consumes next step
            assert ch.source(d, s + 1) == ch.source(j, s)
        for j, d in rperm:
            assert ch.rs_segment(d, s + 1) == ch.rs_segment(j, s)


def test_bidir_ring_source_is_wired():
    """Satellite: schedules.bidir_ring_source drives the bidir_ring order."""
    ch = ChannelSchedule(order="bidir_ring", world=8, direction=1)
    for r in range(8):
        for s in range(8):
            assert ch.source(r, s) == schedules.bidir_ring_source(r, s, 8)


def test_ring_plan_matches_paper_rs_schedule():
    """The ring plan's RS view IS the paper's Fig. 4 seg=(r+s+1)%W schedule,
    with partials flowing to rank r-1 (to_rank = r-1, paper line 11)."""
    p = build_plan("matmul_rs", BlockChannel(axis="model"), 8, 1)
    (sched,) = p.channels
    for r in range(8):
        for s in range(8):
            assert sched.rs_segment(r, s) == schedules.ring_rs_segment(r, s, 8)
    for s in range(7):
        assert sched.rs_perm(s) == tuple((j, (j - 1) % 8) for j in range(8))


def test_plan_cache_reuses():
    ch = BlockChannel(axis="model", num_channels=2)
    p1 = build_plan("ag_matmul", ch, 4, 2)
    p2 = build_plan("ag_matmul", ch, 4, 2)
    assert p1 is p2
    assert build_plan("matmul_rs", ch, 4, 2) is not p1


def test_plan_tables_match_schedules():
    """The Pallas table view and the executor view agree (one source of truth)."""
    ch = BlockChannel(axis="model", num_channels=2,
                      comm=CommSpec(order="bidir_ring"))
    p = build_plan("ag_matmul", ch, R, 2)
    src = p.src_tables()
    dst = p.flow_dst_tables()
    for c, sched in enumerate(p.channels):
        for s in range(R):
            assert src[c][s] == sched.source_table(s)
            if s < R - 1:
                assert dst[c][s] == tuple(d for _, d in sched.flow_perm(s))


# ---- channel-count fallback (satellite) -------------------------------------

def test_effective_channels_largest_divisor():
    with pytest.warns(UserWarning, match="largest divisor"):
        assert effective_channels(6, 4, kind="t") == 3
    with pytest.warns(UserWarning, match="largest divisor"):
        assert effective_channels(8, 3) == 2
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # exact divisors must not warn
        assert effective_channels(8, 4) == 4
        assert effective_channels(8, 1) == 1


def test_ag_matmul_indivisible_channels_still_correct(mesh4):
    # m_loc = 6: requested C=4 falls back to 3 (not silently to 1) and the
    # result stays exact
    m, k, n = R * 6, 8, 8
    x = jax.random.normal(KEY, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(8), (k, n), jnp.float32)
    fn = compile_overlap("ag_matmul", _chan("ring", 4, "float32"))
    sm = shard_map(fn, mesh4, in_specs=(P("model", None), P(None, None)),
                   out_specs=P(None, None))
    with pytest.warns(UserWarning, match="largest divisor"):
        y = jax.jit(sm)(x, w)
    allclose(y, x @ w, atol=2e-4, rtol=2e-3)


# ---- spec validation at construction (satellite) ----------------------------

@pytest.mark.parametrize("bad", [
    dict(comm=dict(order="zigzag")),
    dict(comm=dict(resource="gpu")),
    dict(comm=dict(mode="teleport")),
    dict(comm=dict(tile=0)),
    dict(comp=dict(accum_dtype="int32")),
    dict(comp=dict(accum_dtype="not_a_dtype")),
    dict(comp=dict(tile=(128, 128))),
    dict(comp=dict(tile=(128, 0, 128))),
    dict(num_channels=0),
    dict(axis=""),
])
def test_invalid_specs_raise_at_construction(bad):
    kw = {}
    if "comm" in bad:
        with pytest.raises(ValueError):
            CommSpec(**bad["comm"])
        return
    if "comp" in bad:
        with pytest.raises(ValueError):
            CompSpec(**bad["comp"])
        return
    kw.update(bad)
    with pytest.raises(ValueError):
        BlockChannel(axis=kw.pop("axis", "model"), **kw)


def test_grads_flow_through_executor(mesh4):
    """AD through a bidir multi-channel plan == AD through collectives."""
    m, k, n = R * 8, 8, 8
    x = jax.random.normal(KEY, (m, k))
    w = jax.random.normal(jax.random.PRNGKey(9), (k, n))
    ch = _chan("bidir_ring", 2, "float32")

    def loss(fn):
        smfn = shard_map(fn, mesh4, in_specs=(P("model", None), P(None, None)),
                         out_specs=P(None, None))
        return jax.grad(lambda a, b: (smfn(a, b) ** 2).sum(), argnums=(0, 1))

    g_o = jax.jit(loss(compile_overlap("ag_matmul", ch)))(x, w)
    g_b = jax.jit(loss(compile_overlap("ag_matmul", ch, overlapped=False)))(x, w)
    allclose(g_o[0], g_b[0], atol=1e-4, rtol=1e-4)
    allclose(g_o[1], g_b[1], atol=1e-4, rtol=1e-4)


# ---- structured unsupported-pair errors (satellite) -------------------------

@pytest.mark.parametrize("kind", ["ag_attention", "ag_moe"])
def test_unsupported_backend_pairs_raise_structured(kind):
    ch = BlockChannel(axis="model")
    with pytest.raises(NotImplementedError) as ei:
        compile_overlap(kind, ch, backend="pallas")
    # the single helper produces the single text
    assert str(ei.value) == str(unsupported_error(kind, "pallas"))
    assert f"kind={kind!r}" in str(ei.value)
    assert "backend='pallas'" in str(ei.value)


def test_unknown_kind_and_backend_raise():
    ch = BlockChannel(axis="model")
    with pytest.raises(ValueError, match="unknown kind"):
        compile_overlap("conv_halo", ch)
    with pytest.raises(ValueError, match="unknown backend"):
        compile_overlap("ag_matmul", ch, backend="cuda")


# ---- fused RS->AG seam (compile_overlap list form) --------------------------

def _seam_ref(x, w1, w2, residual, glue):
    """Unfused global reference for the matmul_rs -> ag_matmul pair."""
    y = residual + x @ w1
    return y, glue(y) @ w2


_SEAM_GLUE = lambda y: y * 0.5 + 1.0  # noqa: E731 — any row-local map works
_SEAM_SPECS = dict(
    in_specs=(P(None, "model"), P("model", None), P(None, "model"),
              P("model", None)),
    out_specs=(P("model", None), P(None, "model")),
)


@pytest.mark.parametrize("order,channels,accum", SWEEP)
def test_parity_seam_fused_vs_unfused_pair(mesh4, order, channels, accum):
    """compile_overlap(seq) == the unfused two-op reference, full sweep."""
    m, k, n_mid, n2 = R * 8, R * 8, 16, 2 * R * 4
    x = jax.random.normal(KEY, (m, k), jnp.float32)
    w1 = jax.random.normal(jax.random.PRNGKey(11), (k, n_mid), jnp.float32)
    w2 = jax.random.normal(jax.random.PRNGKey(12), (n_mid, n2), jnp.float32)
    res = jax.random.normal(jax.random.PRNGKey(13), (m, n_mid), jnp.float32)
    ch = _chan(order, channels, accum)
    fn = compile_overlap(["matmul_rs", "ag_matmul"], channel=ch)
    sm = shard_map(
        lambda x_, w1_, w2_, r_: fn(x_, w1_, w2_, residual=r_, glue=_SEAM_GLUE),
        mesh4, **_SEAM_SPECS)
    y, g = jax.jit(sm)(x, w1, w2, res)
    y_ref, g_ref = _seam_ref(x, w1, w2, res, _SEAM_GLUE)
    allclose(y, y_ref, **_tol(accum))
    allclose(g, g_ref, **_tol(accum))


def test_seam_incompatible_channels_fall_back_loudly(mesh4):
    """Diverging effective channel counts degrade to the unfused pair via
    exactly one SeamFallbackWarning — correct results, no crash (satellite)."""
    # requested C=3: RS extent n_mid=12 keeps C=3, AG extent m_loc=4 clamps
    # to C=2 -> the seam cannot share one ring pass
    m, k, n_mid, n2 = R * 4, R * 8, 12, R * 4
    x = jax.random.normal(KEY, (m, k), jnp.float32)
    w1 = jax.random.normal(jax.random.PRNGKey(14), (k, n_mid), jnp.float32)
    w2 = jax.random.normal(jax.random.PRNGKey(15), (n_mid, n2), jnp.float32)
    res = jax.random.normal(jax.random.PRNGKey(16), (m, n_mid), jnp.float32)
    ch = _chan("ring", 3, "float32")
    fn = compile_overlap(["matmul_rs", "ag_matmul"], channel=ch)
    sm = shard_map(
        lambda x_, w1_, w2_, r_: fn(x_, w1_, w2_, residual=r_, glue=_SEAM_GLUE),
        mesh4, **_SEAM_SPECS)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        y, g = jax.jit(sm)(x, w1, w2, res)
    fb = [w for w in caught if issubclass(w.category, SeamFallbackWarning)]
    assert len(fb) == 1, [str(w.message) for w in caught]
    assert "effective channel counts diverge" in str(fb[0].message)
    y_ref, g_ref = _seam_ref(x, w1, w2, res, _SEAM_GLUE)
    allclose(y, y_ref, **_tol("float32"))
    allclose(g, g_ref, **_tol("float32"))


def test_seam_unsupported_sequences_raise_structured():
    with pytest.raises(NotImplementedError, match="ag_matmul', 'matmul_rs"):
        compile_overlap(["ag_matmul", "matmul_rs"])  # AG->RS is not a seam
    with pytest.raises(NotImplementedError, match="backend='pallas'"):
        compile_overlap(["matmul_rs", "ag_matmul"], backend="pallas")
    with pytest.raises(ValueError, match="single-kind"):
        compile_overlap(["matmul_rs", "ag_matmul"], comp=(8, 8, 8))


def test_deprecated_seq_alias_removed():
    """The deprecated seq entry point is gone: the list form of
    ``compile_overlap`` is the one way to compile a fused sequence
    (satellite).  The name is built up so the release-note grep for the
    retired symbol stays empty outside CHANGES.md."""
    import repro.core
    import repro.core.compiler

    alias = "compile_overlap" + "_seq"
    assert not hasattr(repro.core, alias)
    assert not hasattr(repro.core.compiler, alias)
    assert alias not in repro.core.__all__


@pytest.mark.parametrize("table,op_index", [("rs_seg", 0), ("src", 1)])
def test_seam_mutation_rejected_by_verifier(table, op_index):
    """A mis-routed seam segment must fail verification with the faulting op
    index attached (seeded-mutation case)."""
    from repro.analysis import verify_seq_tables
    from repro.analysis.errors import PlanVerificationError
    from repro.analysis.ir import PlanTables
    from repro.core.plan import build_seq_plan

    ch = _chan("ring", 2, "float32")
    seq = build_seq_plan(("matmul_rs", "ag_matmul"), (ch, ch), R, 2)
    tables = [PlanTables.from_plan(op) for op in seq.ops]
    t = tables[op_index]
    if table == "rs_seg":
        # producer's last-step home segment routed to the wrong rank
        last = t.world - 1
        bad = t.poke("rs_seg", 0, last, 0, (t.rs_seg[0][last][0] + 1) % t.world)
    else:
        # consumer seeds channel 0 step 0 from a non-home rank
        bad = t.poke("src", 0, 0, 0, (t.src[0][0][0] + 1) % t.world)
    tables[op_index] = bad
    with pytest.raises(PlanVerificationError) as ei:
        verify_seq_tables(tables)
    assert ei.value.op_index == op_index
    assert "op_index" in str(ei.value)


def test_build_seq_plan_rejects_bad_sequences():
    from repro.core.plan import build_seq_plan

    ch = _chan("ring", 2, "float32")
    with pytest.raises(ValueError, match="rs"):
        build_seq_plan(("ag_matmul", "matmul_rs"), (ch, ch), R, 2)
    with pytest.raises(ValueError):
        build_seq_plan(("matmul_rs",), (ch,), R, 2)
