"""bench-gate contract (benchmarks/compare.py) — pure host logic, no jax.

The asymmetric coverage rule is the load-bearing part (ISSUE 5): entries
present in the baseline but missing from the PR run are failures; entries
new in the PR run — a new kind, a new sweep-stats block — are "new entry"
notices and must never fail the gate, even for the exact-gated invariant
leaves.  Exact invariants (candidate counts, the sweep pruning ledger) gate
only when both runs carry them.
"""
import importlib.util
import json
import os
import sys

_SPEC = importlib.util.spec_from_file_location(
    "bench_compare",
    os.path.join(os.path.dirname(__file__), os.pardir, "benchmarks", "compare.py"),
)
compare = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(compare)


def _cmp(base, cur, **kw):
    kw.setdefault("tolerance", 0.2)
    kw.setdefault("floor_us", 200.0)
    return compare.compare_file(
        "BENCH_x.json", compare.flatten(base), compare.flatten(cur), **kw
    )


BASE = {
    "ag_matmul": {"considered": 18, "us": 100.0, "cache_round_trip": True},
}


def test_identical_runs_pass():
    failures, notices = _cmp(BASE, BASE)
    assert not failures and not notices


def test_new_entries_are_notices_not_failures():
    cur = dict(
        BASE,
        ag_attention={"joint": {"considered": 54, "us": 10.0}},
        sweep={"total": 222, "screened": 89, "timed": 1, "pruned": 133},
    )
    failures, notices = _cmp(BASE, cur)
    assert not failures  # exact-gated leaves in NEW entries must not fail
    assert any("new entry" in n for n in notices)
    # grouped per subtree: one notice per new block, not one per leaf
    assert len(notices) == 2


def test_missing_from_pr_run_stays_a_failure():
    cur = {"ag_matmul": {"us": 100.0, "cache_round_trip": True}}  # no considered
    failures, _ = _cmp(BASE, cur)
    assert any("considered" in f and "missing" in f for f in failures)


def test_exact_invariants_gate_when_present_in_both():
    cur = dict(BASE, ag_matmul={"considered": 20, "us": 100.0, "cache_round_trip": True})
    failures, _ = _cmp(BASE, cur)
    assert any("exact invariant changed 18 -> 20" in f for f in failures)

    base = {"k": {"sweep": {"pruned": 133, "timed": 1}}}
    cur = {"k": {"sweep": {"pruned": 40, "timed": 1}}}
    failures, _ = _cmp(base, cur)
    assert any("pruned" in f for f in failures)


def test_timing_tolerance_and_floor():
    slow = dict(BASE, ag_matmul=dict(BASE["ag_matmul"], us=180.0))
    failures, _ = _cmp(BASE, slow)
    assert not failures  # +80% but under the 200us jitter floor

    base = {"k": {"us": 10_000.0}}
    failures, _ = _cmp(base, {"k": {"us": 13_000.0}})
    assert any("timing regression" in f for f in failures)
    failures, _ = _cmp(base, {"k": {"us": 11_000.0}})
    assert not failures  # within 20%


def test_health_flags_may_not_regress():
    cur = dict(BASE, ag_matmul=dict(BASE["ag_matmul"], cache_round_trip=False))
    failures, _ = _cmp(BASE, cur)
    assert any("health flag regressed" in f for f in failures)


def test_main_no_baseline_passes_with_notice(tmp_path, capsys, monkeypatch):
    cur_dir = tmp_path / "current"
    cur_dir.mkdir()
    with open(cur_dir / "BENCH_x.json", "w") as fh:
        json.dump(BASE, fh)
    monkeypatch.setattr(
        sys,
        "argv",
        ["compare.py", "--baseline", str(tmp_path / "nope"), "--current", str(cur_dir)],
    )
    assert compare.main() == 0
    assert "no baseline" in capsys.readouterr().out


def test_refresh_baseline_downgrades_exact_drift_to_notice():
    cur = dict(BASE, ag_matmul={"considered": 20, "us": 100.0, "cache_round_trip": True})
    pat = "BENCH_x.json:ag_matmul/considered"
    failures, notices = _cmp(BASE, cur, refresh=[pat])
    assert not failures
    assert any("refreshed" in n and pat in n for n in notices)
    # a pattern that does NOT match leaves the failure in place
    failures, _ = _cmp(BASE, cur, refresh=["BENCH_other.json:*"])
    assert any("exact invariant changed" in f for f in failures)


def test_refresh_baseline_covers_dropped_entries_too():
    cur = {"ag_matmul": {"us": 100.0, "cache_round_trip": True}}  # no considered
    failures, notices = _cmp(BASE, cur, refresh=["BENCH_x.json:*/considered"])
    assert not failures
    assert any("missing" in n and "refreshed" in n for n in notices)


def test_refresh_patterns_load_from_file_and_cli(tmp_path):
    path = tmp_path / "refresh_baseline.txt"
    path.write_text("# comment line\n\nBENCH_x.json:*/considered\n")
    pats = compare.load_refresh_patterns(["cli:pat"], str(path))
    assert pats == ["cli:pat", "BENCH_x.json:*/considered"]
    # absent file: CLI patterns only, no error
    assert compare.load_refresh_patterns([], str(tmp_path / "nope.txt")) == []


def test_main_new_bench_file_is_a_notice(tmp_path, capsys, monkeypatch):
    base_dir, cur_dir = tmp_path / "baseline", tmp_path / "current"
    base_dir.mkdir()
    cur_dir.mkdir()
    with open(base_dir / "BENCH_x.json", "w") as fh:
        json.dump(BASE, fh)
    with open(cur_dir / "BENCH_x.json", "w") as fh:
        json.dump(BASE, fh)
    with open(cur_dir / "BENCH_new.json", "w") as fh:  # added by the PR
        json.dump({"kind": {"considered": 7}}, fh)
    monkeypatch.setattr(
        sys, "argv", ["compare.py", "--baseline", str(base_dir), "--current", str(cur_dir)]
    )
    assert compare.main() == 0
    assert "new bench artifact" in capsys.readouterr().out
