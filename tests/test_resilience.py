"""Fault tolerance: checkpoint/restore, elastic remesh, restart loop, watchdog,
data-pipeline exactly-once semantics."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.compat import make_mesh
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models import lm
from repro.parallel.context import ParallelContext
from repro.parallel.sharding import place
from repro.runtime import StepWatchdog, ElasticMesh, run_resilient
from repro.training import AdamWConfig, init_opt_state, make_train_step
from utils import reduce_config


def _tiny(pc, mesh):
    cfg = reduce_config(get_config("smollm-360m"))
    cfg = dataclasses.replace(cfg, n_layers=2, vocab_size=128)
    params = place(lm.init(jax.random.PRNGKey(0), cfg, pc, jnp.float32),
                   mesh, lm.specs(cfg, pc))
    return cfg, params


def test_checkpoint_roundtrip_and_retention(tmp_path, pc8, mesh8):
    cfg, params = _tiny(pc8, mesh8)
    opt = init_opt_state(params)
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    for s in (1, 2, 3):
        mgr.save(s, params, opt, extra={"data": {"cursor": s * 10, "seed": 0}})
    mgr.wait()
    assert mgr.all_steps() == [2, 3]  # retention dropped step 1
    restored, meta = mgr.restore(3, {"params": params, "opt": opt})
    assert meta["extra"]["data"]["cursor"] == 30
    for a, b in zip(jax.tree_util.tree_leaves(restored["params"]),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_resume_continues_training(tmp_path, pc8, mesh8):
    """Save at step k, restore, continue — identical to uninterrupted run."""
    cfg, params = _tiny(pc8, mesh8)
    opt = init_opt_state(params)
    step = make_train_step(lm, cfg, pc8, AdamWConfig(lr=1e-3, total_steps=10),
                           donate=False)
    pipe = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    mgr = CheckpointManager(str(tmp_path), async_save=False)

    # uninterrupted: 4 steps
    p_u, o_u, pipe_u = params, opt, SyntheticLM(
        vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    for _ in range(4):
        p_u, o_u, _ = step(p_u, o_u, pipe_u.host_batch())

    # interrupted at 2
    p, o = params, opt
    for _ in range(2):
        p, o, _ = step(p, o, pipe.host_batch())
    mgr.save(2, p, o, extra={"data": pipe.state()})
    # "crash"; restore
    restored, meta = mgr.restore(2, {"params": p, "opt": o})
    p2, o2 = restored["params"], restored["opt"]
    pipe2 = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    pipe2.restore(meta["extra"]["data"])
    for _ in range(2):
        p2, o2, _ = step(p2, o2, pipe2.host_batch())

    for a, b in zip(jax.tree_util.tree_leaves(p2),
                    jax.tree_util.tree_leaves(p_u)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_elastic_restore_onto_different_mesh(tmp_path):
    """Checkpoint saved on one mesh restores onto another (elastic scaling)."""
    mesh_a = make_mesh((1, 2, 4), ("pod", "data", "model"))
    pc_a = ParallelContext(mesh=mesh_a)
    cfg, params = _tiny(pc_a, mesh_a)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, params, init_opt_state(params), extra={})

    mesh_b = make_mesh((1, 4, 2), ("pod", "data", "model"))  # remesh!
    pc_b = ParallelContext(mesh=mesh_b)
    cfg_b = reduce_config(get_config("smollm-360m"))
    cfg_b = dataclasses.replace(cfg_b, n_layers=2, vocab_size=128)
    like = lm.init(jax.random.PRNGKey(1), cfg_b, pc_b, jnp.float32)
    restored, _ = mgr.restore(1, {"params": like, "opt": init_opt_state(like)},
                              mesh_b, {"params": lm.specs(cfg_b, pc_b),
                                       "opt": {"mu": lm.specs(cfg_b, pc_b),
                                               "nu": lm.specs(cfg_b, pc_b),
                                               "step": jax.sharding.PartitionSpec()}})
    # same values, new sharding; forward runs on the new mesh
    logits, _ = jax.jit(lambda p, t: lm.forward(p, cfg_b, pc_b, t))(
        restored["params"], jnp.ones((2, 16), jnp.int32))
    assert not bool(jnp.isnan(logits).any())


def test_elastic_mesh_planner():
    em = ElasticMesh(target_model=16)
    assert em.plan(512) == {"pod": 2, "data": 16, "model": 16}
    assert em.plan(256) == {"pod": 2, "data": 8, "model": 16}
    p = em.plan(240)  # 16 dead chips: model stays 16, data shrinks
    assert p["model"] == 16 and p["pod"] * p["data"] * p["model"] == 240
    p = em.plan(6)
    assert p["pod"] * p["data"] * p["model"] == 6


def test_run_resilient_restarts_after_failures(tmp_path):
    calls = {"n": 0}

    def make_state():
        return {"attempt": calls["n"]}

    def run(state):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError(f"simulated node failure {calls['n']}")
        return "done"

    failures = []
    out = run_resilient(make_state, run, max_failures=3,
                        on_failure=lambda e, n: failures.append(str(e)))
    assert out == "done"
    assert len(failures) == 2


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(threshold=3.0, min_samples=3)
    for _ in range(5):
        wd.start()
        time.sleep(0.01)
        wd.stop()
    wd.start()
    time.sleep(0.2)
    assert wd.stop() is True
    assert wd.stragglers == 1


def test_data_pipeline_exactly_once_across_remesh():
    """Global cursor semantics: resharding hosts never duplicates samples."""
    ref = SyntheticLM(vocab_size=64, seq_len=8, global_batch=8)
    b0, b1 = ref.host_batch(), ref.host_batch()

    # same stream consumed by 2 hosts for step0, then 4 hosts for step1
    parts = []
    for hid in range(2):
        p = SyntheticLM(vocab_size=64, seq_len=8, global_batch=8,
                        n_hosts=2, host_id=hid)
        parts.append(p.host_batch()["inputs"])
    np.testing.assert_array_equal(np.concatenate(parts), b0["inputs"])

    parts = []
    for hid in range(4):
        p = SyntheticLM(vocab_size=64, seq_len=8, global_batch=8,
                        n_hosts=4, host_id=hid)
        p.restore({"cursor": 1, "seed": 0})
        parts.append(p.host_batch()["inputs"])
    np.testing.assert_array_equal(np.concatenate(parts), b1["inputs"])
