"""Expert-parallel MoE: the overlapped dispatch/combine all-to-all pair.

The tentpole claim of the EP redesign: ``["a2a_dispatch", "combine_rs"]``
compiles through the same plan -> verifier -> executor pipeline as every
other kind, and the overlapped pipeline matches the unfused
``a2a_moe_baseline`` (bulk AllGather + GroupGEMM + ReduceScatter with
identical capacity semantics) across the full CommSpec sweep — including
capacity regimes that force token drops, where the kept/dropped sets must
agree BITWISE, not just within tolerance.
"""
import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map, make_mesh
from repro.core import BlockChannel, CommSpec, CompSpec, compile_overlap
from repro.core.moe_overlap import a2a_moe, a2a_moe_baseline, moe_router
from repro.parallel.context import ParallelContext
from utils import allclose

KEY = jax.random.PRNGKey(0)
R = 4  # world size of the parity mesh

ORDERS = ("ring", "bidir_ring", "all2all")
CHANNELS = (1, 2, 4)
ACCUMS = ("float32", "bfloat16")
SWEEP = list(itertools.product(ORDERS, CHANNELS, ACCUMS))


@pytest.fixture(scope="module")
def mesh4():
    return make_mesh((R,), ("model",))


def _chan(order, channels, accum):
    return BlockChannel(axis="model", num_channels=channels,
                        comm=CommSpec(order=order),
                        comp=CompSpec(accum_dtype=accum))


def _tol(accum):
    return dict(atol=2e-4, rtol=2e-3) if accum == "float32" else dict(atol=8e-2, rtol=3e-2)


def _operands(m, d=16, f=16, e=8, k_top=2, scale=0.5):
    x = jax.random.normal(KEY, (m, d)) * scale
    wr = jax.random.normal(jax.random.PRNGKey(5), (d, e))
    wgu = jax.random.normal(jax.random.PRNGKey(6), (e, d, 2 * f)) * 0.1
    wdn = jax.random.normal(jax.random.PRNGKey(7), (e, f, d)) * 0.1
    return x, wr, wgu, wdn


def _ep_shard_fn(mesh, ch, wr, e, k_top, overlapped, capacity_factor):
    """EP layout: tokens sequence-sharded, experts sharded over the same axis."""
    fn = compile_overlap(["a2a_dispatch", "combine_rs"], channel=ch,
                         overlapped=overlapped,
                         capacity_factor=capacity_factor)

    def f_(xs, wgu_, wdn_):
        ids, wts, _ = moe_router(xs, wr, num_experts=e, top_k=k_top)
        return fn(xs, ids, wts, wgu_, wdn_)

    return shard_map(f_, mesh,
                     in_specs=(P("model", None), P("model", None, None),
                               P("model", None, None)),
                     out_specs=P("model", None))


# ---- parity sweep: the full comm/comp space ---------------------------------

@pytest.mark.parametrize("order,channels,accum", SWEEP)
def test_parity_a2a_moe(mesh4, order, channels, accum):
    e, k_top = 8, 2
    x, wr, wgu, wdn = _operands(R * 16)
    ch = _chan(order, channels, accum)
    y_o = jax.jit(_ep_shard_fn(mesh4, ch, wr, e, k_top, True, 8.0))(x, wgu, wdn)
    y_b = jax.jit(_ep_shard_fn(mesh4, ch, wr, e, k_top, False, 8.0))(x, wgu, wdn)
    allclose(y_o, y_b, **_tol(accum))


# ---- capacity overflow: kept/dropped token sets must match bitwise ----------

@pytest.mark.parametrize("order", ORDERS)
@pytest.mark.parametrize("channels", (1, 2))
@pytest.mark.parametrize("capacity_factor", (8.0, 1.0, 0.25))
def test_capacity_drop_parity_bitwise(mesh4, order, channels, capacity_factor):
    """Under capacity pressure both paths must drop the SAME tokens: the
    overlapped pipeline and the baseline feed identical per-tile inputs to
    the same dispatch tables, so their float outputs agree bitwise in f32
    (any divergence in the kept set would show as O(1) output error)."""
    e, k_top = 8, 2
    # 32 tokens/rank + experts 0/1 made hot so tight capacities really
    # overflow in every channel split (the per-tile capacity floors at 8
    # rows; uniform routing of 16 tokens/rank would never hit it)
    x, wr, wgu, wdn = _operands(R * 32)
    wr = wr.at[:, :2].add(10.0)
    ch = _chan(order, channels, "float32")
    y_o = jax.jit(_ep_shard_fn(mesh4, ch, wr, e, k_top, True, capacity_factor))(x, wgu, wdn)
    y_b = jax.jit(_ep_shard_fn(mesh4, ch, wr, e, k_top, False, capacity_factor))(x, wgu, wdn)
    np.testing.assert_array_equal(np.asarray(y_o), np.asarray(y_b))
    if capacity_factor < 1.0:
        # sanity: the tight capacity really dropped something (the dropped
        # tokens contribute zeros, so the two regimes must differ)
        y_full = jax.jit(_ep_shard_fn(mesh4, ch, wr, e, k_top, True, 8.0))(x, wgu, wdn)
        assert not np.array_equal(np.asarray(y_o), np.asarray(y_full))


# ---- nn/moe apply_seq: EP opt-in, aux loss under expert padding -------------

def _moe_cfg(num_experts=8):
    from repro.configs import get_config
    from utils import reduce_config

    cfg = reduce_config(get_config("granite-moe-3b-a800m"))
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=num_experts,
                                     num_shared=0))


def _run_moe_layer(pc, cfg, params, x, *, ep=None):
    from repro.nn import moe

    specs = moe.specs(cfg, pc.tp, None)
    in_specs = (jax.tree_util.tree_map(
        pc.manual, specs, is_leaf=lambda v: isinstance(v, P)),
        P(None, "model", None))
    sm = pc.smap(lambda p, xx: moe.apply_seq(p, xx, pc, cfg, ep=ep),
                 in_specs, (P(None, "model", None), P()))
    return jax.jit(sm)(params, x)


@pytest.mark.parametrize("num_experts", (8, 6))
def test_nn_moe_ep_path(mesh4, num_experts):
    """moe.apply_seq(ep=True) == the EP baseline — including when the expert
    count pads up to the EP degree (num_experts=6 -> e_pad=8) and the aux
    loss must only see the valid experts."""
    from repro.nn import moe

    cfg = _moe_cfg(num_experts)
    pc = ParallelContext(mesh=mesh4, ep_axis="model")
    pc_b = ParallelContext(mesh=mesh4, ep_axis="model", mode="baseline")
    params = moe.init(jax.random.PRNGKey(0), cfg, pc.tp, jnp.float32)
    x = jax.random.normal(KEY, (1, R * 8, cfg.d_model), jnp.float32)

    y_o, aux_o = _run_moe_layer(pc, cfg, params, x)  # ep defaults on via ep_axis
    y_b, aux_b = _run_moe_layer(pc_b, cfg, params, x, ep=True)
    allclose(y_o, y_b, **_tol("float32"))
    # routing (and thus the aux loss) is path-independent; under padding the
    # aux must be computed over the valid experts only, and stay finite
    np.testing.assert_allclose(np.asarray(aux_o), np.asarray(aux_b), rtol=1e-6)
    assert np.isfinite(np.asarray(aux_o)).all()

    # the TP double-ring path still works side by side and agrees (no drops
    # at the generous reduced-config capacity)
    y_t, aux_t = _run_moe_layer(pc, cfg, params, x, ep=False)
    allclose(y_t, y_o, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(aux_t), np.asarray(aux_o), rtol=1e-6)


def test_unified_apply_seq_keyword_surface(mesh4):
    """Satellite: one keyword surface (tune=, next_proj=, ep=) across the nn
    blocks — ep is MoE-only, next_proj is seam-capable blocks only."""
    from repro.nn import attention, ffn, moe

    cfg = _moe_cfg()
    pc = ParallelContext(mesh=mesh4)  # no ep_axis: EP not opted in
    x = jnp.zeros((1, R * 8, cfg.d_model), jnp.float32)

    with pytest.raises(ValueError, match="ep_axis"):
        moe.apply_seq({}, x, pc, cfg, ep=True)
    with pytest.raises(ValueError, match="next_proj"):
        moe.apply_seq({}, x, pc, cfg, next_proj=(lambda y: y, None))
    with pytest.raises(ValueError, match="expert-parallel"):
        ffn.apply_seq({}, x, pc, cfg, ep=True)
    with pytest.raises(ValueError, match="expert-parallel"):
        attention.apply_seq({}, x, pc, cfg, ep=True)
    with pytest.raises(ValueError, match="expert-parallel"):
        attention.apply_seq_ring({}, x, pc, cfg, ep=True)
    with pytest.raises(ValueError, match="not a mesh axis"):
        ParallelContext(mesh=mesh4, ep_axis="experts")
    with pytest.raises(ValueError, match="ep_axis"):
        ParallelContext(mesh=mesh4).a2a_moe(x, x, x, x, x)


# ---- verifier: exchange legality, seam composition, protocol ---------------

def test_a2a_candidate_space_including_non_power_of_2():
    """Every (order, world, C) point the tuner would consider is legal —
    including world=3, where the all2all order falls back from XOR pairing
    to rotation peers (the non-power-of-2 fallback)."""
    from repro.analysis import check_a2a_candidate

    for order in ORDERS:
        for world in (2, 3, 4, 8):
            for nch in (1, 2, 4):
                assert check_a2a_candidate(order, world, nch) is None, (
                    order, world, nch)


def test_a2a_mutation_rejected_by_verifier():
    """A corrupted exchange destination or a mismatched dispatch/combine pair
    must fail verification with the structured check name attached."""
    from repro.analysis import verify_seq_tables
    from repro.analysis.errors import PlanVerificationError
    from repro.analysis.ir import PlanTables
    from repro.core.plan import build_seq_plan

    ch = _chan("all2all", 2, "float32")
    seq = build_seq_plan(("a2a_dispatch", "combine_rs"), (ch, ch), R, 2)
    tables = [PlanTables.from_plan(op) for op in seq.ops]

    # mis-route one exchange destination on the dispatch half
    t = tables[0]
    row = list(list(map(list, c)) for c in t.a2a_dst)
    row[0][1][0] = (row[0][1][0] + 1) % R
    bad = dataclasses.replace(
        t, a2a_dst=tuple(tuple(tuple(r) for r in c) for c in row))
    with pytest.raises(PlanVerificationError) as ei:
        verify_seq_tables([bad, tables[1]])
    assert ei.value.check in ("a2a_exchange_composition", "a2a_involution",
                              "a2a_seed")

    # a combine that disagrees with its dispatch about who sent step s
    ch_ring = _chan("ring", 2, "float32")
    other = build_seq_plan(("a2a_dispatch", "combine_rs"), (ch_ring, ch_ring), R, 2)
    mixed = [PlanTables.from_plan(seq.ops[0]),
             PlanTables.from_plan(other.ops[1])]
    with pytest.raises(PlanVerificationError) as ei:
        verify_seq_tables(mixed)
    assert ei.value.check == "a2a_seam_composition"


def test_verify_cli_covers_a2a_kinds():
    """`verify --all` includes the a2a kinds and the fused pair."""
    from repro.analysis.verify import SEQ_OPS, A2A_SEQ_KIND

    assert SEQ_OPS[A2A_SEQ_KIND] == ("a2a_dispatch", "combine_rs")
    from repro.core.plan import FLOW_OF_KIND

    assert FLOW_OF_KIND["a2a_dispatch"] == "a2a"
    assert FLOW_OF_KIND["combine_rs"] == "a2a_rs"


# ---- compiler: list form, structured errors --------------------------------

def test_compile_overlap_a2a_list_form(mesh4):
    """The list form compiles the pair; pallas and comp= stay structured
    errors like the RS->AG seam."""
    with pytest.raises(NotImplementedError, match="a2a_dispatch"):
        compile_overlap(["a2a_dispatch", "combine_rs"], backend="pallas")
    with pytest.raises(NotImplementedError, match="combine_rs', 'a2a_dispatch"):
        compile_overlap(["combine_rs", "a2a_dispatch"])
    with pytest.raises(ValueError, match="single-kind"):
        compile_overlap(["a2a_dispatch", "combine_rs"], comp=(8, 8, 8))

    # channel=None compiles with the default channel
    e, k_top = 8, 2
    x, wr, wgu, wdn = _operands(R * 16)
    fn = compile_overlap(["a2a_dispatch", "combine_rs"], capacity_factor=8.0)

    def f_(xs, wgu_, wdn_):
        ids, wts, _ = moe_router(xs, wr, num_experts=e, top_k=k_top)
        return fn(xs, ids, wts, wgu_, wdn_)

    sm = shard_map(f_, mesh4,
                   in_specs=(P("model", None), P("model", None, None),
                             P("model", None, None)),
                   out_specs=P("model", None))
    y = jax.jit(sm)(x, wgu, wdn)
    y_b = jax.jit(_ep_shard_fn(mesh4, _chan("ring", 1, "float32"), wr, e,
                               k_top, False, 8.0))(x, wgu, wdn)
    allclose(y, y_b, **_tol("float32"))


def test_compile_overlap_a2a_auto_channel(mesh4):
    """channel='auto' resolves the pair jointly (model-ranked) and matches
    the baseline numerically within the winner's flow-dtype tolerance."""
    e, k_top = 8, 2
    x, wr, wgu, wdn = _operands(R * 16)
    fn = compile_overlap(["a2a_dispatch", "combine_rs"], channel="auto",
                         axis="model", capacity_factor=8.0)

    def f_(xs, wgu_, wdn_):
        ids, wts, _ = moe_router(xs, wr, num_experts=e, top_k=k_top)
        return fn(xs, ids, wts, wgu_, wdn_)

    sm = shard_map(f_, mesh4,
                   in_specs=(P("model", None), P("model", None, None),
                             P("model", None, None)),
                   out_specs=P("model", None))
    y = jax.jit(sm)(x, wgu, wdn)
    y_b = jax.jit(_ep_shard_fn(mesh4, _chan("ring", 1, "float32"), wr, e,
                               k_top, False, 8.0))(x, wgu, wdn)
    # the joint search may pick a bf16 flow for the combine partials
    allclose(y, y_b, **_tol("bfloat16"))


# ---- tuner: hop counts, signatures, joint resolution ------------------------

def test_order_hops_derived_from_peer_tables():
    """Satellite: all2all hop counts come from schedules.all2all_peer, not
    the old max(1, world/4) guess — and differ from it where it was wrong."""
    from repro.core import schedules
    from repro.tune.cost import _order_hops

    for order in ("ring", "bidir_ring"):
        assert _order_hops(order, 8) == 1.0
    # power-of-2: mean XOR-pair ring distance
    for world in (2, 4, 8):
        total = sum(
            min((schedules.all2all_peer(r, s, world) - r) % world,
                (r - schedules.all2all_peer(r, s, world)) % world)
            for s in range(1, world) for r in range(world))
        assert _order_hops("all2all", world) == max(
            1.0, total / ((world - 1) * world))
    # non-power-of-2 fallback is rotation: neighbors half the time -> the
    # old world/4 heuristic overcharged it
    assert _order_hops("all2all", 3) == 1.0
    assert _order_hops("all2all", 6) != max(1.0, 6 / 4.0)


def test_moe_signature_workload_axes():
    """MoE signatures carry quantized (imbalance, capacity) axes; every
    consumer slices sig[:5] so the axes never break shape unpacking."""
    from repro import tune
    from repro.tune import cost

    shapes = [(64, 16), (64, 2), (64, 2), (8, 16, 32), (8, 16, 16)]
    base = tune.signature("ag_moe", shapes)
    assert len(base) == 5
    sig = tune.signature(tune.A2A_SEQ_KIND, shapes, imbalance=1.6, capacity=21)
    assert sig[:5] == base
    assert sig[5:] == (6, 24)  # 1.6 -> 6 quarter-units; 21 -> 24 rows
    # capacity without imbalance still pins the positional layout
    sig2 = tune.signature("ag_moe", shapes, capacity=40)
    assert sig2[5:] == (4, 40)
    with pytest.raises(ValueError, match="MoE"):
        tune.signature("ag_matmul", [(8, 8), (8, 8)], capacity=8)
    # cost model consumes the extended sigs without unpacking errors, and a
    # tighter capacity never models slower
    cand = tune.Candidate(order="ring", num_channels=1, accum_dtype="float32")
    for kind in ("ag_moe", "a2a_dispatch", "combine_rs"):
        assert cost.predict_cost(kind, sig, R, cand) > 0.0
    loose = tune.signature("ag_moe", shapes, capacity=512)
    tight = tune.signature("ag_moe", shapes, capacity=8)
    assert (cost.predict_cost("ag_moe", tight, R, cand)
            <= cost.predict_cost("ag_moe", loose, R, cand))


def test_resolve_a2a_joint(mesh4):
    """resolve_a2a returns one shared verified channel for both halves, and
    the overlapped program never models slower than the split one."""
    from repro import tune
    from repro.analysis import check_a2a_candidate
    from repro.tune import cost

    shapes = [(64, 16), (64, 2), (64, 2), (8, 16, 32), (8, 16, 16)]
    fused, ch_d, ch_c = tune.resolve_a2a(shapes=shapes, mesh=mesh4,
                                         capacity_factor=1.25)
    assert fused and ch_d is ch_c
    assert check_a2a_candidate(ch_d.comm.order, R, ch_d.num_channels) is None
    sig = tune.signature(tune.A2A_SEQ_KIND, shapes)
    for cand in tune.enumerate_a2a_candidates(sig=sig, world=R):
        assert (cost.predict_a2a_cost(sig, R, cand, fused=True)
                <= cost.predict_a2a_cost(sig, R, cand, fused=False))
