"""Autotuner subsystem: enumeration, cache contract, channel="auto" parity.

The contract under test (ISSUE 3 + ISSUE 4 + ISSUE 5 acceptance):
  * candidate enumeration is deterministic and honors
    ``mapping.effective_channels`` divisibility;
  * the joint space's compute-tile lattice respects shape-divisibility,
    MXU-alignment, and VMEM-footprint pruning — for the GEMM kinds AND the
    attention/MoE consumers;
  * the measured ranker's timing path is trustworthy: compile time is
    AOT-split out of every score, ``time_fn`` reports (median, iqr) and
    refuses cold calls, and the successive-halving sweep prunes the joint
    space while agreeing with the exhaustive sweep's winner;
  * cache entries survive a save/load round-trip (memo AND disk); v1/v2 and
    malformed/corrupt records re-tune under the v3 schema instead of
    crashing;
  * a mesh-fingerprint mismatch invalidates (re-tunes) instead of silently
    reusing another mesh's winner;
  * a fingerprint hit never re-measures;
  * ``channel="auto"`` / ``comp="auto"`` output is parity-equal to the
    default-tile path on both backends on the 4-rank emulated mesh, for the
    GEMM kinds and the tiled attention/MoE consumers alike.
"""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import tune
from repro.compat import make_mesh, shard_map
from repro.core import BlockChannel, CompSpec, compile_overlap, effective_channels
from repro.core.comp_tiles import DEFAULT_TILE, blocked_dot
from repro.core.moe_overlap import moe_router
from repro.tune import cache as tune_cache
from repro.tune import measure as tune_measure
from repro.tune import sweep as tune_sweep

R = 4
KEY = jax.random.PRNGKey(0)

SIGS = {
    "ag_matmul": (1, 16, 16, 12),
    "matmul_rs": (1, R * 8, 8, 16),
    "ag_attention": (1, 2, 1, 16, 8),
    "ag_moe": (16, 8, 2, 2, 8),
}

TINY_SPACE = tune.Space(orders=("ring",), channel_counts=(1,), accum_dtypes=("float32",))

MEASURE_KW = dict(ranker="measure", space=TINY_SPACE, repeats=1, warmup=1)


class FakeCaseTimer:
    """Drop-in for measure.CaseTimer: deterministic scores, no wall clock."""

    calls = []

    def __init__(self, kind, mesh, axis, sig):
        self.kind = kind

    def time(self, channel, *, repeats=3, warmup=1):
        type(self).calls.append((self.kind, repeats))
        return 1.0, 0.0


@pytest.fixture(scope="module")
def mesh4():
    return make_mesh((R,), ("model",))


@pytest.fixture(autouse=True)
def isolated_cache(tmp_path, monkeypatch):
    """Every test gets a fresh cache dir + empty process memo."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tune-cache"))
    tune_cache.clear_memo()
    yield
    tune_cache.clear_memo()


# ---- candidate enumeration --------------------------------------------------


def test_enumeration_deterministic():
    a = tune.enumerate_candidates("ag_matmul", extent=32)
    b = tune.enumerate_candidates("ag_matmul", extent=32)
    assert a == b
    assert len(a) == 18  # 3 orders x {1,2,4} x 2 dtypes, all feasible


def test_enumeration_honors_effective_channels():
    # extent 6: requested {1,2,4} -> effective {1,2,3} via the same
    # largest-divisor rule the runtime plan layer applies
    cands = tune.enumerate_candidates("ag_matmul", extent=6)
    for c in cands:
        assert 6 % c.num_channels == 0
        assert c.num_channels in {effective_channels(6, req) for req in (1, 2, 4)}
    # extent 5 (prime, < 2): every count clamps to 1 and duplicates collapse
    clamped = tune.enumerate_candidates("ag_matmul", extent=5)
    assert {c.num_channels for c in clamped} == {1}
    assert len(clamped) == 6  # 3 orders x 2 dtypes, one channel point each


def test_signature_canonicalization():
    assert tune.signature("ag_matmul", [(2, 3, 16, 8), (8, 5)]) == (6, 16, 8, 5)
    att = tune.signature("ag_attention", [(1, 4, 16, 8), (1, 2, 16, 8)])
    assert att == (1, 4, 2, 16, 8)
    sig = tune.signature("ag_moe", [(16, 8), (16, 2), (16, 2), (4, 8, 32), (4, 16, 8)])
    assert sig == (16, 8, 2, 4, 16)


def test_decode_signature_keys_separately():
    """decode=True negates the lead ONLY — same dims, disjoint cache entries
    for every tiny-M decode shape (serving-engine satellite)."""
    from repro.tune import _entry_key

    shapes = [(4, 1, 512), (512, 512)]
    sig_p = tune.signature("ag_matmul", shapes)
    sig_d = tune.signature("ag_matmul", shapes, decode=True)
    assert sig_d == (-sig_p[0],) + sig_p[1:]
    keys = set()
    for m in range(1, 9):  # M = 1..8 decode slots, each its own corner
        s = [(m, 1, 512), (512, 512)]
        for decode in (False, True):
            sig = tune.signature("ag_matmul", s, decode=decode)
            keys.add(_entry_key("ag_matmul", "model", 4, sig, tune.JOINT_SPACE))
    assert len(keys) == 16  # 8 decode + 8 prefill, no aliasing
    # non-GEMM kinds have no decode corner — refuse loudly
    with pytest.raises(ValueError, match="decode signatures"):
        tune.signature("ag_attention", [(1, 4, 16, 8), (1, 2, 16, 8)], decode=True)


def test_decode_winner_differs_from_prefill(mesh4):
    """The decode corner (n_slots rows of ONE token) must resolve a different
    joint winner than the prefill shape sharing its K/N dims — on the
    analytic ranker, no device timing (serving-engine satellite)."""
    for kind, pre_sig, dec_sig in [
        ("ag_matmul", (1, 1024, 512, 512), (-8, 1, 512, 512)),
        ("matmul_rs", (1, 1024, 128, 512), (-8, 1, 128, 512)),
    ]:
        pre = tune.autotune(kind, signature=pre_sig, mesh=mesh4, space=tune.JOINT_SPACE)
        dec = tune.autotune(kind, signature=dec_sig, mesh=mesh4, space=tune.JOINT_SPACE)
        assert dec.channel.comp.tile[0] == 1  # one-token tiles for decode
        assert (pre.channel.comp.tile != dec.channel.comp.tile
                or pre.channel.num_channels != dec.channel.num_channels), kind


# ---- joint space: compute-tile lattice (ISSUE 4) ----------------------------


def test_joint_enumeration_divisibility():
    sig = (1, 256, 512, 384)  # (lead, m_loc, k, n_loc); n=384 defeats tn=256
    cands = tune.enumerate_candidates(
        "ag_matmul", extent=256, space=tune.JOINT_SPACE, sig=sig, world=4
    )
    assert cands == tune.enumerate_candidates(
        "ag_matmul", extent=256, space=tune.JOINT_SPACE, sig=sig, world=4
    )  # deterministic
    for c in cands:
        tm, tn, tk = c.comp_tile
        if c.comp_tile == DEFAULT_TILE:
            continue  # sentinel: backend-chosen blocking, never clamped
        m_sub = 256 // c.num_channels
        assert m_sub % tm == 0 and 384 % tn == 0 and 512 % tk == 0
        # MXU alignment: clamped dims are full-extent or packing multiples
        assert tn == 384 or tn % 128 == 0
    # the 256-request on n=384 clamps to 192, which is neither the full
    # extent nor lane-aligned — the pruner must have dropped it
    assert all(c.comp_tile[1] != 192 for c in cands)
    # a genuinely non-default tile survives for this shape
    assert any(c.comp_tile != DEFAULT_TILE for c in cands)


def test_joint_enumeration_vmem_pruning(monkeypatch):
    sig = (1, 256, 512, 256)
    full = tune.comp_tile_candidates("ag_matmul", sig, world=4, space=tune.JOINT_SPACE)
    assert len(full) > 1
    monkeypatch.setenv("REPRO_VMEM_BYTES", "1000")  # nothing fits
    pruned = tune.comp_tile_candidates("ag_matmul", sig, world=4, space=tune.JOINT_SPACE)
    assert pruned == (DEFAULT_TILE,)  # only the unprunable sentinel survives


def test_joint_space_extends_to_attention_and_moe():
    # ISSUE 5: the attention/MoE consumers have a compute-tile axis too —
    # tiles clamp to their own dims (attention: queries x head dim x
    # per-channel KV rows; MoE: per-expert rows x 2f x d_model)
    att_sig = (1, 2, 1, 64, 32)
    att = tune.enumerate_candidates(
        "ag_attention", extent=64, space=tune.JOINT_SPACE, sig=att_sig, world=R
    )
    assert len(att) > 18  # the joint space genuinely grew past comm-only
    assert any(c.comp_tile != DEFAULT_TILE for c in att)
    for c in att:
        if c.comp_tile == DEFAULT_TILE:
            continue
        tm, tn, tk = c.comp_tile
        s_sub = 64 // c.num_channels
        assert 64 % tm == 0 and 32 % tn == 0 and s_sub % tk == 0

    moe_sig = (32, 16, 2, 2, 16)
    moe = tune.enumerate_candidates(
        "ag_moe", extent=32, space=tune.JOINT_SPACE, sig=moe_sig, world=R
    )
    assert len(moe) > 18
    assert any(c.comp_tile != DEFAULT_TILE for c in moe)
    for c in moe:
        if c.comp_tile == DEFAULT_TILE:
            continue
        tm, tn, tk = c.comp_tile
        m_sub = 32 // c.num_channels
        assert m_sub % tm == 0 and 32 % tn == 0 and 16 % tk == 0

    # an unknown signature still collapses to the sentinel
    assert tune.comp_tile_candidates("ag_attention", None, world=R) == (DEFAULT_TILE,)


def test_joint_winner_differs_from_default_tile(mesh4):
    # the acceptance shape: big enough that explicit MXU blocking beats the
    # 128^3 default under the per-tile cost terms
    res = tune.autotune(
        "ag_matmul", signature=(1, 256, 512, 256), mesh=mesh4, space=tune.JOINT_SPACE
    )
    assert res.candidate.comp_tile != DEFAULT_TILE
    assert res.channel.comp.tile == res.candidate.comp_tile
    assert "tile=" in res.candidate.label()


def test_blocked_dot_matches_plain_dot():
    a = np.asarray(jax.random.normal(KEY, (2, 24, 32)), np.float32)
    b = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (32, 16)), np.float32)
    got = np.asarray(blocked_dot(jax.numpy.asarray(a), jax.numpy.asarray(b), (8, 8, 8)))
    np.testing.assert_allclose(got, a @ b, atol=1e-5, rtol=1e-5)


# ---- cache contract ---------------------------------------------------------


def test_cache_round_trip(mesh4):
    first = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4)
    assert not first.cache_hit and first.considered == 18

    memo = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4)
    assert memo.cache_hit and memo.candidate == first.candidate

    tune_cache.clear_memo()  # force the JSON read
    disk = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4)
    assert disk.cache_hit and disk.candidate == first.candidate
    assert disk.ranker == first.ranker

    files = os.listdir(tune_cache.cache_dir())
    assert len(files) == 1 and files[0].endswith(".json")
    with open(os.path.join(tune_cache.cache_dir(), files[0])) as fh:
        payload = json.load(fh)
    assert payload["fingerprint"] == first.fingerprint
    assert len(payload["entries"]) == 1


def test_fingerprint_mismatch_invalidates(mesh4):
    first = tune.autotune("matmul_rs", signature=SIGS["matmul_rs"], mesh=mesh4)
    assert not first.cache_hit

    # same file name, tampered fingerprint payload: the stored identity no
    # longer matches the live mesh -> whole file must be ignored (re-tune),
    # never silently reused
    digest = tune_cache.fingerprint_digest(first.fingerprint)
    path = os.path.join(tune_cache.cache_dir(), digest + ".json")
    with open(path) as fh:
        payload = json.load(fh)
    payload["fingerprint"]["jax_version"] = "0.0.0-other-mesh"
    with open(path, "w") as fh:
        json.dump(payload, fh)

    tune_cache.clear_memo()
    redo = tune.autotune("matmul_rs", signature=SIGS["matmul_rs"], mesh=mesh4)
    assert not redo.cache_hit  # invalidated -> re-tuned
    assert redo.candidate == first.candidate  # same space, same winner

    # and the re-tune heals the file back to the live fingerprint
    with open(path) as fh:
        assert json.load(fh)["fingerprint"] == first.fingerprint


def test_cache_hit_never_remeasures(mesh4, monkeypatch):
    first = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4, **MEASURE_KW)
    assert not first.cache_hit and first.ranker == "measure"

    class Boom:
        def __init__(self, *a, **k):
            raise AssertionError("cache hit must not re-measure")

    monkeypatch.setattr(tune_measure, "CaseTimer", Boom)
    tune_cache.clear_memo()  # disk hit, not memo hit
    hit = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4, **MEASURE_KW)
    assert hit.cache_hit and hit.candidate == first.candidate


def test_explicit_measure_upgrades_model_entry(mesh4, monkeypatch):
    # pre-warm flow: a model-ranked record must not satisfy an explicit
    # measured request — it is re-ranked by measurement and overwritten
    model = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4, space=TINY_SPACE)
    assert not model.cache_hit and model.ranker == "model"

    calls = FakeCaseTimer.calls
    calls.clear()
    monkeypatch.setattr(tune_measure, "CaseTimer", FakeCaseTimer)
    up = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4, **MEASURE_KW)
    assert not up.cache_hit and up.ranker == "measure" and calls

    # the measured record now satisfies BOTH rankers without re-measuring
    calls.clear()
    hit_m = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4, **MEASURE_KW)
    hit_a = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4, space=TINY_SPACE)
    assert hit_m.cache_hit and hit_a.cache_hit and not calls
    assert hit_a.ranker == "measure"  # measured result is never clobbered


def test_cache_dirs_are_isolated_in_process(mesh4, tmp_path):
    # the process memo must not leak entries across cache_dir arguments
    a = tune.autotune(
        "ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4, cache_dir=str(tmp_path / "a")
    )
    b = tune.autotune(
        "ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4, cache_dir=str(tmp_path / "b")
    )
    assert not a.cache_hit and not b.cache_hit  # distinct stores, no cross-hit
    assert os.path.isdir(tmp_path / "a") and os.path.isdir(tmp_path / "b")


def test_axis_and_world_are_part_of_entry_key():
    # one multi-axis mesh fingerprint: a winner tuned along the 4-rank axis
    # must not be reused for the 2-rank axis (different ring length)
    mesh = make_mesh((2, 4), ("data", "model"))
    a = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh, axis="model")
    b = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh, axis="data")
    assert not a.cache_hit and not b.cache_hit  # no cross-axis reuse
    assert a.fingerprint == b.fingerprint  # same file, distinct entries


def test_store_merges_external_writes(mesh4):
    # a concurrent process's entry written between our read and our write
    # must survive our store (per-entry last-writer-wins, not per-file)
    first = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4)
    digest = tune_cache.fingerprint_digest(first.fingerprint)
    path = os.path.join(tune_cache.cache_dir(), digest + ".json")
    with open(path) as fh:
        payload = json.load(fh)
    payload["entries"]["external|entry"] = {"ranker": "measure", "score": 1.0}
    with open(path, "w") as fh:
        json.dump(payload, fh)

    # our memo still holds the pre-external snapshot; a new store must merge
    tune.autotune("matmul_rs", signature=SIGS["matmul_rs"], mesh=mesh4)
    with open(path) as fh:
        entries = json.load(fh)["entries"]
    assert "external|entry" in entries  # not clobbered by the stale memo
    assert len(entries) == 3


def test_cache_v1_schema_migration_retunes(mesh4):
    # a PR-3 cache file (comm-only records: no "schema", no "comp_tile")
    # must re-tune under the v3 schema, never crash or half-apply
    first = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4)
    digest = tune_cache.fingerprint_digest(first.fingerprint)
    path = os.path.join(tune_cache.cache_dir(), digest + ".json")
    with open(path) as fh:
        payload = json.load(fh)
    for rec in payload["entries"].values():  # downgrade every record to v1
        rec.pop("schema", None)
        rec.pop("comp_tile", None)
    with open(path, "w") as fh:
        json.dump(payload, fh)

    tune_cache.clear_memo()
    redo = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4)
    assert not redo.cache_hit  # v1 record rejected -> re-tuned
    assert redo.candidate == first.candidate

    # the re-tune healed the record to the current schema
    tune_cache.clear_memo()
    healed = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4)
    assert healed.cache_hit
    with open(path) as fh:
        entries = json.load(fh)["entries"]
    assert all(rec.get("schema") == tune.CACHE_SCHEMA for rec in entries.values())
    assert all("comp_tile" in rec for rec in entries.values())


def test_cache_v2_schema_migration_retunes(mesh4):
    # a PR-4 record (schema 2: joint winner, but chosen from the smaller
    # pre-sweep space with no attention/MoE tile axes) re-tunes under v3
    first = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4)
    digest = tune_cache.fingerprint_digest(first.fingerprint)
    path = os.path.join(tune_cache.cache_dir(), digest + ".json")
    with open(path) as fh:
        payload = json.load(fh)
    for rec in payload["entries"].values():  # downgrade every record to v2
        rec["schema"] = 2
        rec.pop("sweep", None)
        rec.pop("score_iqr_us", None)
    with open(path, "w") as fh:
        json.dump(payload, fh)

    tune_cache.clear_memo()
    redo = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4)
    assert not redo.cache_hit  # v2 record rejected -> re-tuned
    assert redo.candidate == first.candidate

    tune_cache.clear_memo()
    healed = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4)
    assert healed.cache_hit
    with open(path) as fh:
        entries = json.load(fh)["entries"]
    assert all(rec.get("schema") == tune.CACHE_SCHEMA for rec in entries.values())


def test_cache_corrupt_file_and_records_retune(mesh4):
    # a junk cache file (truncated JSON) and a malformed record (hand-edited
    # entry) both degrade to a re-tune — load must never raise
    first = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4)
    digest = tune_cache.fingerprint_digest(first.fingerprint)
    path = os.path.join(tune_cache.cache_dir(), digest + ".json")

    with open(path, "w") as fh:  # truncated/binary junk: not JSON at all
        fh.write('{"fingerprint": {"mesh_sh\x00\x01garbage')
    tune_cache.clear_memo()
    redo = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4)
    assert not redo.cache_hit and redo.candidate == first.candidate

    # valid JSON, garbage records: wrong types, missing fields, junk values
    with open(path) as fh:
        payload = json.load(fh)
    (key,) = payload["entries"].keys()
    for bad in ("not-a-record", {"schema": tune.CACHE_SCHEMA}, {"schema": "x"}, 7, None):
        payload["entries"][key] = bad
        with open(path, "w") as fh:
            json.dump(payload, fh)
        tune_cache.clear_memo()
        redo = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4)
        assert not redo.cache_hit and redo.candidate == first.candidate
        with open(path) as fh:  # the re-tune healed the record
            payload = json.load(fh)

    # a record whose winner fails spec validation (junk order) also re-tunes
    payload["entries"][key] = dict(
        schema=tune.CACHE_SCHEMA,
        order="zigzag",
        num_channels=1,
        accum_dtype="float32",
        comp_tile=[128, 128, 128],
        ranker="model",
        score=1.0,
    )
    with open(path, "w") as fh:
        json.dump(payload, fh)
    tune_cache.clear_memo()
    redo = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4)
    assert not redo.cache_hit and redo.candidate == first.candidate


# ---- measured ranker: timing contract + early-exit sweep (ISSUE 5) ----------


def test_time_fn_stats_and_warmup_guard():
    calls = []

    def fn(x):
        calls.append(1)
        return x

    med, iqr = tune_measure.time_fn(fn, 1.0, repeats=5, warmup=2)
    assert len(calls) == 7  # warmup + repeats, one shared callable
    assert med >= 0.0 and iqr >= 0.0
    with pytest.raises(ValueError, match="warmup >= 1"):
        tune_measure.time_fn(fn, 1.0, warmup=0)
    with pytest.raises(ValueError, match="repeats >= 1"):
        tune_measure.time_fn(fn, 1.0, repeats=0)


def test_time_fn_aot_splits_compile_from_measurement():
    import jax.numpy as jnp

    traces = []

    @jax.jit
    def f(x):
        traces.append(1)
        return x + 1.0

    med, iqr = tune_measure.time_fn(f, jnp.ones((8,)), repeats=3, warmup=1)
    # lower().compile() traced exactly once; the compiled executable served
    # every warmup and timed call — compile time can never enter a score
    assert len(traces) == 1
    assert med > 0.0 and iqr >= 0.0


def _oracle(kind, sig, world):
    """Deterministic fake timer: analytic cost in us + stable per-point skew."""
    import hashlib

    def timer(cand, *, repeats=3, warmup=1):
        from repro.tune import cost as tune_cost

        j = int(hashlib.sha256(cand.label().encode()).hexdigest()[:4], 16) % 97
        return tune_cost.predict_cost(kind, sig, world, cand) * 1e6 * (1.0 + j / 9700.0), 0.0

    return timer


def test_measured_sweep_prunes_and_matches_exhaustive():
    sig = (1, 256, 512, 256)
    cands = tune.enumerate_candidates(
        "ag_matmul", extent=256, space=tune.JOINT_SPACE, sig=sig, world=R
    )
    timer = _oracle("ag_matmul", sig, R)
    cfg = tune_sweep.SweepConfig()
    sw = tune_sweep.measured_sweep("ag_matmul", sig, R, cands, timer, config=cfg)
    ex = tune_sweep.measured_sweep(
        "ag_matmul", sig, R, cands, timer, config=tune_sweep.SweepConfig(enabled=False)
    )
    assert sw.winner == ex.winner  # pruning never changes the winner here
    assert sw.stats["total"] == len(cands) == ex.stats["total"]
    assert sw.stats["screened"] <= len(cands) // 2  # timed <= 50% of the space
    assert sw.stats["pruned"] >= len(cands) - len(cands) // 2
    assert sw.stats["timed"] < sw.stats["screened"]  # full repeats: a handful
    assert ex.stats == {
        "total": len(cands),
        "screened": len(cands),
        "timed": len(cands),
        "pruned": 0,
        "early_exit": False,
    }


def test_measured_sweep_early_exit_on_incumbent_bound():
    sig = (1, 256, 512, 256)
    cands = tune.enumerate_candidates(
        "ag_matmul", extent=256, space=tune.JOINT_SPACE, sig=sig, world=R
    )
    timer = _oracle("ag_matmul", sig, R)
    sw = tune_sweep.measured_sweep("ag_matmul", sig, R, cands, timer)
    # deterministic oracle: iqr == 0, so after the first full timing the
    # incumbent's lower bound equals its median and beats every later screen
    assert sw.stats["early_exit"] and sw.stats["timed"] == 1


def test_measured_sweep_noise_widens_the_search():
    # the early exit must use the incumbent's UPPER bound (median + iqr): a
    # candidate whose screen sits inside the incumbent's noise band is still
    # plausibly faster and must be fully timed — exiting on the optimistic
    # lower bound (median - iqr) would prune the true winner exactly when
    # measurements are noisy
    sig = (1, 256, 512, 256)
    cands = tune.enumerate_candidates(
        "ag_matmul", extent=256, space=tune.JOINT_SPACE, sig=sig, world=R
    )
    from repro.tune import cost as tune_cost

    order = sorted(cands, key=lambda c: tune_cost.predict_cost("ag_matmul", sig, R, c))
    c0, c1 = order[0], order[1]

    def timer(cand, *, repeats=3, warmup=1):
        if repeats == 1:  # the 1-repeat screen: c0 looks best, c1 second
            return (50.0, 0.0) if cand == c0 else (70.0, 0.0) if cand == c1 else (500.0, 0.0)
        return (100.0, 40.0) if cand == c0 else (70.0, 1.0)  # full repeats

    sw = tune_sweep.measured_sweep("ag_matmul", sig, R, cands, timer)
    assert sw.stats["timed"] >= 2  # c1's 70us screen < 100 + 40: must be timed
    assert sw.winner == c1 and sw.median_us == 70.0


def test_sweep_env_knobs(monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_SWEEP", "0")
    assert not tune_sweep.sweep_config_from_env().enabled
    monkeypatch.setenv("REPRO_TUNE_SWEEP", "1")
    monkeypatch.setenv("REPRO_TUNE_SWEEP_SCREEN", "0.25")
    monkeypatch.setenv("REPRO_TUNE_SWEEP_KEEP", "0.5")
    cfg = tune_sweep.sweep_config_from_env()
    assert cfg.enabled and cfg.screen_fraction == 0.25 and cfg.keep_fraction == 0.5
    with pytest.raises(ValueError, match="fractions"):
        tune_sweep.SweepConfig(screen_fraction=0.0)


def test_measured_record_carries_sweep_stats(mesh4, monkeypatch):
    monkeypatch.setattr(tune_measure, "CaseTimer", FakeCaseTimer)
    res = tune.autotune(
        "ag_matmul",
        signature=(1, 64, 64, 64),
        mesh=mesh4,
        ranker="measure",
        space=tune.JOINT_SPACE,
    )
    assert res.ranker == "measure" and res.sweep is not None
    assert res.sweep["total"] == res.considered
    assert res.sweep["pruned"] >= 1  # the joint space is big enough to prune

    # the pruning ledger is part of the v3 record and survives the round-trip
    tune_cache.clear_memo()
    hit = tune.autotune(
        "ag_matmul",
        signature=(1, 64, 64, 64),
        mesh=mesh4,
        ranker="measure",
        space=tune.JOINT_SPACE,
    )
    assert hit.cache_hit and hit.sweep == res.sweep and hit.score_iqr == res.score_iqr


# ---- tiled attention/MoE consumers: parity on both backends (ISSUE 5) --------


def _attention_case(mesh4):
    b, h, hkv, s_loc, d = 1, 2, 1, 16, 8
    q = jax.random.normal(KEY, (b, h, R * s_loc, d))
    kv = jax.random.normal(jax.random.PRNGKey(3), (b, hkv, R * s_loc, d))
    spec = P(None, None, "model", None)

    def build(ch, comp=None):
        fn = compile_overlap("ag_attention", ch, comp=comp, causal=True)
        return jax.jit(shard_map(fn, mesh4, in_specs=(spec,) * 3, out_specs=spec))

    return build, (q, kv, kv)


def test_tiled_attention_parity_xla(mesh4):
    build, args = _attention_case(mesh4)
    base = BlockChannel(axis="model", num_channels=2)
    ref = np.asarray(build(base)(*args), np.float32)
    # an explicit (tm, ., tk) blocks (block_q, block_kv); tk=6 clamps to 4
    tiled = np.asarray(build(base, comp=(8, 128, 6))(*args), np.float32)
    np.testing.assert_allclose(tiled, ref, atol=2e-5, rtol=2e-5)

    # tuner-resolved joint winner: ag_attention is an AG flow, so the f32
    # tie-break must hold (the cost model's compute term is accum-dtype-free)
    res = tune.autotune(
        "ag_attention", signature=(1, 2, 1, 16, 8), mesh=mesh4, space=tune.JOINT_SPACE
    )
    assert res.candidate.accum_dtype == "float32"
    got = np.asarray(build(res.channel)(*args), np.float32)
    np.testing.assert_allclose(got, ref, atol=2e-4, rtol=2e-3)


def test_tiled_moe_parity_xla(mesh4):
    m_loc, dm, top_k, e_loc, f = 16, 8, 2, 2, 8
    e = e_loc * R
    x = jax.random.normal(KEY, (R * m_loc, dm)) * 0.5
    wgu = jax.random.normal(jax.random.PRNGKey(5), (e, dm, 2 * f)) * 0.1
    wdn = jax.random.normal(jax.random.PRNGKey(6), (e, f, dm)) * 0.1
    wr = jax.random.normal(jax.random.PRNGKey(4), (dm, e))
    specs = dict(
        in_specs=(P("model", None), P("model", None, None), P("model", None, None)),
        out_specs=P("model", None),
    )

    def build(ch, comp=None):
        g = compile_overlap("ag_moe", ch, comp=comp, capacity_factor=8.0)

        def f_(xs, wgu_, wdn_):
            ids, wts, _ = moe_router(xs, wr, num_experts=e, top_k=top_k)
            return g(xs, ids, wts, wgu_, wdn_)

        return jax.jit(shard_map(f_, mesh4, **specs))

    base = BlockChannel(axis="model")
    ref = np.asarray(build(base)(x, wgu, wdn), np.float32)
    tiled = np.asarray(build(base, comp=(8, 8, 4))(x, wgu, wdn), np.float32)
    np.testing.assert_allclose(tiled, ref, atol=2e-5, rtol=2e-5)

    # tuner-resolved joint winner (ag_rs flow: the tuner may pick a bf16
    # flow dtype — the bf16 tolerance rule applies then)
    res = tune.autotune(
        "ag_moe", signature=(16, 8, 2, 2, 8), mesh=mesh4, space=tune.JOINT_SPACE
    )
    got = np.asarray(build(res.channel)(x, wgu, wdn), np.float32)
    if res.candidate.accum_dtype == "float32":
        tol = dict(atol=2e-4, rtol=2e-3)
    else:
        tol = dict(atol=8e-2, rtol=3e-2)
    np.testing.assert_allclose(got, ref, **tol)


@pytest.mark.parametrize("n_kv", [1, 2, 4, 8])
def test_apply_seq_ring_matches_apply_seq(mesh4, n_kv):
    # n_kv sweeps the GQALayout regimes on tp=4: MQA (kv_pad=1, the original
    # shared-head ring), kv < tp (kv_pad=2, rep=2: ranks share a group),
    # kv == tp (one distinct group per rank) and kv > tp (kv_loc=2 groups
    # per rank) — the per-KV-group ring must match apply_seq on all of them
    from repro.configs.base import ArchConfig
    from repro.nn import attention as nn_attention
    from repro.parallel.context import ParallelContext

    cfg = ArchConfig(
        name="tiny",
        family="dense",
        n_layers=1,
        d_model=32,
        n_heads=8,
        n_kv_heads=n_kv,
        d_ff=64,
        vocab_size=64,
    )
    pc = ParallelContext(mesh=mesh4, axis="model", dp_axes=())
    params = nn_attention.init(KEY, cfg, pc.tp, dtype=jax.numpy.float32)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, R * 16, 32)) * 0.5
    full = nn_attention.specs(cfg, pc.tp, pc.dp_spec())
    sp = {k: pc.manual(v) for k, v in full.items()}

    def run(fn):
        sm = pc.smap(
            lambda p, xs: fn(p, xs, pc, cfg), (sp, P(None, "model", None)), P(None, "model", None)
        )
        return np.asarray(jax.jit(sm)(params, x), np.float32)

    ring = run(nn_attention.apply_seq_ring)
    seq = run(nn_attention.apply_seq)
    np.testing.assert_allclose(ring, seq, atol=2e-4, rtol=2e-3)


def test_auto_keeps_unsupported_backend_loud():
    # PR-2 contract: unsupported (kind, backend) raises at BUILD time — no
    # resolution mode may defer it into the first trace
    with pytest.raises(NotImplementedError, match="copy engine"):
        compile_overlap("ag_attention", "auto", backend="pallas")
    with pytest.raises(NotImplementedError, match="copy engine"):
        compile_overlap("ag_attention", BlockChannel(axis="model"), comp="auto", backend="pallas")


def test_space_is_part_of_entry_key(mesh4):
    narrow = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4, space=TINY_SPACE)
    full = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4)
    assert not full.cache_hit  # narrowed sweep must not shadow the full one
    assert narrow.considered == 1 and full.considered == 18


def test_base_channel_fields_inherited(mesh4):
    pull = dataclasses.replace(BlockChannel(axis="model").comm, mode="pull")
    base = BlockChannel(axis="model", comm=pull)
    res = tune.autotune("ag_matmul", signature=SIGS["ag_matmul"], mesh=mesh4, base=base)
    assert res.channel.comm.mode == "pull"  # non-tuned field survives
    assert res.channel.comm.order == res.candidate.order


# ---- channel="auto" end-to-end ----------------------------------------------


def _auto_and_explicit(kind, mesh4):
    """(auto_fn, explicit_fn, baseline_fn, args): same specs, three lowerings."""
    key = KEY
    resolved = tune.resolve_channel(kind, sig=SIGS[kind], mesh=mesh4)

    def sm(fn, in_specs, out_specs):
        return jax.jit(shard_map(fn, mesh4, in_specs=in_specs, out_specs=out_specs))

    if kind == "ag_matmul":
        _, m_loc, k, n = SIGS[kind]
        args = (
            jax.random.normal(key, (R * m_loc, k)),
            jax.random.normal(jax.random.PRNGKey(1), (k, n)),
        )
        specs = ((P("model", None), P(None, None)), P(None, None))

        def build(ch, ov=True):
            return sm(compile_overlap(kind, ch, overlapped=ov), *specs)
    elif kind == "matmul_rs":
        _, m, k_loc, n = SIGS[kind]
        args = (
            jax.random.normal(key, (m, R * k_loc)),
            jax.random.normal(jax.random.PRNGKey(2), (R * k_loc, n)),
        )
        specs = ((P(None, "model"), P("model", None)), P("model", None))

        def build(ch, ov=True):
            return sm(compile_overlap(kind, ch, overlapped=ov), *specs)
    elif kind == "ag_attention":
        b, h, hkv, s_loc, d = SIGS[kind]
        q = jax.random.normal(key, (b, h, R * s_loc, d))
        kv = jax.random.normal(jax.random.PRNGKey(3), (b, hkv, R * s_loc, d))
        args = (q, kv, kv)
        specs = ((P(None, None, "model"),) * 3, P(None, None, "model"))

        def build(ch, ov=True):
            return sm(compile_overlap(kind, ch, overlapped=ov, causal=True), *specs)
    else:  # ag_moe
        m_loc, dm, top_k, e_loc, f = SIGS[kind]
        e = e_loc * R
        args = (
            jax.random.normal(key, (R * m_loc, dm)) * 0.5,
            jax.random.normal(jax.random.PRNGKey(5), (e, dm, 2 * f)) * 0.1,
            jax.random.normal(jax.random.PRNGKey(6), (e, f, dm)) * 0.1,
        )
        wr = jax.random.normal(jax.random.PRNGKey(4), (dm, e))
        specs = (
            (P("model", None), P("model", None, None), P("model", None, None)),
            P("model", None),
        )

        def build(ch, ov=True):
            g = compile_overlap(kind, ch, overlapped=ov, capacity_factor=8.0)

            def f_(xs, wgu, wdn):
                ids, wts, _ = moe_router(xs, wr, num_experts=e, top_k=top_k)
                return g(xs, ids, wts, wgu, wdn)

            return sm(f_, *specs)

    return build("auto"), build(resolved), build(resolved, False), args, resolved


@pytest.mark.parametrize("kind", tune.TUNABLE_KINDS)
def test_channel_auto_parity(kind, mesh4):
    auto_fn, explicit_fn, baseline_fn, args, resolved = _auto_and_explicit(kind, mesh4)
    got = np.asarray(auto_fn(*args), np.float32)
    want = np.asarray(explicit_fn(*args), np.float32)
    # auto resolves to exactly the explicit channel's lowering: bit-identical
    np.testing.assert_array_equal(got, want)
    # ... and correct vs the non-overlapping baseline, at the tolerance of
    # the flow dtype the tuner picked (bf16 partials are genuinely lossy)
    base = np.asarray(baseline_fn(*args), np.float32)
    if resolved.comp.accum_dtype == "float32":
        tol = dict(atol=2e-4, rtol=2e-3)
    else:
        tol = dict(atol=8e-2, rtol=3e-2)
    np.testing.assert_allclose(got, base, **tol)


def test_comp_auto_parity_xla(mesh4):
    # comp="auto" (joint search) must match the default-tile lowering; the
    # shape is big enough that the winner's tile genuinely differs
    m_loc, k, n = 256, 512, 256
    x = jax.random.normal(KEY, (R * m_loc, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    specs = dict(in_specs=(P("model", None), P(None, None)), out_specs=P(None, None))

    joint = jax.jit(shard_map(compile_overlap("ag_matmul", "auto", comp="auto"), mesh4, **specs))
    got = np.asarray(joint(x, w), np.float32)  # resolves the joint winner

    res = tune.autotune("ag_matmul", signature=(1, m_loc, k, n), world=R, space=tune.JOINT_SPACE)
    assert res.cache_hit and res.candidate.comp_tile != DEFAULT_TILE  # joint hit

    default = res.channel.with_(comp=dataclasses.replace(res.channel.comp, tile=DEFAULT_TILE))
    ref_fn = jax.jit(shard_map(compile_overlap("ag_matmul", default), mesh4, **specs))
    want = np.asarray(ref_fn(x, w), np.float32)
    if res.candidate.accum_dtype == "float32":
        tol = dict(atol=2e-4, rtol=2e-3)
    else:
        tol = dict(atol=8e-2, rtol=3e-2)
    np.testing.assert_allclose(got, want, **tol)


def test_comp_explicit_tile_parity_pallas(mesh4):
    # the fused Pallas kernels must honor a non-default (tm, tn, tk); parity
    # vs the default-tile kernel on both fused kinds
    m_loc, k, n = 16, 32, 32
    x = jax.random.normal(KEY, (R * m_loc, k))
    w = jax.random.normal(jax.random.PRNGKey(2), (k, n))
    specs = dict(in_specs=(P("model", None), P(None, None)), out_specs=P(None, None))

    def pallas_fn(comp):
        ch = BlockChannel(axis="model")
        fn = compile_overlap("ag_matmul", ch, comp=comp, backend="pallas", world_size=R)
        return jax.jit(shard_map(fn, mesh4, **specs))

    tiled = np.asarray(pallas_fn((8, 16, 16))(x, w), np.float32)
    ref = np.asarray(pallas_fn(None)(x, w), np.float32)
    np.testing.assert_allclose(tiled, ref, atol=2e-4, rtol=2e-3)

    xr = jax.random.normal(KEY, (R * 16, R * 8))
    wr = jax.random.normal(jax.random.PRNGKey(3), (R * 8, 32))
    rs_specs = dict(in_specs=(P(None, "model"), P("model", None)), out_specs=P("model", None))

    def rs_fn(comp):
        ch = BlockChannel(axis="model")
        fn = compile_overlap("matmul_rs", ch, comp=comp, backend="pallas", world_size=R)
        return jax.jit(shard_map(fn, mesh4, **rs_specs))

    tiled_rs = np.asarray(rs_fn((8, 16, 4))(xr, wr), np.float32)
    ref_rs = np.asarray(rs_fn(None)(xr, wr), np.float32)
    np.testing.assert_allclose(tiled_rs, ref_rs, atol=2e-4, rtol=2e-3)


def test_comp_auto_parity_pallas(mesh4):
    # joint resolution through the fused backend: the tuned winner (whatever
    # tile it picks) must stay parity-equal to the plain local matmul
    m_loc, k, n = 16, 32, 32
    x = jax.random.normal(KEY, (R * m_loc, k))
    w = jax.random.normal(jax.random.PRNGKey(2), (k, n))
    fn = compile_overlap(
        "ag_matmul", "auto", comp="auto", backend="pallas", mesh=mesh4, world_size=R
    )
    specs = dict(in_specs=(P("model", None), P(None, None)), out_specs=P(None, None))
    sm = jax.jit(shard_map(fn, mesh4, **specs))
    np.testing.assert_allclose(np.asarray(sm(x, w)), np.asarray(x @ w), atol=2e-4, rtol=2e-3)


def test_comp_rejects_bad_values():
    with pytest.raises(ValueError, match="comp must be"):
        compile_overlap("ag_matmul", BlockChannel(axis="model"), comp="fastest")
    with pytest.raises(ValueError, match="comp must be"):
        compile_overlap("ag_matmul", "auto", comp=(128, 128))
    # explicit CompSpec replaces the whole compute half (tile AND flow dtype)
    fn = compile_overlap("ag_matmul", BlockChannel(axis="model"), comp=CompSpec(tile=(64, 64, 64)))
    assert fn.keywords["channel"].comp.tile == (64, 64, 64)
    assert fn.keywords["channel"].comp.accum_dtype == "float32"
    # a bare tuple pins the TILE only — the channel's flow dtype survives
    bf16 = BlockChannel(axis="model", comp=CompSpec(accum_dtype="bfloat16"))
    fn2 = compile_overlap("matmul_rs", bf16, comp=(64, 64, 64))
    assert fn2.keywords["channel"].comp.tile == (64, 64, 64)
    assert fn2.keywords["channel"].comp.accum_dtype == "bfloat16"


def test_auto_channel_with_pinned_comp_honors_tile(mesh4):
    # channel="auto" + explicit comp: the comm half is searched, the tile is
    # pinned — the resolved lowering must actually carry the (clamped)
    # explicit tile, not the backend-chosen sentinel
    m_loc, k, n = 16, 32, 32
    x = jax.random.normal(KEY, (R * m_loc, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    specs = dict(in_specs=(P("model", None), P(None, None)), out_specs=P(None, None))
    fn = jax.jit(shard_map(compile_overlap("ag_matmul", "auto", comp=(8, 16, 16)), mesh4, **specs))
    np.testing.assert_allclose(np.asarray(fn(x, w)), np.asarray(x @ w), atol=2e-4, rtol=2e-3)
    res = tune.autotune(
        "ag_matmul",
        signature=(1, m_loc, k, n),
        world=R,
        space=tune.Space(comp_tiles=((8, 16, 16),)),  # tile pinned, rest swept
    )
    assert res.cache_hit  # the traced call resolved exactly this pinned space
    assert res.candidate.comp_tile == (8, 16, 16)
    assert res.channel.comp.tile == (8, 16, 16)


def test_auto_resolves_without_mesh_inside_shard_map(mesh4):
    # no mesh kwarg: world comes from axis_size inside the manual region and
    # the fingerprint narrows to the collective axis
    _, m_loc, k, n = SIGS["ag_matmul"]
    x = jax.random.normal(KEY, (R * m_loc, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    sm = shard_map(
        compile_overlap("ag_matmul", "auto"),
        mesh4,
        in_specs=(P("model", None), P(None, None)),
        out_specs=P(None, None),
    )
    fn = jax.jit(sm)
    np.testing.assert_allclose(np.asarray(fn(x, w)), np.asarray(x @ w), atol=2e-4, rtol=2e-3)


def test_parallel_context_tune_resolves(mesh4):
    from repro.parallel.context import ParallelContext

    pc = dataclasses.replace(ParallelContext(mesh=mesh4, axis="model", dp_axes=()), tune=True)
    _, m_loc, k, n = SIGS["ag_matmul"]
    x = jax.random.normal(KEY, (R * m_loc, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    sm = pc.smap(lambda a, b: pc.ag_matmul(a, b), (P("model", None), P(None, None)), P(None, None))
    fn = jax.jit(sm)
    np.testing.assert_allclose(np.asarray(fn(x, w)), np.asarray(x @ w), atol=2e-4, rtol=2e-3)
    # the resolution landed in the persistent cache
    assert os.path.isdir(tune_cache.cache_dir())
    assert len(os.listdir(tune_cache.cache_dir())) == 1


def test_bad_inputs():
    with pytest.raises(ValueError, match="not tunable"):
        tune.enumerate_candidates("nope")
    with pytest.raises(ValueError, match="mesh or an explicit world"):
        tune.autotune("ag_matmul", signature=(1, 8, 8, 8))
    with pytest.raises(ValueError, match="BlockChannel or 'auto'"):
        compile_overlap("ag_matmul", "fastest")
    with pytest.raises(ValueError, match="unknown ranker"):
        tune.autotune("ag_matmul", signature=(1, 8, 8, 8), world=4, ranker="vibes")


# ---------------------------------------------------------------------------
# seam-aware resolution (fused RS -> AG, PR 7)

SEAM_SIG = (1, R * 16, 16, 32, 8)  # (lead, m_glob, k_loc, n_mid, n2_loc)


def test_seq_candidates_share_one_effective_channel_count():
    cands = tune.enumerate_seq_candidates(sig=SEAM_SIG, world=R)
    assert cands
    m_loc = SEAM_SIG[1] // R
    for c in cands:
        # the seam handoff is per-channel: both halves' chunked extents must
        # clamp to the candidate's count, or the pair degrades to unfused
        assert effective_channels(SEAM_SIG[3], c.num_channels) == c.num_channels
        assert effective_channels(m_loc, c.num_channels) == c.num_channels


def test_predict_seq_cost_credits_strictly_positive_saving():
    from repro.tune import cost as tune_cost

    for cand in tune.enumerate_seq_candidates(sig=SEAM_SIG, world=R):
        saving = tune_cost.seam_saving(SEAM_SIG, R, cand)
        assert saving > 0.0
        fused = tune_cost.predict_seq_cost(SEAM_SIG, R, cand, fused=True)
        unfused = tune_cost.predict_seq_cost(SEAM_SIG, R, cand, fused=False)
        assert fused == pytest.approx(unfused - saving)


def test_resolve_seq_verdicts_fused_with_shared_channels():
    fused, ch_rs, ch_ag = tune.resolve_seq(sig=SEAM_SIG, world=R)
    assert fused
    assert ch_rs.num_channels == ch_ag.num_channels
    assert ch_rs.comm.order == ch_ag.comm.order
