"""Training integration: loss decreases, masks hold, kv-grad sync, modes agree."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models import lm
from repro.nn.layers import gqa_layout, sync_kv_grad
from repro.parallel.context import ParallelContext
from repro.parallel.sharding import place
from repro.training import AdamWConfig, init_opt_state, make_train_step
from utils import reduce_config


def test_loss_decreases_on_synthetic_bigrams(pc8, mesh8):
    cfg = reduce_config(get_config("smollm-360m"))
    cfg = dataclasses.replace(cfg, n_layers=2, vocab_size=256)
    params = place(lm.init(jax.random.PRNGKey(0), cfg, pc8, jnp.float32),
                   mesh8, lm.specs(cfg, pc8))
    opt = init_opt_state(params)
    step = make_train_step(lm, cfg, pc8,
                           AdamWConfig(lr=3e-3, total_steps=40, warmup_steps=5),
                           grad_masks=lm.grad_masks(cfg, pc8), donate=False)
    pipe = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    losses = []
    for _ in range(30):
        params, opt, m = step(params, opt, pipe.host_batch())
        losses.append(float(m["ce"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.2, (first, last)


def test_grad_masks_keep_padded_heads_zero(pc8, mesh8):
    """smollm's 15q/5kv padding: padded weights must stay exactly zero."""
    cfg = reduce_config(get_config("smollm-360m"))
    cfg = dataclasses.replace(cfg, n_layers=1, n_heads=3, n_kv_heads=1,
                              vocab_size=128)  # 3 heads on tp=4 -> pad to 4
    params = place(lm.init(jax.random.PRNGKey(0), cfg, pc8, jnp.float32),
                   mesh8, lm.specs(cfg, pc8))
    masks = lm.grad_masks(cfg, pc8)
    assert masks is not None
    opt = init_opt_state(params)
    step = make_train_step(lm, cfg, pc8, AdamWConfig(lr=1e-2, total_steps=10),
                           grad_masks=masks, donate=False)
    pipe = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    for _ in range(3):
        params, opt, _ = step(params, opt, pipe.host_batch())

    lay = gqa_layout(cfg.n_heads, cfg.n_kv_heads, pc8.tp)
    wq = np.asarray(params["scan"][0]["mixer"]["wq"])  # [L, D, h_pad*hd]
    pad_cols = wq.reshape(wq.shape[0], wq.shape[1], lay.h_pad, cfg.hd)[
        :, :, cfg.n_heads:]
    assert np.abs(pad_cols).max() == 0.0


def test_sync_kv_grad_averages_replicas():
    lay = gqa_layout(8, 2, 4)  # kv=2 < tp=4 -> rep=2, kv_store=4
    g = jnp.arange(3 * lay.kv_store * 5, dtype=jnp.float32).reshape(3, -1)
    g2 = sync_kv_grad(g, lay, axis=-1)
    gr = np.asarray(g2).reshape(3, lay.kv_pad, lay.rep, 5)
    # replicas identical after sync
    np.testing.assert_allclose(gr[:, :, 0], gr[:, :, 1])
    # and equal to the mean of the originals
    go = np.asarray(g).reshape(3, lay.kv_pad, lay.rep, 5)
    np.testing.assert_allclose(gr[:, :, 0], go.mean(axis=2))


def test_overlap_and_baseline_modes_agree(mesh8):
    """Same params + data => numerically matching losses in both modes."""
    cfg = reduce_config(get_config("qwen2-72b"))
    cfg = dataclasses.replace(cfg, n_layers=2, vocab_size=128)
    pco = ParallelContext(mesh=mesh8, mode="overlap")
    pcb = ParallelContext(mesh=mesh8, mode="baseline")
    params = place(lm.init(jax.random.PRNGKey(0), cfg, pco, jnp.float32),
                   mesh8, lm.specs(cfg, pco))
    pipe = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    batch = pipe.host_batch()

    from repro.training.steps import softmax_xent

    def loss(pc):
        logits, _ = lm.forward(params, cfg, pc, batch["inputs"])
        return softmax_xent(logits, batch["labels"])

    lo = float(jax.jit(lambda: loss(pco))())
    lb = float(jax.jit(lambda: loss(pcb))())
    assert abs(lo - lb) < 1e-4, (lo, lb)
