"""Static-verifier tests: acceptance, seeded-mutation detection, lint, wiring.

The acceptance property mirrors the dynamic parity sweeps (tests/test_plan.py
runs every order x world x channels against jnp references on a live mesh):
the verifier must accept exactly that space — and flag every seeded schedule
bug the mutation suite plants in the baked tables / instruction streams.
"""
import dataclasses

import pytest

from repro import analysis
from repro.analysis import lint as repro_lint
from repro.analysis import verify as verify_cli
from repro.analysis.errors import PlanVerificationError
from repro.analysis.ir import PlanTables
from repro.analysis.protocol import DmaStart, Wait, build_streams, check_streams
from repro.analysis.schedule import check_channel_partition, check_schedule
from repro.core.channels import BlockChannel, CommSpec, ORDERS
from repro.core.plan import FLOW_OF_KIND, ChannelSchedule, build_plan
from repro.tune.candidates import enumerate_candidates

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs requirements-dev.txt
    HAS_HYPOTHESIS = False


def _tables(kind="ag_matmul", order="ring", world=4, nch=2) -> PlanTables:
    ch = BlockChannel(axis="model", comm=CommSpec(order=order), num_channels=nch)
    return PlanTables.from_plan(build_plan(kind, ch, world, nch))


# ---- acceptance: the verifier accepts what the parity sweep accepts ---------


@pytest.mark.parametrize("kind", sorted(FLOW_OF_KIND))
@pytest.mark.parametrize("order", ORDERS)
def test_shipped_space_accepted(kind, order):
    for world in (2, 3, 4, 8):
        for nch in (1, 2, 3):
            ch = BlockChannel(axis="model", comm=CommSpec(order=order), num_channels=nch)
            report = analysis.verify_plan(build_plan(kind, ch, world, nch), protocol=True)
            assert report.passes == ("schedule", "protocol")
            assert report.effective_channels == nch
            assert report.checks > 0 and report.events > 0


def test_verify_cli_all_passes(capsys):
    assert verify_cli.main(["--all", "--quiet"]) == 0
    assert "0 failure(s)" in capsys.readouterr().out


def test_channel_partition():
    assert check_channel_partition(8, 2) > 0
    with pytest.raises(PlanVerificationError) as e:
        check_channel_partition(6, 4)
    assert e.value.check == "channel_partition"


# ---- seeded mutation suite: every planted bug must be flagged ---------------


def _expect(check_names, fn, *args):
    with pytest.raises(PlanVerificationError) as e:
        fn(*args)
    assert e.value.check in check_names, e.value
    return e.value


def test_mutation_off_by_one_step():
    t = _tables()
    rotated = tuple(ch[1:] + ch[:1] for ch in t.src)  # every step shifted by one
    _expect({"seed_identity", "per_step_permutation"}, check_schedule,
            dataclasses.replace(t, src=rotated))


def test_mutation_swapped_perm_pair():
    t = _tables()
    row = list(t.flow_dst[0][1])
    row[0], row[1] = row[1], row[0]
    bad = [[list(r) for r in ch] for ch in t.flow_dst]
    bad[0][1] = row
    t2 = dataclasses.replace(t, flow_dst=tuple(tuple(tuple(r) for r in ch) for ch in bad))
    _expect({"flow_composition"}, check_schedule, t2)


def test_mutation_nonpermutation_src_row():
    t = _tables()
    dup = t.src[0][1][1]  # duplicate a neighbor's entry within one step row
    _expect({"per_step_permutation"}, check_schedule, t.poke("src", 0, 1, 0, dup))


def test_mutation_rs_segment_poked():
    t = _tables(kind="matmul_rs")
    wrong = (t.rs_seg[0][1][0] + 1) % t.world
    _expect({"rs_time_reversal", "rs_home"}, check_schedule, t.poke("rs_seg", 0, 1, 0, wrong))


def test_mutation_align_poked():
    t = _tables(kind="ag_moe")
    wrong = (t.align[0][0] + 1) % t.world
    _expect({"align_home"}, check_schedule, t.poke_align(0, 0, wrong))


def test_mutation_dropped_signal_deadlocks():
    t = _tables()
    streams = build_streams(t)
    streams[0] = [op for op in streams[0] if not isinstance(op, DmaStart)][:]
    # rank 0 never pushes: its consumers starve (counts catch it first)
    _expect({"sem_count", "deadlock"}, check_streams, streams, t)


def test_mutation_wait_after_read_races():
    t = _tables()
    streams = build_streams(t)
    ops = streams[0]
    idx = next(i for i, op in enumerate(ops) if isinstance(op, Wait) and op.sem[0] == "recv")
    # acquire moved past the gathered-tile loads it guards
    streams[0] = ops[:idx] + ops[idx + 1 :] + [ops[idx]]
    _expect({"read_before_signal"}, check_streams, streams, t)


def test_mutation_reused_recv_slot():
    t = _tables()
    streams = build_streams(t)
    ops = streams[0]
    idx = next(i for i, op in enumerate(ops) if isinstance(op, DmaStart))
    other = (ops[idx].dst[1] + 1) % (t.world * t.num_channels)
    streams[0] = (
        ops[:idx]
        + [dataclasses.replace(ops[idx], dst=("gather", other))]
        + ops[idx + 1 :]
    )
    _expect(
        {"double_write", "read_before_signal", "overwritten_before_wait"},
        check_streams, streams, t,
    )


def test_mutation_held_pushes_deadlock():
    t = _tables(order="ring", world=4, nch=1)
    streams = build_streams(t)
    for r, ops in streams.items():
        di = next(i for i, op in enumerate(ops) if isinstance(op, DmaStart))
        wi = next(
            i for i, op in enumerate(ops) if isinstance(op, Wait) and op.sem[0] == "recv"
        )
        dma = ops[di]
        # every rank holds its step-0 push until after its step-0 acquire:
        # a signal/wait cycle around the ring — counts still match
        streams[r] = ops[:di] + ops[di + 1 : wi + 1] + [dma] + ops[wi + 1 :]
    err = _expect({"deadlock"}, check_streams, streams, t)
    assert err.rank is not None


# ---- the documented latent bug: shared send semaphore across channels -------


def test_shared_rs_send_sem_war_race():
    """Pre-fix gemm_rs shared one send semaphore across channels: the
    wait_send credits are interchangeable, so channel c's stage-s push may
    still be reading its accumulator columns when stage s+1 overwrites them.
    Safe at C == 1; a WAR race at C >= 2 (why kernels/gemm_rs.py now uses
    per-channel send semaphores)."""
    for order in ORDERS:
        safe = _tables(kind="matmul_rs", order=order, world=4, nch=1)
        check_streams(build_streams(safe, shared_rs_send_sem=True), safe)  # C=1 ok
        t = _tables(kind="matmul_rs", order=order, world=4, nch=2)
        check_streams(build_streams(t), t)  # per-channel sems: race-free
        err = _expect(
            {"overwritten_before_wait"},
            check_streams, build_streams(t, shared_rs_send_sem=True), t,
        )
        assert err.check == "overwritten_before_wait"


# ---- structured errors + executor/tuner wiring ------------------------------


def test_error_carries_coordinates():
    t = _tables(order="bidir_ring", world=4, nch=2)
    err = _expect({"per_step_permutation"}, check_schedule,
                  t.poke("src", 1, 2, 3, t.src[1][2][0]))
    assert isinstance(err, ValueError)
    assert (err.kind, err.order, err.world) == ("ag_matmul", "bidir_ring", 4)
    assert err.channel == 1 and err.step == 2 and err.rank is not None
    assert "per_step_permutation" in str(err)


def test_flow_perm_raises_structured_error():
    class Broken(ChannelSchedule):
        def source(self, rank, step):
            return 0 if step else rank  # constant after step 0: not a perm

    with pytest.raises(PlanVerificationError) as e:
        Broken(order="ring", world=4).flow_perm(0)
    assert e.value.check == "per_step_permutation"
    assert e.value.world == 4 and e.value.step == 1


def test_build_plan_verifies_unless_opted_out(monkeypatch):
    calls = []

    def boom(plan, **kw):
        calls.append(plan)
        raise PlanVerificationError("planted", check="planted")

    monkeypatch.setattr(analysis, "verify_plan", boom)
    build_plan.cache_clear()
    try:
        ch = BlockChannel(axis="model")
        monkeypatch.setenv("REPRO_VERIFY", "0")
        assert build_plan("ag_matmul", ch, 4, 1).world == 4  # escape hatch
        assert not calls
        build_plan.cache_clear()
        monkeypatch.setenv("REPRO_VERIFY", "1")
        with pytest.raises(PlanVerificationError):
            build_plan("ag_matmul", ch, 4, 1)
        assert calls
    finally:
        build_plan.cache_clear()


def test_build_plan_cache_is_bounded():
    assert build_plan.cache_info().maxsize is not None


def test_candidate_filter_keeps_legal_space():
    with_world = enumerate_candidates("ag_matmul", extent=8, world=4)
    without = enumerate_candidates("ag_matmul", extent=8)
    assert with_world == without  # the shipped space is fully legal
    assert analysis.check_candidate("ag_matmul", "ring", 4, 2) is None


def test_report_records_effective_channels():
    ch = BlockChannel(axis="model", num_channels=4)
    plan = build_plan("ag_matmul", ch, 4, 3)  # extent 6 clamps 4 -> 3
    report = analysis.verify_plan(plan, requested_channels=4)
    assert report.effective_channels == plan.num_channels == 3
    assert report.requested_channels == 4 and report.clamped
    assert "requested 4" in report.summary()


# ---- lint pass --------------------------------------------------------------


def test_lint_repo_is_clean():
    assert repro_lint.lint_tree() == []


def test_lint_flags_ppermute_outside_overlap():
    bad = repro_lint.lint_source("y = lax.ppermute(x, 'i', perm)\n", "nn/layers.py")
    assert [v.rule for v in bad] == ["ppermute-site"]
    ok = repro_lint.lint_source("y = lax.ppermute(x, 'i', perm)\n", "core/overlap.py")
    assert ok == []


def test_lint_flags_semaphores_outside_kernels():
    bad = repro_lint.lint_source("backend.semaphore_wait(s, 1)\n", "core/overlap.py")
    assert [v.rule for v in bad] == ["semaphore-site"]
    assert repro_lint.lint_source("backend.dma_semaphore()\n", "kernels/new.py") == []
    assert repro_lint.lint_source("pltpu.semaphore_signal(s)\n", "backend/lowering.py") == []


def test_lint_flags_raw_pallas_call():
    bad = repro_lint.lint_source("pl.pallas_call(k, grid=(1,))\n", "kernels/new.py")
    assert [v.rule for v in bad] == ["raw-pallas-call"]
    assert repro_lint.lint_source("backend.pallas_call(k)\n", "kernels/new.py") == []
    assert repro_lint.lint_source("pl.pallas_call(k)\n", "backend/target.py") == []


# ---- hypothesis properties (CI; local runs skip without the package) --------

if HAS_HYPOTHESIS:
    SET = settings(max_examples=60, deadline=None)

    plan_points = st.tuples(
        st.sampled_from(sorted(FLOW_OF_KIND)),
        st.sampled_from(ORDERS),
        st.integers(2, 9),
        st.integers(1, 4),
    )

    @SET
    @given(point=plan_points)
    def test_property_space_accepted(point):
        kind, order, world, nch = point
        ch = BlockChannel(axis="model", comm=CommSpec(order=order), num_channels=nch)
        report = analysis.verify_plan(build_plan(kind, ch, world, nch), protocol=True)
        assert report.checks > 0

    @SET
    @given(
        point=plan_points,
        coord=st.tuples(st.integers(0, 99), st.integers(0, 99), st.integers(0, 99)),
        delta=st.integers(1, 8),
    )
    def test_property_single_entry_mutations_rejected(point, coord, delta):
        kind, order, world, nch = point
        ch = BlockChannel(axis="model", comm=CommSpec(order=order), num_channels=nch)
        t = PlanTables.from_plan(build_plan(kind, ch, world, nch))
        c, s, r = coord[0] % nch, coord[1] % world, coord[2] % world
        old = t.src[c][s][r]
        mutated = t.poke("src", c, s, r, (old + delta % (world - 1) + 1) % world)
        with pytest.raises(PlanVerificationError):
            check_schedule(mutated)
