"""Shared test helpers."""

import jax
import numpy as np

from repro.launch.train import reduce_config  # re-export

__all__ = ["reduce_config", "allclose", "tree_finite"]


def allclose(a, b, atol=2e-4, rtol=2e-3):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol)


def tree_finite(tree) -> bool:
    return all(np.isfinite(np.asarray(leaf)).all()
               for leaf in jax.tree_util.tree_leaves(tree))
