"""TileLink overlap ops == operator-centric baselines == dense references."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map, make_mesh
from repro.core import overlap, BlockChannel, CommSpec
from repro.core.moe_overlap import ag_moe, ag_moe_baseline, moe_router
from utils import allclose

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((8,), ("model",))


@pytest.mark.parametrize("channels,order", [(1, "ring"), (2, "ring"),
                                            (2, "bidir_ring"), (4, "ring")])
@pytest.mark.parametrize("batched", [False, True])
def test_ag_matmul(mesh, channels, order, batched):
    ch = BlockChannel(axis="model", num_channels=channels,
                      comm=CommSpec(order=order))
    m, k, n = 8 * 32, 64, 48
    shape = (2, m, k) if batched else (m, k)
    x = jax.random.normal(KEY, shape, jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    xs = P(None, "model", None) if batched else P("model", None)
    fn = shard_map(lambda a, b: overlap.ag_matmul(a, b, axis="model", channel=ch),
                   mesh, in_specs=(xs, P(None, None)),
                   out_specs=P(None, None, None) if batched else P(None, None))
    allclose(jax.jit(fn)(x, w), x @ w, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("batched", [False, True])
def test_matmul_rs(mesh, batched):
    m, k, n = 8 * 16, 64, 48
    shape = (2, m, k) if batched else (m, k)
    x = jax.random.normal(KEY, shape, jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(2), (k, n), jnp.float32)
    xs = P(None, None, "model") if batched else P(None, "model")
    os = P(None, "model", None) if batched else P("model", None)
    fn = shard_map(lambda a, b: overlap.matmul_rs(a, b, axis="model"),
                   mesh, in_specs=(xs, P("model", None)), out_specs=os)
    fnb = shard_map(lambda a, b: overlap.matmul_rs_baseline(a, b, axis="model"),
                    mesh, in_specs=(xs, P("model", None)), out_specs=os)
    r = x @ w
    allclose(jax.jit(fn)(x, w), r, atol=1e-4, rtol=1e-4)
    allclose(jax.jit(fnb)(x, w), r, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("window", [None, 48])
def test_ring_attention_vs_baseline(mesh, causal, window):
    b, h, s, d, hkv = 2, 4, 8 * 16, 32, 2
    q = jax.random.normal(KEY, (b, h, s, d))
    k = jax.random.normal(jax.random.PRNGKey(3), (b, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(4), (b, hkv, s, d))
    specs = (P(None, None, "model"),) * 3
    fn = shard_map(
        lambda *a: overlap.ring_attention(*a, axis="model", causal=causal,
                                          window=window),
        mesh, in_specs=specs, out_specs=P(None, None, "model"))
    fnb = shard_map(
        lambda *a: overlap.ag_attention_baseline(*a, axis="model", causal=causal,
                                                 window=window),
        mesh, in_specs=specs, out_specs=P(None, None, "model"))
    allclose(jax.jit(fn)(q, k, v), jax.jit(fnb)(q, k, v), atol=2e-5, rtol=1e-4)


def test_ag_moe_double_ring_vs_dense(mesh):
    e, k_top, d, f = 16, 2, 32, 64
    m = 8 * 64
    x = jax.random.normal(KEY, (m, d)) * 0.5
    wr = jax.random.normal(jax.random.PRNGKey(5), (d, e))
    wgu = jax.random.normal(jax.random.PRNGKey(6), (e, d, 2 * f)) * 0.1
    wdn = jax.random.normal(jax.random.PRNGKey(7), (e, f, d)) * 0.1

    def shard_fn(overlapped):
        def f_(xs, wgu_, wdn_):
            ids, wts, _ = moe_router(xs, wr, num_experts=e, top_k=k_top)
            g = ag_moe if overlapped else ag_moe_baseline
            return g(xs, ids, wts, wgu_, wdn_, axis="model",
                     capacity_factor=8.0)
        return shard_map(f_, mesh,
                         in_specs=(P("model", None), P("model", None, None),
                                   P("model", None, None)),
                         out_specs=P("model", None))

    y_o = jax.jit(shard_fn(True))(x, wgu, wdn)
    y_b = jax.jit(shard_fn(False))(x, wgu, wdn)

    # dense oracle
    probs = jax.nn.softmax(x @ wr, -1)
    topw, topi = jax.lax.top_k(probs, k_top)
    topw = topw / topw.sum(-1, keepdims=True)
    dense = jnp.zeros_like(x)
    for ei in range(e):
        h = x @ wgu[ei]
        hh = jax.nn.silu(h[:, :f]) * h[:, f:]
        dense = dense + (((topi == ei) * topw).sum(-1))[:, None] * (hh @ wdn[ei])
    allclose(y_o, dense, atol=1e-4, rtol=1e-4)
    allclose(y_b, dense, atol=1e-4, rtol=1e-4)


def test_overlap_grads_match_baseline(mesh):
    """AD through the ring schedules == AD through operator collectives."""
    m, k, n = 8 * 16, 32, 24
    x = jax.random.normal(KEY, (m, k))
    w = jax.random.normal(jax.random.PRNGKey(8), (k, n))

    def loss(fn):
        smfn = shard_map(fn, mesh, in_specs=(P("model", None), P(None, None)),
                         out_specs=P(None, None))
        return jax.grad(lambda a, b: (smfn(a, b) ** 2).sum(), argnums=(0, 1))

    g_o = jax.jit(loss(lambda a, b: overlap.ag_matmul(a, b, axis="model")))(x, w)
    g_b = jax.jit(loss(lambda a, b: overlap.ag_matmul_baseline(a, b, axis="model")))(x, w)
    allclose(g_o[0], g_b[0], atol=1e-4, rtol=1e-4)
    allclose(g_o[1], g_b[1], atol=1e-4, rtol=1e-4)
