"""Hypothesis property tests on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.comp_tiles import largest_divisor
from repro.core.mapping import StaticTileMapping, build_moe_dynamic_mapping
from repro.core import schedules
from repro.core.moe_overlap import _dispatch_tables, _capacity
from repro.nn.layers import gqa_layout
from repro.training.compression import compress_with_feedback, dequantize_int8

SET = settings(max_examples=40, deadline=None)


# ---- largest_divisor (sqrt-enumeration rewrite vs the old decrement loop) ----

def _largest_divisor_decrement(extent: int, cap: int) -> int:
    """The pre-rewrite O(extent) reference: decrement cap until it divides."""
    extent = max(1, int(extent))
    c = min(max(1, int(cap)), extent)
    while extent % c:
        c -= 1
    return c


@settings(max_examples=200, deadline=None)
@given(extent=st.integers(-3, 50_000), cap=st.integers(-3, 50_000))
def test_largest_divisor_matches_old_behavior(extent, cap):
    got = largest_divisor(extent, cap)
    assert got == _largest_divisor_decrement(extent, cap)
    # contract: a divisor, within cap (when cap is positive), >= 1
    e = max(1, extent)
    assert e % got == 0 and 1 <= got <= max(1, min(max(1, cap), e))


def test_largest_divisor_fast_on_large_primes():
    # the decrement loop walks cap..1 on primes — O(extent); the rewrite
    # enumerates divisor pairs up to sqrt(extent).  2**31 - 1 is prime: the
    # old loop would spin for ~2**31 iterations here.
    import time as _time

    t0 = _time.perf_counter()
    assert largest_divisor(2**31 - 1, 2**31 - 2) == 1
    assert largest_divisor(179_424_673, 179_424_672) == 1  # 10-millionth prime
    assert largest_divisor(151_936, 151_000) == 75_968  # qwen2 vocab, big cap
    assert _time.perf_counter() - t0 < 1.0


# ---- static tile mapping (paper §4.1 affine formulas) ------------------------

@SET
@given(
    tiles_per_rank=st.integers(1, 8),
    world=st.sampled_from([2, 4, 8, 16]),
    channels=st.integers(1, 4),
    tile=st.sampled_from([16, 64, 128]),
)
def test_static_mapping_invariants(tiles_per_rank, world, channels, tile):
    dim = tiles_per_rank * world * tile
    # paper's affine f_C requires channels | tiles_per_rank (see validate())
    channels = next(c for c in range(min(channels, tiles_per_rank), 0, -1)
                    if tiles_per_rank % c == 0)
    m = StaticTileMapping(dim=dim, tile=tile, world_size=world,
                          num_channels=channels)
    m.validate()
    seen_rows = 0
    for t in range(m.num_tiles):
        lo, hi = m.shape_range(t)
        assert 0 <= lo < hi <= dim  # f_S in range
        seen_rows += hi - lo
        r = m.rank(t)
        assert 0 <= r < world  # f_R in range
        assert t in m.tiles_of_rank(r)  # f_R inverse consistent
        c = m.channel(t)
        # channel refines rank: all tiles of one channel live on one rank
        assert m.rank(t) == c // max(1, m.num_channels)
    assert seen_rows == dim  # f_S covers the tensor exactly

    # traced forms agree with host forms
    t_ids = jnp.arange(m.num_tiles)
    np.testing.assert_array_equal(
        np.asarray(m.rank_t(t_ids)), [m.rank(t) for t in range(m.num_tiles)])


@SET
@given(
    e=st.integers(2, 8),
    tiles_per_expert=st.integers(1, 4),
    tile=st.sampled_from([8, 16]),
)
def test_dynamic_mapping_tables(e, tiles_per_expert, tile):
    rng = np.random.default_rng(0)
    sizes = rng.integers(0, tiles_per_expert * tile + 1, size=e)
    sizes = (sizes // tile) * tile  # tile-aligned groups
    offsets = jnp.asarray(np.concatenate([[0], np.cumsum(sizes)]), jnp.int32)
    m = build_moe_dynamic_mapping(offsets, tiles_per_expert, tile,
                                  experts_per_rank=1)
    lows, highs = np.asarray(m.f_S_low), np.asarray(m.f_S_high)
    ranks = np.asarray(m.f_R)
    covered = {ei: 0 for ei in range(e)}
    for t in range(m.num_tiles):
        ei = t // tiles_per_expert
        assert ranks[t] == ei  # f_R = expert rank
        assert lows[t] <= highs[t]
        assert highs[t] - lows[t] <= tile
        covered[ei] += int(highs[t] - lows[t])
    for ei in range(e):
        assert covered[ei] == sizes[ei]  # tiles tile the group exactly


# ---- schedules ---------------------------------------------------------------

@SET
@given(world=st.sampled_from([2, 4, 8, 16]))
def test_schedules_are_permutations(world):
    for rank in range(world):
        for fn in (schedules.ring_rs_segment, schedules.ring_ag_source,
                   schedules.bidir_ring_source, schedules.all2all_peer):
            seen = [fn(rank, s, world) for s in range(world)]
            assert sorted(seen) == list(range(world)), (fn.__name__, rank)


# ---- MoE capacity dispatch ---------------------------------------------------

@SET
@given(m=st.integers(4, 64), k=st.integers(1, 4), e=st.sampled_from([2, 4, 8]))
def test_dispatch_slots_unique_and_bounded(m, k, e):
    rng = np.random.default_rng(1)
    ids = jnp.asarray(rng.integers(0, e, size=(m, k)), jnp.int32)
    valid = jnp.ones((m, k), jnp.float32)
    cap = _capacity(m, k, e, 1.0)
    disp = _dispatch_tables(ids, valid, e, cap, jnp.float32)  # [m,k,e,c]
    d = np.asarray(disp)
    # each (token, k) occupies at most one (expert, slot)
    assert (d.sum(axis=(2, 3)) <= 1 + 1e-6).all()
    # each (expert, slot) holds at most one (token, k)
    assert (d.sum(axis=(0, 1)) <= 1 + 1e-6).all()
    # nothing beyond capacity
    assert d.shape[-1] == cap


# ---- GQA layout --------------------------------------------------------------

@SET
@given(kv=st.integers(1, 32), group=st.integers(1, 8),
       tp=st.sampled_from([1, 2, 4, 8, 16]))
def test_gqa_layout_invariants(kv, group, tp):
    h = kv * group  # valid GQA: kv heads evenly divide q heads
    lay = gqa_layout(h, kv, tp)
    assert lay.h_pad >= h and lay.h_pad % tp == 0
    assert lay.h_loc * tp == lay.h_pad
    assert lay.kv_loc * tp == lay.kv_store * (
        tp // (lay.kv_store // max(1, lay.kv_loc))
    ) or lay.kv_store in (lay.kv_pad, tp)
    # every rank's q heads map to exactly one local kv group
    assert lay.h_loc % lay.kv_loc == 0
    if lay.rep > 1:
        assert lay.kv_store == tp and lay.kv_loc == 1
        assert lay.kv_pad * lay.rep == tp


# ---- gradient compression ------------------------------------------------------

@SET
@given(scale=st.floats(1e-3, 1e3), n=st.integers(4, 256))
def test_error_feedback_contract(scale, n):
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    err0 = jnp.zeros_like(g)
    q, s, err1 = compress_with_feedback(g, err0)
    # exact identity: g + err0 == deq(q) + err1
    np.testing.assert_allclose(np.asarray(g + err0),
                               np.asarray(dequantize_int8(q, s) + err1),
                               rtol=1e-5, atol=1e-5 * float(scale))
    # bounded quantization error per element
    assert np.abs(np.asarray(err1)).max() <= float(s) * 0.5 + 1e-6


# ---- quantized wires (QuantSpec layer) ---------------------------------------

@SET
@given(seed=st.integers(0, 2**16), scale=st.floats(1e-3, 1e3),
       granularity=st.sampled_from(["per_tile", "per_channel"]))
def test_wire_quant_roundtrip_bound(seed, scale, granularity):
    """|x - deq(quant(x))| <= scale/2 elementwise: symmetric absmax maps the
    extreme exactly onto the +/-127 endpoint, so clipping never truncates."""
    from repro.core.quant import dequantize, quantize

    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(16, 24) * scale, jnp.float32)
    payload = quantize(x, "int8", granularity)
    bound = 0.5 * np.asarray(payload.scale, np.float32)
    err = np.abs(np.asarray(dequantize(payload, jnp.float32)) - np.asarray(x))
    assert (err <= bound + 1e-6 * scale).all()


@SET
@given(seed=st.integers(0, 2**16), world=st.sampled_from([1, 2, 4, 8, 16]))
def test_wire_quant_error_independent_of_world(seed, world):
    """Per-tile scales are applied ONCE at each AG tile's origin (wire-edge
    encode), so the end-to-end gather->dequant->GEMM error obeys a bound with
    no world-size term: each shard's scale <= the global-absmax scale."""
    from repro.core.quant import dequantize, quantize

    rng = np.random.RandomState(seed)
    m, k, n = 8, 16, 8
    x = rng.randn(world * m, k).astype(np.float32)
    w = rng.randn(k, n).astype(np.float32)
    deq = np.concatenate([
        np.asarray(dequantize(quantize(jnp.asarray(s), "int8"), jnp.float32))
        for s in np.split(x, world, axis=0)])
    err = np.abs(deq @ w - x @ w).max()
    bound = k * (np.abs(x).max() / 254.0 + 1e-6) * np.abs(w).max()
    assert err <= bound
