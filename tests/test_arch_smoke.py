"""Per-architecture smoke tests (assignment deliverable f).

Each assigned arch instantiates a REDUCED config of the same family and runs
one forward + one train step on CPU, asserting output shapes and no NaNs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.launch.specs import model_module
from repro.models import frontends
from repro.parallel.sharding import place
from repro.training import AdamWConfig, init_opt_state, make_train_step
from utils import reduce_config, tree_finite

B, S = 2, 32


def _batch(cfg, key):
    data = {}
    n_text = S
    if cfg.frontend == "vision":
        n_img = min(8, S // 2)
        n_text = S - n_img
        data["embeds"] = frontends.stub_patch_embeddings(key, B, 2 * n_img,
                                                         cfg.d_model, jnp.float32)[:, :n_img]
    elif cfg.frontend == "audio":
        data["embeds"] = frontends.stub_frame_embeddings(key, B, 32,
                                                         cfg.d_model, jnp.float32)
    data["inputs"] = jax.random.randint(key, (B, n_text), 0, cfg.vocab_size)
    data["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return data


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_forward_and_train_step(arch, pc8, mesh8):
    cfg = reduce_config(get_config(arch))
    mod = model_module(cfg)
    params = place(mod.init(jax.random.PRNGKey(0), cfg, pc8, jnp.float32),
                   mesh8, mod.specs(cfg, pc8))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, aux = jax.jit(
        lambda p, t, e: mod.forward(p, cfg, pc8, t, embeds=e)
    )(params, batch["inputs"], batch.get("embeds"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    step = make_train_step(mod, cfg, pc8, AdamWConfig(lr=1e-3, total_steps=10),
                           grad_masks=mod.grad_masks(cfg, pc8), donate=False)
    opt = init_opt_state(params)
    p2, o2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert tree_finite(p2)


@pytest.mark.parametrize("arch", ["qwen2-72b", "gemma3-27b", "mamba2-2.7b",
                                  "granite-moe-3b-a800m", "zamba2-2.7b"])
def test_arch_decode_step(arch, pc8, mesh8):
    from repro.models import lm

    cfg = reduce_config(get_config(arch))
    params = place(lm.init(jax.random.PRNGKey(0), cfg, pc8, jnp.float32),
                   mesh8, lm.specs(cfg, pc8))
    caches = place(lm.init_caches(cfg, pc8, B, 64, jnp.float32),
                   mesh8, lm.cache_specs(cfg, pc8))
    tok = jnp.ones((B, 1), jnp.int32)
    step = jax.jit(lambda p, c, t, n: lm.decode_step(p, c, cfg, pc8, t, n))
    logits, caches = step(params, caches, tok, 0)
    logits, caches = step(params, caches, tok, 1)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


def test_full_configs_match_assignment():
    """The registered FULL configs carry the assigned hyperparameters."""
    expect = {
        "granite-moe-3b-a800m": dict(n_layers=32, d_model=1536, n_heads=24,
                                     n_kv_heads=8, vocab_size=49155),
        "deepseek-moe-16b": dict(n_layers=28, d_model=2048, n_heads=16,
                                 n_kv_heads=16, vocab_size=102400),
        "paligemma-3b": dict(n_layers=18, d_model=2048, n_heads=8,
                             n_kv_heads=1, d_ff=16384, vocab_size=257216),
        "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32,
                            n_kv_heads=32, d_ff=10240, vocab_size=32000),
        "qwen2-72b": dict(n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
                          d_ff=29568, vocab_size=152064, qkv_bias=True),
        "smollm-360m": dict(n_layers=32, d_model=960, n_heads=15,
                            n_kv_heads=5, d_ff=2560, vocab_size=49152),
        "starcoder2-7b": dict(n_layers=32, d_model=4608, n_heads=36,
                              n_kv_heads=4, d_ff=18432, vocab_size=49152),
        "gemma3-27b": dict(n_layers=62, d_model=5376, n_heads=32,
                           n_kv_heads=16, d_ff=21504, vocab_size=262144),
        "mamba2-2.7b": dict(n_layers=64, d_model=2560, vocab_size=50280),
        "seamless-m4t-medium": dict(n_layers=12, encoder_layers=12,
                                    d_model=1024, n_heads=16, d_ff=4096,
                                    vocab_size=256206),
    }
    for arch, fields in expect.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    # MoE structure
    assert get_config("granite-moe-3b-a800m").moe.num_experts == 40
    assert get_config("granite-moe-3b-a800m").moe.top_k == 8
    assert get_config("deepseek-moe-16b").moe.num_experts == 64
    assert get_config("deepseek-moe-16b").moe.top_k == 6
    assert get_config("deepseek-moe-16b").moe.num_shared == 2
    # SSM structure
    assert get_config("mamba2-2.7b").ssm.d_state == 128
    assert get_config("zamba2-2.7b").ssm.d_state == 64
