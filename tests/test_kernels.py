"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import kernels
from repro.compat import shard_map, make_mesh
from repro.kernels import ref
from utils import allclose

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 128, 384), (384, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(m, n, k, dtype):
    x = jax.random.normal(KEY, (m, k), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), dtype)
    y = kernels.matmul(x, w, interpret=True)
    r = ref.matmul_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    allclose(y.astype(jnp.float32), r.astype(jnp.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("window", [None, 96])
@pytest.mark.parametrize("gqa", [1, 2])
def test_flash_attention_sweep(causal, window, gqa):
    bh, s, d = 4, 256, 64
    q = jax.random.normal(KEY, (bh, s, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (bh // gqa, s, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(3), (bh // gqa, s, d), jnp.float32)
    y = kernels.flash_attention(q, k, v, causal=causal, window=window,
                                bq=128, bk=128, interpret=True)
    r = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    allclose(y, r, atol=2e-4, rtol=2e-3)


def test_flash_attention_comp_tile():
    # the tuner's CompSpec (tm, ., tk) derives (block_q, block_kv); tk=96
    # clamps to the largest divisor of Sk (the shared degrade rule)
    bh, s, d = 2, 256, 64
    q = jax.random.normal(KEY, (bh, s, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (1, s, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(3), (1, s, d), jnp.float32)
    y = kernels.flash_attention(q, k, v, causal=True, tile=(64, 128, 96),
                                interpret=True)
    r = ref.flash_attention_ref(q, k, v, causal=True)
    allclose(y, r, atol=2e-4, rtol=2e-3)
    # the default sentinel leaves bq/bk untouched (backend-chosen blocking)
    y0 = kernels.flash_attention(q, k, v, causal=True, tile=(128, 128, 128),
                                 interpret=True)
    yn = kernels.flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(yn))


def test_grouped_matmul_clamps_non_dividing_tile():
    # tuner-resolved tiles may not divide awkward extents: bn=48 / bk=64
    # clamp via largest_divisor (40, 48) instead of refusing
    e, m, k, n, bm = 4, 256, 96, 80, 64
    tile_expert = jnp.array([0, 1, 3, 3], jnp.int32)
    x = jax.random.normal(KEY, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(4), (e, k, n), jnp.float32)
    y = kernels.grouped_matmul(x, w, tile_expert, tile=(bm, 48, 64),
                               interpret=True)
    r = ref.grouped_matmul_ref(x, w, tile_expert, bm)
    allclose(y, r, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_dynamic_mapping(dtype):
    e, m, k, n, bm = 6, 512, 128, 256, 128
    tile_expert = jnp.array([0, 2, 2, 5], jnp.int32)
    x = jax.random.normal(KEY, (m, k), dtype)
    w = jax.random.normal(jax.random.PRNGKey(4), (e, k, n), dtype)
    y = kernels.grouped_matmul(x, w, tile_expert, tile=(bm, 128, 128),
                               interpret=True)
    r = ref.grouped_matmul_ref(x, w, tile_expert, bm)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    allclose(y.astype(jnp.float32), r.astype(jnp.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_ssd_chunked_vs_sequential(chunk):
    b, sl, h, p, g, n = 2, 128, 4, 16, 2, 8
    x = jax.random.normal(KEY, (b, sl, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(5), (b, sl, h)))
    a_log = jax.random.normal(jax.random.PRNGKey(6), (h,)) * 0.5
    bm = jax.random.normal(jax.random.PRNGKey(7), (b, sl, g, n)) * 0.3
    cm = jax.random.normal(jax.random.PRNGKey(8), (b, sl, g, n)) * 0.3
    y = kernels.ssd_chunked(x, dt, a_log, bm, cm, chunk=chunk)
    r = ref.ssd_ref(x, dt, a_log, bm, cm)
    allclose(y, r, atol=1e-4, rtol=1e-3)


def test_ssd_chunked_state_continuation():
    """Final state from chunked == final state from sequential recurrence."""
    b, sl, h, p, g, n = 1, 64, 2, 8, 1, 4
    x = jax.random.normal(KEY, (b, sl, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(5), (b, sl, h)))
    a_log = jnp.zeros((h,))
    bm = jax.random.normal(jax.random.PRNGKey(7), (b, sl, g, n)) * 0.3
    cm = jax.random.normal(jax.random.PRNGKey(8), (b, sl, g, n)) * 0.3
    y1, h1 = kernels.ssd_chunked(x, dt, a_log, bm, cm, chunk=16,
                                 return_state=True)
    # continue for one decode step and compare against full-length chunked
    y_full = kernels.ssd_chunked(
        jnp.concatenate([x, x[:, :16]], 1),
        jnp.concatenate([dt, dt[:, :16]], 1), a_log,
        jnp.concatenate([bm, bm[:, :16]], 1),
        jnp.concatenate([cm, cm[:, :16]], 1), chunk=16)
    y2 = kernels.ssd_chunked(x[:, :16], dt[:, :16], a_log, bm[:, :16],
                             cm[:, :16], chunk=16, h_init=h1)
    allclose(y2, y_full[:, sl:], atol=1e-4, rtol=1e-3)


def test_ssd_intra_chunk_kernel():
    t, q, p = 4, 32, 16
    cum = -jnp.abs(jax.random.normal(KEY, (t, q))).cumsum(axis=1)
    cb = jax.random.normal(jax.random.PRNGKey(9), (t, q, q)) * 0.3
    xdt = jax.random.normal(jax.random.PRNGKey(10), (t, q, p)) * 0.5
    y = kernels.ssd_intra_chunk(cum, cb, xdt, interpret=True)
    # oracle
    diff = cum[:, :, None] - cum[:, None, :]
    mask = np.tril(np.ones((q, q), bool))
    g = np.asarray(cb) * np.where(mask, np.exp(np.asarray(diff)), 0.0)
    r = np.einsum("tqk,tkp->tqp", g, np.asarray(xdt))
    allclose(y, r, atol=1e-4, rtol=1e-3)


# ---- fused communication kernels (remote DMA + semaphores, interpret mode) --

def test_ag_gemm_fused_ring():
    mesh = make_mesh((4,), ("model",))
    r, m_loc, k, n_loc = 4, 32, 64, 256
    x = jax.random.normal(KEY, (r * m_loc, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(11), (k, r * n_loc), jnp.float32)
    fn = shard_map(
        lambda a, b: kernels.ag_gemm_shard(a, b, world_size=r, bn=128,
                                           interpret=True),
        mesh, in_specs=(P("model", None), P(None, "model")),
        out_specs=P(None, "model"))
    y = jax.jit(fn)(x, w)
    allclose(y, x @ w, atol=1e-3, rtol=1e-3)


def test_gemm_rs_fused_ring():
    mesh = make_mesh((4,), ("model",))
    m, k_loc, n = 128, 64, 256
    x = jax.random.normal(KEY, (m, 4 * k_loc), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(12), (4 * k_loc, n), jnp.float32)
    fn = shard_map(
        lambda a, b: kernels.gemm_rs_shard(a, b, world_size=4, bn=128,
                                           interpret=True),
        mesh, in_specs=(P(None, "model"), P("model", None)),
        out_specs=P("model", None))
    y = jax.jit(fn)(x, w)
    allclose(y, x @ w, atol=1e-3, rtol=1e-3)


def test_gemm_rs_matches_paper_schedule():
    """Segment order must follow the paper's seg=(rank+stage+1)%W ring."""
    from repro.core.schedules import ring_rs_segment
    w = 4
    for rank in range(w):
        segs = [ring_rs_segment(rank, s, w) for s in range(w)]
        assert segs[-1] == rank  # final stage = own segment
        assert sorted(segs) == list(range(w))  # visits every segment once
