"""End-to-end system behaviour: train -> checkpoint -> restore -> serve."""

import numpy as np

from repro.launch.train import train


def test_train_checkpoint_resume_end_to_end(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    losses = train("smollm-360m", steps=12, batch=4, seq=64, reduce=True,
                   ckpt_dir=ckpt, ckpt_every=6, log_every=100)
    assert len(losses) == 12
    assert np.isfinite(losses).all()

    # resume picks up from the saved step and continues
    losses2 = train("smollm-360m", steps=16, batch=4, seq=64, reduce=True,
                    ckpt_dir=ckpt, ckpt_every=100, log_every=100, resume=True)
    assert len(losses2) == 4  # 12 -> 16


def test_overlap_and_baseline_training_same_trajectory():
    la = train("smollm-360m", steps=4, batch=4, seq=64, reduce=True,
               mode="overlap", log_every=100)
    lb = train("smollm-360m", steps=4, batch=4, seq=64, reduce=True,
               mode="baseline", log_every=100)
    np.testing.assert_allclose(la, lb, atol=5e-3, rtol=1e-3)
