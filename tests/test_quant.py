"""QuantSpec layer: wire-dtype split, dequant-GEMM, verifier + cache contract.

Covers the quantized-flows surface end to end:

  * spec validation and the encode/decode roundtrip bounds (per_tile and
    per_channel granularity);
  * the gradient-compression dedupe (training.compression re-exports the
    repro.core.quant int8 codec — one codepath, same semantics);
  * the headline property: with per-tile scales, end-to-end quant error
    through the ring is bounded independently of the world size (AG tiles
    are encoded ONCE at their origin, not per hop);
  * bitwise parity of the float wire paths with the pre-quant default;
  * weight-only dequant-GEMM (PackedWeight through blocked_dot) parity;
  * the verifier's quant checks (scale-table coverage / wire dtype /
    granularity) and the tune-cache v3 -> v4 migration (old records re-tune).
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis import PlanTables, PlanVerificationError, verify_tables
from repro.compat import make_mesh, shard_map
from repro.core.channels import BlockChannel
from repro.core.comp_tiles import blocked_dot
from repro.core.compiler import compile_overlap
from repro.core.plan import build_plan
from repro.core.quant import (
    PackedWeight,
    QuantSpec,
    WirePayload,
    decode_tree,
    dequantize,
    dequantize_int8,
    encode_tree,
    pack_weight,
    quantize,
    quantize_int8,
    wire_itemsize,
)

# NOTE: the hypothesis-driven forms of the roundtrip/world-independence
# properties live in tests/test_properties.py (which importorskips
# hypothesis); the parametrized versions here always run.


# ---- spec validation --------------------------------------------------------


def test_spec_validation():
    QuantSpec()  # default: inherit accum dtype
    QuantSpec(wire_dtype="int8", granularity="per_channel")
    QuantSpec(weight_dtype="int4", zero_point=True)
    with pytest.raises(ValueError, match="wire_dtype"):
        QuantSpec(wire_dtype="int4")  # int4 is weight-only, not a wire
    with pytest.raises(ValueError, match="granularity"):
        QuantSpec(granularity="per_row")
    with pytest.raises(ValueError, match="weight_dtype"):
        QuantSpec(weight_dtype="float16")
    with pytest.raises(ValueError, match="zero_point"):
        QuantSpec(zero_point=True)


def test_spec_identity_and_resolution():
    spec = QuantSpec()
    assert spec.resolve_wire("float32") == "float32"
    assert spec.is_identity("float32") and spec.is_identity("bfloat16")
    assert not spec.is_quantized
    q = QuantSpec(wire_dtype="int8")
    assert q.is_quantized and not q.is_identity("float32")
    assert QuantSpec(wire_dtype="bfloat16").is_identity("bfloat16")
    assert not QuantSpec(wire_dtype="bfloat16").is_identity("float32")
    assert wire_itemsize("int8") == 1 and wire_itemsize("bfloat16") == 2


def test_scale_slots_by_flow():
    q = QuantSpec(wire_dtype="int8")
    assert QuantSpec().scale_slots("ag", 8, 2, 8) == 0  # identity wire
    assert q.scale_slots("ag", 8, 2, 8) == 16  # once per origin tile
    assert q.scale_slots("rs", 8, 2, 8) == 14  # re-encoded per send edge
    assert q.scale_slots("ag_rs", 8, 2, 8) == 30  # tiles + flowing reduction
    with pytest.raises(ValueError, match="flow"):
        q.scale_slots("sideways", 8, 2, 8)


# ---- roundtrip bounds -------------------------------------------------------


@pytest.mark.parametrize("seed,scale", [(0, 1.0), (7, 1e-3), (42, 1e3)])
@pytest.mark.parametrize("granularity", ["per_tile", "per_channel"])
def test_quantize_roundtrip_bound(seed, scale, granularity):
    """|x - deq(quant(x))| <= scale/2 elementwise (symmetric absmax, no clip
    truncation: absmax maps exactly to the +/-127 endpoint)."""
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(16, 24) * scale, jnp.float32)
    payload = quantize(x, "int8", granularity)
    deq = dequantize(payload, jnp.float32)
    bound = 0.5 * np.asarray(payload.scale, np.float32)  # per-elem max error
    err = np.abs(np.asarray(deq) - np.asarray(x))
    assert (err <= bound + 1e-6).all()
    if granularity == "per_channel":
        assert payload.scale.shape == (x.shape[-1],)
    else:
        assert payload.scale.shape == ()


def test_per_channel_beats_per_tile_on_skewed_columns():
    rng = np.random.RandomState(0)
    x = rng.randn(64, 8).astype(np.float32)
    x[:, 0] *= 1000.0  # one hot column blows up the shared per-tile scale
    xt = jnp.asarray(x)
    err_tile = np.abs(np.asarray(
        dequantize(quantize(xt, "int8", "per_tile"), jnp.float32)) - x)
    err_chan = np.abs(np.asarray(
        dequantize(quantize(xt, "int8", "per_channel"), jnp.float32)) - x)
    assert err_chan[:, 1:].max() < err_tile[:, 1:].max() / 10.0


def test_encode_tree_passthrough_and_identity():
    spec = QuantSpec(wire_dtype="int8")
    x = jnp.ones((4, 4), jnp.float32)
    ids = jnp.arange(4, dtype=jnp.int32)  # routing tables ride untouched
    enc = encode_tree({"x": x, "ids": ids}, spec, "float32")
    assert isinstance(enc["x"], WirePayload)
    assert enc["ids"] is ids
    dec = decode_tree(enc, spec, "float32")
    assert dec["x"].dtype == jnp.float32 and dec["ids"] is ids
    # identity spec: encode/decode return the SAME objects (bitwise path)
    ident = encode_tree({"x": x}, QuantSpec(), "float32")
    assert ident["x"] is x


# ---- compression dedupe -----------------------------------------------------


def test_compression_reexports_shared_codec():
    from repro.training import compression

    assert compression.quantize_int8 is quantize_int8
    assert compression.dequantize_int8 is dequantize_int8
    g = jnp.asarray(np.random.RandomState(3).randn(33, 7), jnp.float32)
    q, scale = quantize_int8(g)
    assert q.dtype == jnp.int8 and scale.dtype == jnp.float32
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(g))
    assert err.max() <= float(scale) * 0.5 + 1e-7
    # error feedback still closes over the shared codec
    q2, s2, new_err = compression.compress_with_feedback(g, jnp.zeros_like(g))
    np.testing.assert_allclose(
        np.asarray(dequantize_int8(q2, s2) + new_err), np.asarray(g),
        rtol=1e-6, atol=1e-6)


# ---- world-size independence (the wire-edge property) -----------------------


@pytest.mark.parametrize("seed", [3, 17])
@pytest.mark.parametrize("world", [1, 2, 4, 8])
def test_ag_quant_error_independent_of_world(seed, world):
    """AG tiles are encoded once at their origin, so the end-to-end error of
    gather -> dequant -> GEMM is bounded by a constant that does NOT grow
    with the world size (each shard's scale <= the global absmax scale)."""
    rng = np.random.RandomState(seed)
    m, k, n = 32, 16, 8
    x = rng.randn(world * m, k).astype(np.float32)
    w = rng.randn(k, n).astype(np.float32)
    shards = np.split(x, world, axis=0)
    deq = np.concatenate([
        np.asarray(dequantize(quantize(jnp.asarray(s), "int8"), jnp.float32))
        for s in shards])
    err = np.abs(deq @ w - x @ w).max()
    # world-independent bound: elementwise quant error <= global_absmax/254,
    # one GEMM row contracts k of them against |w|
    bound = k * (np.abs(x).max() / 254.0 + 1e-6) * np.abs(w).max()
    assert err <= bound


# ---- mesh parity + bitwise float paths --------------------------------------


@pytest.fixture(scope="module")
def mesh4():
    return make_mesh((4,), ("model",))


def _run(mesh, fn, *args):
    f = shard_map(fn, mesh, in_specs=(P(None, None),) * len(args),
                  out_specs=P("model", None), check_rep=False,
                  axis_names={"model"})
    return f(*args)


@pytest.mark.parametrize("kind", ["matmul_rs", "ag_matmul"])
def test_int8_flow_parity_on_mesh(mesh4, kind):
    rng = np.random.RandomState(11)
    x = jnp.asarray(rng.randn(64, 64), jnp.float32)
    w = jnp.asarray(rng.randn(64, 32), jnp.float32)
    ch = BlockChannel(axis="model")
    y_f = _run(mesh4, compile_overlap(kind, ch), x, w)
    y_q = _run(mesh4, compile_overlap(
        kind, ch, quant=QuantSpec(wire_dtype="int8")), x, w)
    rel = float(jnp.linalg.norm(y_q - y_f) / jnp.linalg.norm(y_f))
    assert rel < 0.05, rel


@pytest.mark.parametrize("wire", ["float32", None])
def test_float_wire_is_bitwise_identical(mesh4, wire):
    """The fp32 flow path must not change AT ALL under the refactor: a
    float32 wire over a float32 accum is encode/decode identity."""
    rng = np.random.RandomState(12)
    x = jnp.asarray(rng.randn(64, 64), jnp.float32)
    w = jnp.asarray(rng.randn(64, 32), jnp.float32)
    ch = BlockChannel(axis="model")
    y_def = _run(mesh4, compile_overlap("matmul_rs", ch), x, w)
    quant = None if wire is None else QuantSpec(wire_dtype=wire)
    ch_q = ch if quant is None else ch.with_(quant=quant)
    y_q = _run(mesh4, compile_overlap("matmul_rs", ch_q), x, w)
    assert bool(jnp.all(y_def == y_q))


def test_context_quant_threading(mesh4):
    from repro.parallel.context import ParallelContext

    pc = ParallelContext(mesh=mesh4, dp_axes=(),
                         quant=QuantSpec(wire_dtype="int8"))
    assert pc.channel.quant.wire_dtype == "int8"
    assert ParallelContext(mesh=mesh4, dp_axes=(), quant=True).quant == "auto"
    with pytest.raises(ValueError, match="quant"):
        ParallelContext(mesh=mesh4, dp_axes=(), quant="int8")


# ---- weight-only dequant-GEMM ----------------------------------------------


@pytest.mark.parametrize("wdtype,zp", [("int8", False), ("int4", True)])
def test_packed_blocked_dot_parity(wdtype, zp):
    rng = np.random.RandomState(21)
    x = jnp.asarray(rng.randn(32, 48), jnp.float32)
    w = jnp.asarray(rng.randn(48, 64), jnp.float32)
    packed = pack_weight(w, QuantSpec(weight_dtype=wdtype, zero_point=zp))
    assert isinstance(packed, PackedWeight)
    from repro.core.quant import dequantize_weight

    w_ref = dequantize_weight(packed.q, packed.scale, packed.zero)
    ref = x @ w_ref
    for unroll in (False, True):
        got = blocked_dot(x, packed, (16, 32, 16), accum=jnp.float32,
                          unroll=unroll)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
    # col_slice keeps scales aligned with the sliced codes
    lo, hi = 16, 48
    sliced = packed.col_slice(lo, hi)
    got = blocked_dot(x, sliced, (16, 32, 16), accum=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref[:, lo:hi]),
                               rtol=2e-5, atol=2e-5)


# ---- verifier quant checks --------------------------------------------------


def _quant_tables(kind="matmul_rs", world=8, nch=2):
    ch = BlockChannel(axis="model", quant=QuantSpec(wire_dtype="int8"))
    plan = build_plan(kind, ch, world, nch)
    return PlanTables.from_plan(plan)


def test_verifier_accepts_quant_plan():
    tables = _quant_tables()
    report = verify_tables(tables)
    assert report.checks > 0
    assert tables.wire_dtype == "int8" and tables.scale_slots is not None


@pytest.mark.parametrize("field,value,check", [
    ("scale_slots", 3, "quant_scale_slots"),
    ("wire_dtype", "int4", "quant_wire_dtype"),
    ("granularity", "per_row", "quant_granularity"),
])
def test_verifier_flags_quant_mutations(field, value, check):
    tables = dataclasses.replace(_quant_tables(), **{field: value})
    with pytest.raises(PlanVerificationError) as e:
        verify_tables(tables)
    assert e.value.check == check


def test_verifier_skips_unquantified_tables():
    ch = BlockChannel(axis="model")
    tables = PlanTables.from_plan(build_plan("matmul_rs", ch, 8, 2))
    assert tables.scale_slots == 0  # identity wire allocates no scale table
    verify_tables(tables)  # and the quant pass stays green


# ---- tune-cache schema migration --------------------------------------------


def test_cache_v3_records_retune():
    from repro.tune import CACHE_SCHEMA, _parse_record

    assert CACHE_SCHEMA == 4
    v4 = {
        "schema": 4, "order": "ring", "num_channels": 2,
        "accum_dtype": "float32", "comp_tile": [64, 128, 128],
        "flow": "int8", "ranker": "model", "score": 1.0,
    }
    parsed = _parse_record(v4)
    assert parsed is not None and parsed["candidate"].flow == "int8"
    v3 = dict(v4, schema=3)
    v3.pop("flow")
    assert _parse_record(v3) is None  # pre-quant schema: silent re-tune
    assert _parse_record(dict(v4, flow="int4")) is None  # junk flow


def test_autotune_explores_flow_axis(tmp_path, mesh4, monkeypatch):
    """channel='auto' with quant enabled must consider int8 wires and record
    the winner's flow in a schema-4 entry."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    from repro.tune import autotune
    from repro.tune.candidates import QUANT_SPACE

    # comm-bound: tiny k keeps compute cheap while m*n rides the wire
    result = autotune("matmul_rs", signature=(1, 512, 64, 2048), world=4,
                      mesh=mesh4, ranker="model", space=QUANT_SPACE)
    assert result.channel.quant is not None
    records = list(tmp_path.rglob("*.json*"))
    assert records, "autotune must persist a cache entry"
