"""Extended coverage: memmap data path, enc-dec decode consistency, bf16 fused
comm kernels, MoE decode-stream equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map, make_mesh
from repro.configs import get_config
from repro.data import MemmapTokens
from repro.models import encdec, frontends, lm
from repro.parallel.context import ParallelContext
from repro.parallel.sharding import place
from utils import reduce_config


def test_memmap_pipeline_roundtrip(tmp_path):
    path = str(tmp_path / "tokens.bin")
    toks = np.arange(10_000, dtype=np.uint16) % 251
    toks.tofile(path)
    pipe = MemmapTokens(path=path, seq_len=64, global_batch=4)
    b1 = pipe.host_batch()
    assert b1["inputs"].shape == (4, 64)
    # labels are the shifted stream
    np.testing.assert_array_equal(b1["inputs"][0, 1:], b1["labels"][0, :-1])
    # cursor state round-trips
    st = pipe.state()
    b2 = pipe.host_batch()
    pipe2 = MemmapTokens(path=path, seq_len=64, global_batch=4)
    pipe2.restore(st)
    np.testing.assert_array_equal(pipe2.host_batch()["inputs"], b2["inputs"])


def test_encdec_decode_matches_forward(pc8, mesh8):
    """Enc-dec: cross-cache decode logits == teacher-forced forward logits."""
    cfg = reduce_config(get_config("seamless-m4t-medium"))
    cfg = dataclasses.replace(cfg, vocab_size=128, enc_len=32)
    params = place(encdec.init(jax.random.PRNGKey(0), cfg, pc8, jnp.float32),
                   mesh8, encdec.specs(cfg, pc8))
    emb = frontends.stub_frame_embeddings(jax.random.PRNGKey(1), 2, 32,
                                          cfg.d_model, jnp.float32)
    s0, extra = 8, 4
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, s0 + extra), 0,
                              cfg.vocab_size)
    full, _ = jax.jit(lambda p, t, e: encdec.forward(p, cfg, pc8, t, e))(
        params, toks, emb)

    enc = jax.jit(lambda p, e: encdec.encode(p, cfg, pc8, e))(params, emb)
    cross = jax.jit(lambda p, e: encdec.build_cross_caches(p, cfg, pc8, e))(
        params, enc)
    caches = place(encdec.init_caches(cfg, pc8, 2, s0 + extra, jnp.float32),
                   mesh8, encdec.cache_specs(cfg, pc8))
    caches = {"self": caches["self"], "cross": cross}
    step = jax.jit(lambda p, c, t, n: encdec.decode_step(p, c, cfg, pc8, t, n))
    for i in range(s0 + extra):
        logits, caches = step(params, caches, toks[:, i: i + 1], i)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, i]), atol=3e-3, rtol=3e-3)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_fused_comm_kernels_bf16(dtype):
    """Fused AG+GEMM / GEMM+RS ring kernels in bf16 (interpret mode)."""
    from repro import kernels

    mesh = make_mesh((4,), ("model",))
    key = jax.random.PRNGKey(0)
    r, m_loc, k, n = 4, 16, 64, 128
    x = jax.random.normal(key, (r * m_loc, k), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, r * n), dtype)
    fn = shard_map(
        lambda a, b: kernels.ag_gemm_shard(a, b, world_size=r, bn=128,
                                           interpret=True),
        mesh, in_specs=(P("model", None), P(None, "model")),
        out_specs=P(None, "model"))
    y = jax.jit(fn)(x, w)
    ref = (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(dtype)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32), atol=0.5, rtol=0.05)


def test_moe_decode_stream_matches_gather(mesh8):
    """The §Perf streamed MoE decode == the baseline gather decode."""
    cfg = reduce_config(get_config("granite-moe-3b-a800m"))
    cfg = dataclasses.replace(cfg, vocab_size=128)
    pc_g = ParallelContext(mesh=mesh8, moe_decode_stream=False)
    pc_s = ParallelContext(mesh=mesh8, moe_decode_stream=True)
    params = place(lm.init(jax.random.PRNGKey(0), cfg, pc_g, jnp.float32),
                   mesh8, lm.specs(cfg, pc_g))
    caches = place(lm.init_caches(cfg, pc_g, 2, 16, jnp.float32),
                   mesh8, lm.cache_specs(cfg, pc_g))
    tok = jnp.ones((2, 1), jnp.int32)
    lg, _ = jax.jit(lambda p, c, t: lm.decode_step(p, c, cfg, pc_g, t, 0))(
        params, caches, tok)
    ls, _ = jax.jit(lambda p, c, t: lm.decode_step(p, c, cfg, pc_s, t, 0))(
        params, caches, tok)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ls), atol=2e-4,
                               rtol=2e-4)


def test_long_context_window_cache_sizes():
    """gemma3 long_500k: local layers allocate window-sized ring caches."""
    cfg = get_config("gemma3-27b")
    mesh = make_mesh((1, 2, 4), ("pod", "data", "model"))
    pc = ParallelContext(mesh=mesh)
    caches = jax.eval_shape(lambda: lm.init_caches(cfg, pc, 1, 524288,
                                                   jnp.bfloat16))
    # scan caches: 5 local slots (ring = window) + 1 global slot (full length)
    local_len = caches["scan"][0]["k"].shape[3]
    global_len = caches["scan"][5]["k"].shape[3]
    assert local_len == cfg.local_window == 1024
    assert global_len == 524288
