"""Serving integration: prefill-into-cache + decode == full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.parallel.sharding import place
from repro.serving import ServeEngine
from utils import reduce_config


@pytest.mark.parametrize("arch", ["qwen2-72b", "gemma3-27b", "mamba2-2.7b"])
def test_prefill_decode_matches_forward(arch, pc8, mesh8):
    """Greedy next-token from (prefill + decode) must match teacher-forced
    forward logits at every position."""
    cfg = reduce_config(get_config(arch))
    cfg = dataclasses.replace(cfg, vocab_size=128)
    params = place(lm.init(jax.random.PRNGKey(0), cfg, pc8, jnp.float32),
                   mesh8, lm.specs(cfg, pc8))
    s0, extra = 16, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, s0 + extra), 0,
                              cfg.vocab_size)

    # teacher-forced forward over the whole sequence
    full_logits, _ = jax.jit(lambda p, t: lm.forward(p, cfg, pc8, t))(
        params, toks)

    # prefill on the prefix, then decode the remaining tokens one by one
    logits_p, caches = jax.jit(
        lambda p, t: lm.prefill(p, cfg, pc8, t, max_len=s0 + extra))(
        params, toks[:, :s0])
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(full_logits[:, :s0]),
                               atol=2e-3, rtol=2e-3)

    step = jax.jit(lambda p, c, t, n: lm.decode_step(p, c, cfg, pc8, t, n))
    for i in range(extra):
        logits_d, caches = step(params, caches, toks[:, s0 + i: s0 + i + 1],
                                s0 + i)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, s0 + i]),
            atol=2e-3, rtol=2e-3)


def test_sliding_window_ring_cache_decode(pc8, mesh8):
    """gemma3-style local layers with a ring-buffer cache smaller than the
    sequence must match teacher-forced forward logits."""
    cfg = reduce_config(get_config("gemma3-27b"))
    cfg = dataclasses.replace(cfg, vocab_size=128, local_window=8,
                              n_layers=len(cfg.pattern))
    params = place(lm.init(jax.random.PRNGKey(0), cfg, pc8, jnp.float32),
                   mesh8, lm.specs(cfg, pc8))
    s0, extra = 16, 8  # decode well past the window (total % tp == 0)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, s0 + extra), 0,
                              cfg.vocab_size)
    full_logits, _ = jax.jit(lambda p, t: lm.forward(p, cfg, pc8, t))(params, toks)
    logits_p, caches = jax.jit(
        lambda p, t: lm.prefill(p, cfg, pc8, t, max_len=s0 + extra))(
        params, toks[:, :s0])
    step = jax.jit(lambda p, c, t, n: lm.decode_step(p, c, cfg, pc8, t, n))
    for i in range(extra):
        logits_d, caches = step(params, caches, toks[:, s0 + i: s0 + i + 1],
                                s0 + i)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, s0 + i]),
            atol=2e-3, rtol=2e-3)


def test_serve_engine_generates(pc8, mesh8):
    cfg = reduce_config(get_config("smollm-360m"))
    cfg = dataclasses.replace(cfg, vocab_size=128)
    params = place(lm.init(jax.random.PRNGKey(0), cfg, pc8, jnp.float32),
                   mesh8, lm.specs(cfg, pc8))
    eng = ServeEngine(cfg, pc8, params, max_len=48)
    prompts = np.ones((2, 8), np.int32)
    out = eng.generate(prompts, max_new_tokens=8)
    assert out.shape == (2, 16)
    # deterministic greedy decode
    out2 = eng.generate(prompts, max_new_tokens=8)
    np.testing.assert_array_equal(out, out2)
