"""Serving integration: prefill-into-cache + decode == full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.parallel.sharding import place
from repro.serving import ServeEngine
from utils import reduce_config


@pytest.mark.parametrize("arch", ["qwen2-72b", "gemma3-27b", "mamba2-2.7b"])
def test_prefill_decode_matches_forward(arch, pc8, mesh8):
    """Greedy next-token from (prefill + decode) must match teacher-forced
    forward logits at every position."""
    cfg = reduce_config(get_config(arch))
    cfg = dataclasses.replace(cfg, vocab_size=128)
    params = place(lm.init(jax.random.PRNGKey(0), cfg, pc8, jnp.float32),
                   mesh8, lm.specs(cfg, pc8))
    s0, extra = 16, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, s0 + extra), 0,
                              cfg.vocab_size)

    # teacher-forced forward over the whole sequence
    full_logits, _ = jax.jit(lambda p, t: lm.forward(p, cfg, pc8, t))(
        params, toks)

    # prefill on the prefix, then decode the remaining tokens one by one
    logits_p, caches = jax.jit(
        lambda p, t: lm.prefill(p, cfg, pc8, t, max_len=s0 + extra))(
        params, toks[:, :s0])
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(full_logits[:, :s0]),
                               atol=2e-3, rtol=2e-3)

    step = jax.jit(lambda p, c, t, n: lm.decode_step(p, c, cfg, pc8, t, n))
    for i in range(extra):
        logits_d, caches = step(params, caches, toks[:, s0 + i: s0 + i + 1],
                                s0 + i)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, s0 + i]),
            atol=2e-3, rtol=2e-3)


def test_sliding_window_ring_cache_decode(pc8, mesh8):
    """gemma3-style local layers with a ring-buffer cache smaller than the
    sequence must match teacher-forced forward logits."""
    cfg = reduce_config(get_config("gemma3-27b"))
    cfg = dataclasses.replace(cfg, vocab_size=128, local_window=8,
                              n_layers=len(cfg.pattern))
    params = place(lm.init(jax.random.PRNGKey(0), cfg, pc8, jnp.float32),
                   mesh8, lm.specs(cfg, pc8))
    s0, extra = 16, 8  # decode well past the window (total % tp == 0)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, s0 + extra), 0,
                              cfg.vocab_size)
    full_logits, _ = jax.jit(lambda p, t: lm.forward(p, cfg, pc8, t))(params, toks)
    logits_p, caches = jax.jit(
        lambda p, t: lm.prefill(p, cfg, pc8, t, max_len=s0 + extra))(
        params, toks[:, :s0])
    step = jax.jit(lambda p, c, t, n: lm.decode_step(p, c, cfg, pc8, t, n))
    for i in range(extra):
        logits_d, caches = step(params, caches, toks[:, s0 + i: s0 + i + 1],
                                s0 + i)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, s0 + i]),
            atol=2e-3, rtol=2e-3)


def test_serve_engine_generates(pc8, mesh8):
    cfg = reduce_config(get_config("smollm-360m"))
    cfg = dataclasses.replace(cfg, vocab_size=128)
    params = place(lm.init(jax.random.PRNGKey(0), cfg, pc8, jnp.float32),
                   mesh8, lm.specs(cfg, pc8))
    eng = ServeEngine(cfg, pc8, params, max_len=48)
    prompts = np.ones((2, 8), np.int32)
    out = eng.generate(prompts, max_new_tokens=8)
    assert out.shape == (2, 16)
    # deterministic greedy decode
    out2 = eng.generate(prompts, max_new_tokens=8)
    np.testing.assert_array_equal(out, out2)


# ---- request-level continuous-batching engine -------------------------------

def _build(arch, pc, mesh, vocab=128, **over):
    cfg = reduce_config(get_config(arch))
    cfg = dataclasses.replace(cfg, vocab_size=vocab, **over)
    params = place(lm.init(jax.random.PRNGKey(0), cfg, pc, jnp.float32),
                   mesh, lm.specs(cfg, pc))
    return cfg, params


def _ref_greedy(cfg, pc, params, prompts, n_new, max_len):
    """Old ServeEngine semantics: per-token host round-trip greedy loop.

    The pinned reference the request-level engine must reproduce exactly
    under greedy sampling.  Feeds the prompt token by token (works for any
    prompt length — lm.prefill seq-shards over the TP axis, so it would
    need length % tp == 0; prefill==tokenwise parity is pinned separately
    by test_prefill_decode_matches_forward)."""
    prompts = np.asarray(prompts, np.int32)
    b, s0 = prompts.shape
    caches = lm.init_caches(cfg, pc, b, max_len, jnp.float32)
    step = jax.jit(lambda p, c, t, n: lm.decode_step(p, c, cfg, pc, t, n))
    lg = None
    for t in range(s0):
        lg, caches = step(params, caches, jnp.asarray(prompts[:, t:t + 1]), t)
    out = [np.asarray(jnp.argmax(lg[:, 0], -1).astype(jnp.int32))]
    for i in range(n_new - 1):
        lg, caches = step(params, caches, jnp.asarray(out[-1])[:, None], s0 + i)
        out.append(np.asarray(jnp.argmax(lg[:, 0], -1).astype(jnp.int32)))
    return np.stack(out, axis=1)  # [B, n_new]


def test_generate_parity_old_vs_new(pc8, mesh8):
    """generate() (submit/step/drain underneath) == the old fixed-batch
    prefill + per-token greedy loop, token for token (satellite)."""
    cfg, params = _build("smollm-360m", pc8, mesh8)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(5), (2, 8), 0, cfg.vocab_size), np.int32)
    eng = ServeEngine(cfg, pc8, params, max_len=48)
    out = eng.generate(prompts, max_new_tokens=6)
    ref = _ref_greedy(cfg, pc8, params, prompts, 6, max_len=48)
    np.testing.assert_array_equal(out[:, 8:], ref)


def test_step_host_sync_and_trace_counts(pc8, mesh8):
    """The jit'd step is the no-per-token-round-trip contract: one trace
    total, one host sync per step, many tokens per sync — with requests
    admitted mid-run as slots free up (tentpole acceptance)."""
    from repro.serving import Request

    cfg, params = _build("smollm-360m", pc8, mesh8)
    eng = ServeEngine(cfg, pc8, params, max_len=64, n_slots=2, decode_block=8)
    key = jax.random.PRNGKey(7)
    prompts = [np.asarray(jax.random.randint(key, (ln,), 0, cfg.vocab_size),
                          np.int32) for key, ln in
               zip(jax.random.split(key, 3), (5, 13, 9))]
    budgets = (4, 10, 6)
    hs = [eng.submit(Request(tokens=p, max_new_tokens=b))
          for p, b in zip(prompts, budgets)]
    # only 2 slots: the third request must wait in the queue
    assert eng.poll(hs[2])["queued"]
    outs = eng.drain(hs)
    assert eng.stats["steps"] >= 2  # mid-run admission forced extra steps
    assert eng.stats["host_syncs"] == eng.stats["steps"]
    assert eng.stats["step_traces"] == 1  # static shapes: one trace, ever
    # decode ran in blocks: some step emitted >1 token for one sync
    assert max(len(o) for o in outs.values()) > eng.stats["steps"] >= 1
    for h, p, b in zip(hs, prompts, budgets):
        assert eng.poll(h)["done"]
        ref = _ref_greedy(cfg, pc8, params, p[None, :], b, max_len=64)
        np.testing.assert_array_equal(outs[h], ref[0])


def test_exact_token_count_and_eos(pc8, mesh8):
    """Exactly max_new_tokens tokens unless eos arrives first; eos stops the
    slot early and is included in the output (bugfix satellite)."""
    from repro.serving import Request

    cfg, params = _build("smollm-360m", pc8, mesh8)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(9), (2, 6), 0, cfg.vocab_size), np.int32)
    ref = _ref_greedy(cfg, pc8, params, prompts, 8, max_len=32)

    # max_new_tokens=1: exactly one token == argmax of the prefill logits
    eng = ServeEngine(cfg, pc8, params, max_len=32, n_slots=2)
    outs = eng.drain([eng.submit(Request(tokens=r, max_new_tokens=1))
                      for r in prompts])
    for h, row in zip(sorted(outs), ref[:, :1]):
        np.testing.assert_array_equal(outs[h], row)

    # eos mid-stream: row 0 stops at the eos position, row 1 (same batch,
    # eos it never emits) runs to its full budget
    eos = int(ref[0, 3])
    eng2 = ServeEngine(cfg, pc8, params, max_len=32, n_slots=2)
    h0 = eng2.submit(Request(tokens=prompts[0], max_new_tokens=8, eos_id=eos))
    h1 = eng2.submit(Request(tokens=prompts[1], max_new_tokens=8,
                             eos_id=cfg.vocab_size + 1))
    outs2 = eng2.drain([h0, h1])
    stop = int(np.argmax(ref[0] == eos))  # first eos occurrence in reference
    np.testing.assert_array_equal(outs2[h0], ref[0, :stop + 1])
    assert outs2[h0][-1] == eos
    np.testing.assert_array_equal(outs2[h1], ref[1])


def test_engine_gqa_and_sampling(pc8, mesh8):
    """GQA config (kv_heads > 1 on tp=4) through the engine; greedy matches
    the reference loop, and seeded sampling is reproducible + composition
    independent (same request alone or sharing the batch)."""
    from repro.serving import Request

    cfg, params = _build("qwen2-72b", pc8, mesh8)
    assert cfg.n_kv_heads > 1
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(11), (2, 8), 0, cfg.vocab_size), np.int32)
    eng = ServeEngine(cfg, pc8, params, max_len=32)
    out = eng.generate(prompts, max_new_tokens=4)
    ref = _ref_greedy(cfg, pc8, params, prompts, 4, max_len=32)
    np.testing.assert_array_equal(out[:, 8:], ref)

    # sampled decode: per-request seed makes results batch-composition
    # independent — alone vs. sharing the batch gives identical tokens
    req = Request(tokens=prompts[0], max_new_tokens=4, temperature=0.7,
                  top_k=8, seed=3)
    alone = ServeEngine(cfg, pc8, params, max_len=32)
    a = alone.drain([alone.submit(req)])
    both = ServeEngine(cfg, pc8, params, max_len=32)
    hs = [both.submit(req),
          both.submit(Request(tokens=prompts[1], max_new_tokens=4,
                              temperature=0.9, seed=12))]
    b = both.drain(hs)
    np.testing.assert_array_equal(list(a.values())[0], b[hs[0]])


def test_engine_warms_decode_channels(pc8, mesh8):
    """With tuning on, engine construction resolves decode-shape joint
    winners (decode=True signatures, keyed apart from prefill) for its TP
    GEMMs (decode-tuning satellite; the winner-differs guarantee at real
    dims is pinned in test_tune.py)."""
    from repro.core.channels import BlockChannel

    cfg, params = _build("smollm-360m", pc8, mesh8)
    pc_t = dataclasses.replace(pc8, tune=True)
    eng = ServeEngine(cfg, pc_t, params, max_len=32)
    assert {"qkv", "attn_out", "ffn_gu", "ffn_down"} <= set(eng.decode_channels)
    for name, ch in eng.decode_channels.items():
        assert isinstance(ch, BlockChannel), name
