"""Test harness configuration.

Tests exercise the distributed machinery, so we simulate a SMALL device pool
(8 CPU devices — NOT the dry-run's 512; launch/dryrun.py sets its own count
process-locally).  Must run before jax initializes.
"""
import os
import sys

os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_force_host_platform_device_count=8 "
    "--xla_disable_hlo_passes=all-reduce-promotion",
)
sys.path.insert(0, os.path.dirname(__file__))  # `import utils` from tests/

import jax  # noqa: E402
import pytest  # noqa: E402

from repro.compat import make_mesh  # noqa: E402
from repro.parallel.context import ParallelContext  # noqa: E402


@pytest.fixture(scope="session")
def mesh8():
    return make_mesh((1, 2, 4), ("pod", "data", "model"))


@pytest.fixture(scope="session")
def pc8(mesh8):
    return ParallelContext(mesh=mesh8, mode="overlap")


@pytest.fixture(scope="session")
def pc8_baseline(mesh8):
    return ParallelContext(mesh=mesh8, mode="baseline")
