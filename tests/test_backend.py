"""Backend layer tests: emulated-target kernel/oracle parity + import hygiene.

Two jobs:

  1. every public kernel builds and matches its ref.py oracle with the
     backend forced to the ``emulated`` target (interpret on CPU) — the
     configuration CI runs on any JAX without a TPU;
  2. a guard that greps ``src/repro`` for direct
     ``jax.experimental.pallas.tpu`` imports outside ``repro/backend/`` —
     the backend package is the single point of version adaptation, and
     drift regressions start with someone re-importing pltpu in a kernel.
"""
import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import backend, kernels
from repro.kernels import ref
from utils import allclose

KEY = jax.random.PRNGKey(0)
SRC_ROOT = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"


@pytest.fixture()
def emulated_target(monkeypatch):
    monkeypatch.setenv("REPRO_BACKEND", "emulated")
    assert backend.target() == "emulated"
    yield


# ---- surface sanity ----------------------------------------------------------

def test_describe_reports_probes():
    info = backend.describe()
    assert info["jax_version"] == jax.__version__
    assert info["compiler_params_cls"] in ("CompilerParams", "TPUCompilerParams")


def test_compiler_params_drops_unknown_fields():
    # must not raise even for hints this JAX doesn't know
    params = backend.compiler_params(
        dimension_semantics=("parallel",), not_a_real_field_ever=1
    )
    assert params.dimension_semantics == ("parallel",)


def test_target_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_BACKEND", "tpu")
    assert backend.target() == "tpu"
    monkeypatch.setenv("REPRO_BACKEND", "emulated")
    assert backend.is_emulated()
    monkeypatch.setenv("REPRO_BACKEND", "bogus")
    with pytest.raises(ValueError):
        backend.target()


def test_resolve_interpret_emulated_forces_interpret(monkeypatch):
    monkeypatch.setenv("REPRO_BACKEND", "emulated")
    assert backend.resolve_interpret(None) is not False
    # even an explicit compile request cannot compile without a TPU toolchain
    assert backend.resolve_interpret(False) is not False
    assert backend.default_interpret() is True


# ---- every public kernel vs. its oracle under the emulated target ------------

def test_matmul_oracle_emulated(emulated_target):
    x = jax.random.normal(KEY, (256, 128), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 256), jnp.float32)
    allclose(kernels.matmul(x, w), ref.matmul_ref(x, w), atol=2e-4, rtol=2e-4)


def test_flash_attention_oracle_emulated(emulated_target):
    q = jax.random.normal(KEY, (2, 128, 64), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (2, 128, 64), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(3), (2, 128, 64), jnp.float32)
    y = kernels.flash_attention(q, k, v, causal=True)
    allclose(y, ref.flash_attention_ref(q, k, v, causal=True),
             atol=2e-4, rtol=2e-3)


def test_grouped_matmul_oracle_emulated(emulated_target):
    e, m, k, n, bm = 4, 256, 128, 128, 128
    tile_expert = jnp.array([1, 3], jnp.int32)
    x = jax.random.normal(KEY, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(4), (e, k, n), jnp.float32)
    y = kernels.grouped_matmul(x, w, tile_expert, tile=(bm, 128, 128))
    allclose(y, ref.grouped_matmul_ref(x, w, tile_expert, bm),
             atol=1e-4, rtol=1e-4)


def test_ssd_intra_chunk_oracle_emulated(emulated_target):
    t, q, p = 2, 16, 8
    cum = -jnp.abs(jax.random.normal(KEY, (t, q))).cumsum(axis=1)
    cb = jax.random.normal(jax.random.PRNGKey(9), (t, q, q)) * 0.3
    xdt = jax.random.normal(jax.random.PRNGKey(10), (t, q, p)) * 0.5
    y = kernels.ssd_intra_chunk(cum, cb, xdt)
    diff = cum[:, :, None] - cum[:, None, :]
    mask = np.tril(np.ones((q, q), bool))
    g = np.asarray(cb) * np.where(mask, np.exp(np.asarray(diff)), 0.0)
    allclose(y, np.einsum("tqk,tkp->tqp", g, np.asarray(xdt)),
             atol=1e-4, rtol=1e-3)


def test_ag_gemm_fused_oracle_emulated(emulated_target):
    r, m_loc, k, n_loc = 4, 16, 32, 128
    mesh = backend.make_mesh((r,), ("model",))
    x = jax.random.normal(KEY, (r * m_loc, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(11), (k, r * n_loc), jnp.float32)
    fn = backend.shard_map(
        lambda a, b: kernels.ag_gemm_shard(a, b, world_size=r, bn=128),
        mesh, in_specs=(P("model", None), P(None, "model")),
        out_specs=P(None, "model"))
    # global-product oracle (ref.ag_gemm_ref states the same spec shard-wise)
    allclose(jax.jit(fn)(x, w), x @ w, atol=1e-3, rtol=1e-3)


def test_gemm_rs_fused_oracle_emulated(emulated_target):
    r, m, k_loc, n = 4, 64, 32, 128
    mesh = backend.make_mesh((r,), ("model",))
    x = jax.random.normal(KEY, (m, r * k_loc), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(12), (r * k_loc, n), jnp.float32)
    fn = backend.shard_map(
        lambda a, b: kernels.gemm_rs_shard(a, b, world_size=r, bn=128),
        mesh, in_specs=(P(None, "model"), P("model", None)),
        out_specs=P("model", None))
    # global-product oracle (ref.gemm_rs_ref states the same spec shard-wise)
    allclose(jax.jit(fn)(x, w), x @ w, atol=1e-3, rtol=1e-3)


# ---- import hygiene guard ----------------------------------------------------

_FORBIDDEN = re.compile(
    r"(from\s+jax\.experimental\.pallas\s+import\s+[^\n]*\btpu\b"
    r"|jax\.experimental\.pallas\.tpu"
    r"|from\s+jax\.experimental\.pallas\.tpu\s+import)"
)


def test_no_pltpu_imports_outside_backend():
    """repro.backend is the only module allowed to touch pallas TPU API."""
    offenders = []
    for path in sorted(SRC_ROOT.rglob("*.py")):
        rel = path.relative_to(SRC_ROOT)
        if rel.parts[0] == "backend":
            continue
        for lineno, line in enumerate(
                path.read_text(encoding="utf-8").splitlines(), 1):
            code = line.split("#", 1)[0]
            if _FORBIDDEN.search(code):
                offenders.append(f"{rel}:{lineno}: {line.strip()}")
    assert not offenders, (
        "direct jax.experimental.pallas.tpu usage outside repro/backend/ "
        "(route through repro.backend instead):\n" + "\n".join(offenders)
    )
