"""Quickstart: TileLink tile-centric overlap in 60 lines.

Builds an 8-device mesh, runs the paper's motivating TP-MLP both ways
(operator-centric non-overlap vs TileLink ring overlap), verifies they agree,
and shows the collective schedule difference in the compiled HLO.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map, make_mesh
from repro.core import compile_overlap, BlockChannel, CommSpec

mesh = make_mesh((8,), ("model",))
# the full CommSpec x CompSpec space compiles — try order="bidir_ring" or
# "all2all", any num_channels, comp=CompSpec(accum_dtype="bfloat16"): the
# frontend lowers (kind, BlockChannel) -> tile plan -> generic executor
channel = BlockChannel(axis="model", num_channels=2,
                       comm=CommSpec(order="ring", resource="dma"))

# frontend: compile tile programs for both resource mappings
ag_gemm = compile_overlap("ag_matmul", channel, overlapped=True)
ag_gemm_base = compile_overlap("ag_matmul", channel, overlapped=False)

S, H, FF = 1024, 512, 1408
key = jax.random.PRNGKey(0)
x = jax.device_put(jax.random.normal(key, (S, H)), NamedSharding(mesh, P("model", None)))
w = jax.device_put(jax.random.normal(key, (H, FF)), NamedSharding(mesh, P(None, "model")))

specs = dict(in_specs=(P("model", None), P(None, "model")), out_specs=P(None, "model"))
f_tl = jax.jit(shard_map(ag_gemm, mesh, **specs))
f_nb = jax.jit(shard_map(ag_gemm_base, mesh, **specs))

y1, y2 = f_tl(x, w), f_nb(x, w)
np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-3)
print("TileLink overlap == non-overlap baseline: OK")

for name, f in [("tilelink", f_tl), ("non-overlap", f_nb)]:
    hlo = f.lower(x, w).compile().as_text()
    counts = {op: hlo.count(f" {op}(") for op in
              ("all-gather", "collective-permute", "all-reduce")}
    print(f"{name:12s} collective schedule: {counts}")
print("note: tilelink decomposes the AllGather into ring permutes that XLA "
      "overlaps with the per-tile GEMMs (copy-engine resource mapping)")
