"""End-to-end training driver example.

Default: a reduced smollm-family model trains a few hundred steps on the
synthetic bigram corpus — loss visibly decreases. The full ~100M-parameter
run is the same command with --full (hours on CPU; the config is the real
smollm-360m).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full]
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from repro.launch.train import train

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="train the real smollm-360m config (slow on CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/tilelink_ckpt")
    args = ap.parse_args()
    losses = train("smollm-360m", steps=args.steps, batch=8, seq=256,
                   reduce=not args.full, ckpt_dir=args.ckpt_dir,
                   ckpt_every=100, log_every=20)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
