"""Batched serving example: prefill-into-cache + jit'd decode loop.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
sys.argv = [sys.argv[0], "--arch", "smollm-360m", "--reduce",
            "--batch", "4", "--prompt-len", "16", "--new-tokens", "24"] + sys.argv[1:]
from repro.launch.serve import main

if __name__ == "__main__":
    main()
