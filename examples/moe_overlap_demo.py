"""Dynamic-mapping demo: the paper's AG+MoE double ring (Fig. 5).

Routes tokens with a real top-k router (dynamic mapping tables travel with the
data around the ring), runs the overlapped AG -> GroupGEMM -> TopkReduce -> RS
chain, and checks it against a dense per-expert oracle.

Run:  PYTHONPATH=src python examples/moe_overlap_demo.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map, make_mesh
from repro.core.moe_overlap import ag_moe, moe_router

E, TOPK, D, F, TOK = 16, 2, 64, 128, 512
mesh = make_mesh((8,), ("model",))
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (TOK, D)) * 0.5
wr = jax.random.normal(jax.random.PRNGKey(1), (D, E))
wgu = jax.random.normal(jax.random.PRNGKey(2), (E, D, 2 * F)) * 0.1
wdn = jax.random.normal(jax.random.PRNGKey(3), (E, F, D)) * 0.1


def moe(xs, wgu_, wdn_):
    ids, wts, aux = moe_router(xs, wr, num_experts=E, top_k=TOPK)
    return ag_moe(xs, ids, wts, wgu_, wdn_, axis="model", capacity_factor=8.0)

f = jax.jit(shard_map(
    moe, mesh,
    in_specs=(P("model", None), P("model", None, None), P("model", None, None)),
    out_specs=P("model", None)))
y = f(x, jax.device_put(wgu, NamedSharding(mesh, P("model", None, None))),
      jax.device_put(wdn, NamedSharding(mesh, P("model", None, None))))

# dense oracle
probs = jax.nn.softmax(x @ wr, -1)
topw, topi = jax.lax.top_k(probs, TOPK)
topw = topw / topw.sum(-1, keepdims=True)
dense = jnp.zeros_like(x)
for e in range(E):
    h = x @ wgu[e]
    hh = jax.nn.silu(h[:, :F]) * h[:, F:]
    dense += (((topi == e) * topw).sum(-1))[:, None] * (hh @ wdn[e])
np.testing.assert_allclose(np.asarray(y), np.asarray(dense), atol=1e-4)
print(f"AG+MoE double ring over 8 ranks == dense oracle "
      f"(E={E}, top-{TOPK}, {TOK} tokens): OK")
